//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! implements exactly the API subset the workspace uses: [`Error`],
//! [`Result`], the [`Context`] extension trait, and the `anyhow!` / `bail!` /
//! `ensure!` macros. Semantics follow the real crate where it matters:
//!
//! - `{}` shows the outermost message only, `{:#}` joins the whole context
//!   chain with `": "`, and `{:?}` renders a `Caused by:` listing;
//! - any `std::error::Error + Send + Sync + 'static` converts via `?`;
//! - `Error` itself is `Send + Sync` so it crosses worker-thread boundaries.

use std::error::Error as StdError;
use std::fmt;

/// Result alias defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error with a chain of human-readable context frames.
pub struct Error {
    /// innermost message (the original failure)
    msg: String,
    /// original typed error, if any (kept for completeness/debugging)
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
    /// context frames, innermost first (pushed in wrap order)
    context: Vec<String>,
}

impl Error {
    /// Create from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
            context: Vec::new(),
        }
    }

    /// Create from a typed error, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error {
            msg: error.to_string(),
            source: Some(Box::new(error)),
            context: Vec::new(),
        }
    }

    /// Wrap with an additional layer of context (outermost-last push).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    /// The original typed error, if this `Error` was built from one.
    pub fn source_ref(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }

    /// Messages outermost-first (most recent context down to the root cause).
    fn frames(&self) -> impl Iterator<Item = &str> {
        self.context
            .iter()
            .rev()
            .map(String::as_str)
            .chain(std::iter::once(self.msg.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the full chain, anyhow-style
            let mut first = true;
            for frame in self.frames() {
                if !first {
                    f.write_str(": ")?;
                }
                f.write_str(frame)?;
                first = false;
            }
            Ok(())
        } else {
            f.write_str(self.frames().next().unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut frames = self.frames();
        if let Some(top) = frames.next() {
            f.write_str(top)?;
        }
        let rest: Vec<&str> = frames.collect();
        if !rest.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for (i, frame) in rest.iter().enumerate() {
                write!(f, "\n    {i}: {frame}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to results
/// and options, mirroring `anyhow::Context`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Early-return with an [`Error`] when the condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_outermost_only() {
        let e = Error::new(io_err()).context("loading weights");
        assert_eq!(format!("{e}"), "loading weights");
    }

    #[test]
    fn alternate_display_full_chain() {
        let e = Error::new(io_err())
            .context("loading weights")
            .context("starting worker");
        assert_eq!(
            format!("{e:#}"),
            "starting worker: loading weights: missing file"
        );
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: missing file");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
        assert_eq!(Some(5).context("x").unwrap(), 5);
    }

    #[test]
    fn macros() {
        fn f(fail: bool) -> Result<u32> {
            ensure!(!fail, "failed with {}", 42);
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "failed with 42");

        fn g() -> Result<()> {
            bail!("nope: {}", "reason");
        }
        assert_eq!(format!("{}", g().unwrap_err()), "nope: reason");

        let e = anyhow!("literal {}", 7);
        assert_eq!(format!("{e}"), "literal 7");
        let e2 = Error::msg(String::from("owned"));
        assert_eq!(format!("{e2}"), "owned");
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
