//! Top-p% magnitude extraction — step (1) of the paper's §4.5:
//! `S = top_p%(|W|)`, residual `R = W − S`.
//!
//! Selection is O(mn) via quickselect on |value| (the paper notes the naive
//! sort costs O(mn log mn); this avoids the log factor).

use crate::linalg::Matrix;
use crate::sparse::Coo;

/// Extract the `k` largest-|value| entries of `w` into a COO matrix and
/// return (S, residual). Exact capacity: S.nnz() == min(k, w.len()).
pub fn top_k_extract(w: &Matrix, k: usize) -> (Coo, Matrix) {
    let total = w.data.len();
    let k = k.min(total);
    let mut resid = w.clone();
    let mut s = Coo::new(w.rows, w.cols);
    if k == 0 {
        return (s, resid);
    }
    if k == total {
        for i in 0..w.rows {
            for j in 0..w.cols {
                s.push(i, j, w.at(i, j));
            }
        }
        return (s, Matrix::zeros(w.rows, w.cols));
    }

    // quickselect the threshold magnitude
    let mut mags: Vec<f32> = w.data.iter().map(|v| v.abs()).collect();
    let thresh = quickselect_desc(&mut mags, k - 1);

    // collect entries: strictly above threshold first, then fill ties
    let mut picked: Vec<usize> = Vec::with_capacity(k);
    let mut ties: Vec<usize> = Vec::new();
    for (idx, v) in w.data.iter().enumerate() {
        let a = v.abs();
        if a > thresh {
            picked.push(idx);
        } else if a == thresh {
            ties.push(idx);
        }
    }
    for &idx in ties.iter().take(k - picked.len()) {
        picked.push(idx);
    }
    picked.sort_unstable(); // row-major order
    for idx in picked {
        let (i, j) = (idx / w.cols, idx % w.cols);
        s.push(i, j, w.data[idx]);
        resid.data[idx] = 0.0;
    }
    (s, resid)
}

/// Extract the top-`p` fraction (0..=1) of entries. Matches the python
/// exporter's capacity rule: floor(p * len).
pub fn top_p_extract(w: &Matrix, p: f64) -> (Coo, Matrix) {
    let k = ((w.data.len() as f64) * p).floor() as usize;
    top_k_extract(w, k)
}

/// k-th largest element (0-based) via in-place quickselect.
fn quickselect_desc(xs: &mut [f32], k: usize) -> f32 {
    let mut lo = 0usize;
    let mut hi = xs.len();
    let mut k = k;
    loop {
        if hi - lo <= 1 {
            return xs[lo];
        }
        // median-of-three pivot
        let mid = lo + (hi - lo) / 2;
        let (a, b, c) = (xs[lo], xs[mid], xs[hi - 1]);
        let pivot = if (a >= b) == (a <= c) {
            a
        } else if (b >= a) == (b <= c) {
            b
        } else {
            c
        };
        // partition descending: [> pivot | == pivot | < pivot]
        let mut i = lo;
        let mut j = lo;
        let mut n = hi;
        while j < n {
            if xs[j] > pivot {
                xs.swap(i, j);
                i += 1;
                j += 1;
            } else if xs[j] < pivot {
                n -= 1;
                xs.swap(j, n);
            } else {
                j += 1;
            }
        }
        // xs[lo..i] > pivot, xs[i..n] == pivot, xs[n..hi] < pivot
        if lo + k < i {
            hi = i;
        } else if lo + k < n {
            return pivot;
        } else {
            k -= n - lo;
            lo = n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn extracts_exactly_k() {
        let w = Matrix::randn(16, 16, 1);
        let (s, _r) = top_k_extract(&w, 40);
        assert_eq!(s.nnz(), 40);
    }

    #[test]
    fn picks_largest_magnitudes() {
        let mut w = Matrix::zeros(4, 4);
        w.set(1, 2, -9.0);
        w.set(3, 3, 5.0);
        w.set(0, 0, 0.1);
        let (s, r) = top_k_extract(&w, 2);
        let d = s.to_dense();
        assert_eq!(d.at(1, 2), -9.0);
        assert_eq!(d.at(3, 3), 5.0);
        assert_eq!(r.at(1, 2), 0.0);
        assert_eq!(r.at(0, 0), 0.1);
    }

    #[test]
    fn sparse_plus_residual_is_exact() {
        check(20, |rng| {
            let n = 2 + rng.below(30);
            let w = Matrix::randn(n, n, rng.next_u64());
            let k = rng.below(n * n + 1);
            let (s, r) = top_k_extract(&w, k);
            let rec = s.to_dense().add(&r);
            if rec.data == w.data {
                Ok(())
            } else {
                Err("S + R != W".into())
            }
        });
    }

    #[test]
    fn threshold_correctness_vs_sort() {
        check(15, |rng| {
            let n = 3 + rng.below(20);
            let w = Matrix::randn(n, n, rng.next_u64());
            let k = 1 + rng.below(n * n - 1);
            let (s, _r) = top_k_extract(&w, k);
            // min |v| in S must be >= max |v| not in S
            let mut all: Vec<f32> = w.data.iter().map(|v| v.abs()).collect();
            all.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let kth = all[k - 1];
            let min_in_s = s.v.iter().map(|v| v.abs()).fold(f32::INFINITY, f32::min);
            if (min_in_s - kth).abs() < 1e-6 || min_in_s >= kth {
                Ok(())
            } else {
                Err(format!("min in S {min_in_s} < kth {kth}"))
            }
        });
    }

    #[test]
    fn zero_and_full_budget() {
        let w = Matrix::randn(5, 5, 2);
        let (s0, r0) = top_p_extract(&w, 0.0);
        assert_eq!(s0.nnz(), 0);
        assert_eq!(r0.data, w.data);
        let (s1, r1) = top_p_extract(&w, 1.0);
        assert_eq!(s1.nnz(), 25);
        assert!(r1.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn ties_respect_capacity() {
        let w = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let (s, _) = top_k_extract(&w, 3);
        assert_eq!(s.nnz(), 3);
    }

    #[test]
    fn entries_row_major_sorted() {
        let w = Matrix::randn(8, 8, 3);
        let (s, _) = top_k_extract(&w, 10);
        for k in 1..s.nnz() {
            let prev = (s.ri[k - 1], s.ci[k - 1]);
            let cur = (s.ri[k], s.ci[k]);
            assert!(prev < cur);
        }
    }
}
