//! Sparsity-pattern graph: builds the symmetrized adjacency structure RCM
//! walks. The pattern comes from the residual's largest-magnitude entries
//! (quantile-thresholded), matching DESIGN.md §7.

use crate::linalg::Matrix;

/// Undirected graph in adjacency-list form over n vertices.
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    pub adj: Vec<Vec<u32>>,
}

impl Graph {
    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    /// Build from the magnitude pattern of a square matrix: an edge (i,j)
    /// exists if |m[i,j]| or |m[j,i]| is >= the `quantile`-th magnitude.
    /// quantile=0.9 keeps the top 10% of entries as structure.
    pub fn from_pattern(m: &Matrix, quantile: f64) -> Graph {
        assert!(m.is_square());
        let n = m.rows;
        let thresh = magnitude_quantile(m, quantile).max(1e-30);
        let mut adj: Vec<Vec<u32>> = vec![Vec::new(); n];
        for i in 0..n {
            for j in (i + 1)..n {
                if m.at(i, j).abs() >= thresh || m.at(j, i).abs() >= thresh {
                    adj[i].push(j as u32);
                    adj[j].push(i as u32);
                }
            }
        }
        // sort adjacency by (degree, index) — canonical RCM tie-breaking
        let degs: Vec<usize> = adj.iter().map(|a| a.len()).collect();
        for list in adj.iter_mut() {
            list.sort_by_key(|&v| (degs[v as usize], v));
        }
        Graph { n, adj }
    }

    /// Connected components (as vertex lists); used to seed RCM per component.
    pub fn components(&self) -> Vec<Vec<u32>> {
        let mut seen = vec![false; self.n];
        let mut out = Vec::new();
        for start in 0..self.n {
            if seen[start] {
                continue;
            }
            let mut comp = vec![start as u32];
            seen[start] = true;
            let mut head = 0;
            while head < comp.len() {
                let v = comp[head] as usize;
                head += 1;
                for &w in &self.adj[v] {
                    if !seen[w as usize] {
                        seen[w as usize] = true;
                        comp.push(w);
                    }
                }
            }
            out.push(comp);
        }
        out
    }
}

/// The q-quantile of |entries| (q in [0,1]); q=0 -> min, q→1 -> max.
pub fn magnitude_quantile(m: &Matrix, q: f64) -> f32 {
    let mut mags: Vec<f32> = m.data.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((q * mags.len() as f64) as usize).min(mags.len() - 1);
    mags[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_matrix(n: usize) -> Matrix {
        // tridiagonal: a path graph
        Matrix::from_fn(n, n, |i, j| {
            if i == j || i.abs_diff(j) == 1 {
                1.0
            } else {
                0.0
            }
        })
    }

    #[test]
    fn path_graph_degrees() {
        let g = Graph::from_pattern(&path_matrix(5), 0.0);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(2), 2);
        assert_eq!(g.degree(4), 1);
    }

    #[test]
    fn symmetrizes_asymmetric_pattern() {
        let mut m = Matrix::zeros(3, 3);
        m.set(0, 2, 5.0); // only one direction present
        let g = Graph::from_pattern(&m, 0.0);
        assert!(g.adj[0].contains(&2));
        assert!(g.adj[2].contains(&0));
    }

    #[test]
    fn quantile_thresholding_drops_small() {
        let mut m = Matrix::zeros(4, 4);
        m.set(0, 1, 10.0);
        m.set(2, 3, 0.001);
        // high quantile keeps only the big entry
        let g = Graph::from_pattern(&m, 0.95);
        assert!(g.adj[0].contains(&1));
        assert!(g.adj[2].is_empty());
    }

    #[test]
    fn components_of_disconnected() {
        let mut m = Matrix::zeros(6, 6);
        m.set(0, 1, 1.0);
        m.set(2, 3, 1.0);
        let g = Graph::from_pattern(&m, 0.0);
        let comps = g.components();
        // {0,1}, {2,3}, {4}, {5}
        assert_eq!(comps.len(), 4);
        let sizes: Vec<usize> = comps.iter().map(|c| c.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 6);
    }

    #[test]
    fn magnitude_quantile_endpoints() {
        let m = Matrix::from_vec(1, 4, vec![-4.0, 1.0, -2.0, 3.0]);
        assert_eq!(magnitude_quantile(&m, 0.0), 1.0);
        assert_eq!(magnitude_quantile(&m, 0.99), 4.0);
    }
}
