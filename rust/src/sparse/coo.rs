//! Coordinate-format sparse matrix (the S "spike" matrix of the paper).

use crate::linalg::Matrix;

#[derive(Clone, Debug, Default)]
pub struct Coo {
    pub rows: usize,
    pub cols: usize,
    pub ri: Vec<u32>,
    pub ci: Vec<u32>,
    pub v: Vec<f32>,
}

impl Coo {
    pub fn new(rows: usize, cols: usize) -> Coo {
        Coo {
            rows,
            cols,
            ri: Vec::new(),
            ci: Vec::new(),
            v: Vec::new(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.v.len()
    }

    pub fn push(&mut self, r: usize, c: usize, val: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.ri.push(r as u32);
        self.ci.push(c as u32);
        self.v.push(val);
    }

    /// y += S x.
    pub fn matvec_add(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for k in 0..self.v.len() {
            y[self.ri[k] as usize] += self.v[k] * x[self.ci[k] as usize];
        }
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for k in 0..self.v.len() {
            let (i, j) = (self.ri[k] as usize, self.ci[k] as usize);
            m.data[i * self.cols + j] += self.v[k];
        }
        m
    }

    /// Sort entries row-major (the TPU segment-sum layout; also what the
    /// python exporter emits).
    pub fn sort_row_major(&mut self) {
        let mut idx: Vec<usize> = (0..self.v.len()).collect();
        idx.sort_by_key(|&k| (self.ri[k], self.ci[k]));
        self.ri = idx.iter().map(|&k| self.ri[k]).collect();
        self.ci = idx.iter().map(|&k| self.ci[k]).collect();
        self.v = idx.iter().map(|&k| self.v[k]).collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_matches_dense() {
        let mut s = Coo::new(4, 4);
        s.push(0, 1, 2.0);
        s.push(3, 0, -1.0);
        s.push(1, 1, 0.5);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut y = vec![0.0; 4];
        s.matvec_add(&x, &mut y);
        let expect = s.to_dense().matvec(&x);
        assert_eq!(y, expect);
    }

    #[test]
    fn duplicates_accumulate() {
        let mut s = Coo::new(2, 2);
        s.push(0, 0, 1.0);
        s.push(0, 0, 2.0);
        assert_eq!(s.to_dense().at(0, 0), 3.0);
        let mut y = vec![0.0; 2];
        s.matvec_add(&[1.0, 0.0], &mut y);
        assert_eq!(y[0], 3.0);
    }

    #[test]
    fn sort_row_major_orders() {
        let mut s = Coo::new(3, 3);
        s.push(2, 1, 1.0);
        s.push(0, 2, 2.0);
        s.push(2, 0, 3.0);
        s.sort_row_major();
        assert_eq!(s.ri, vec![0, 2, 2]);
        assert_eq!(s.ci, vec![2, 0, 1]);
        assert_eq!(s.v, vec![2.0, 3.0, 1.0]);
    }

    #[test]
    fn empty_matvec_is_noop() {
        let s = Coo::new(3, 3);
        let mut y = vec![1.0; 3];
        s.matvec_add(&[1.0; 3], &mut y);
        assert_eq!(y, vec![1.0; 3]);
    }
}
