//! Sparse substrate: COO/CSR formats, top-p% magnitude extraction, the
//! sparsity-pattern graph, Reverse Cuthill–McKee reordering, and bandwidth
//! metrics — everything §4.5's "carve out the spikes, reorder the residual"
//! step needs.

pub mod bandwidth;
pub mod coo;
pub mod csr;
pub mod graph;
pub mod rcm;
pub mod topk;

pub use coo::Coo;
pub use csr::Csr;
pub use rcm::rcm;
pub use topk::top_p_extract;
