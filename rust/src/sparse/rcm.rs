//! Reverse Cuthill–McKee reordering (George–Liu pseudo-peripheral start).
//!
//! The paper's step (2): after spike removal, symmetrically permute the
//! residual so its large entries concentrate near the diagonal, shrinking
//! the numerical rank of the off-diagonal HSS blocks.

use crate::linalg::Permutation;
use crate::sparse::graph::Graph;
use std::collections::VecDeque;

/// BFS level structure rooted at `start`; returns (levels, depth, last level).
fn level_structure(g: &Graph, start: u32, level: &mut [i32]) -> (usize, Vec<u32>) {
    level.iter_mut().for_each(|l| *l = -1);
    let mut q = VecDeque::new();
    q.push_back(start);
    level[start as usize] = 0;
    let mut depth = 0usize;
    let mut last = vec![start];
    let mut cur_level: Vec<u32> = Vec::new();
    while let Some(v) = q.pop_front() {
        let lv = level[v as usize] as usize;
        if lv > depth {
            depth = lv;
            last = std::mem::take(&mut cur_level);
        }
        if lv == depth {
            cur_level.push(v);
        }
        for &w in &g.adj[v as usize] {
            if level[w as usize] < 0 {
                level[w as usize] = lv as i32 + 1;
                q.push_back(w);
            }
        }
    }
    if !cur_level.is_empty() {
        last = cur_level;
    }
    (depth, last)
}

/// George–Liu pseudo-peripheral vertex of the component containing `seed`.
fn pseudo_peripheral(g: &Graph, seed: u32) -> u32 {
    let mut level = vec![-1i32; g.n];
    let mut root = seed;
    let (mut depth, mut last) = level_structure(g, root, &mut level);
    loop {
        // candidate: minimum-degree vertex of the last level
        let cand = *last
            .iter()
            .min_by_key(|&&v| g.degree(v as usize))
            .unwrap();
        let (d2, l2) = level_structure(g, cand, &mut level);
        if d2 > depth {
            depth = d2;
            root = cand;
            last = l2;
        } else {
            return root;
        }
    }
}

/// Reverse Cuthill–McKee permutation. Returns `p` such that reordering with
/// `a.permute_sym(p.indices())` concentrates the pattern near the diagonal.
pub fn rcm(g: &Graph) -> Permutation {
    let mut order: Vec<usize> = Vec::with_capacity(g.n);
    let mut visited = vec![false; g.n];

    // process components by ascending min-degree seed for determinism
    let mut comps = g.components();
    comps.sort_by_key(|c| c[0]);
    for comp in comps {
        let seed = *comp
            .iter()
            .min_by_key(|&&v| (g.degree(v as usize), v))
            .unwrap();
        let start = if comp.len() > 2 {
            pseudo_peripheral(g, seed)
        } else {
            seed
        };
        // Cuthill–McKee BFS with degree-sorted neighbor visits
        let mut q = VecDeque::new();
        q.push_back(start);
        visited[start as usize] = true;
        while let Some(v) = q.pop_front() {
            order.push(v as usize);
            let mut nbrs: Vec<u32> = g.adj[v as usize]
                .iter()
                .copied()
                .filter(|&w| !visited[w as usize])
                .collect();
            nbrs.sort_by_key(|&w| (g.degree(w as usize), w));
            for w in nbrs {
                visited[w as usize] = true;
                q.push_back(w);
            }
        }
    }
    order.reverse(); // the "R" in RCM
    Permutation::from_vec(order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::sparse::bandwidth::bandwidth;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn banded_shuffled(n: usize, half_band: usize, seed: u64) -> (Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let band = Matrix::from_fn(n, n, |i, j| {
            if i.abs_diff(j) <= half_band {
                rng.gaussian_f32() + 1.0
            } else {
                0.0
            }
        });
        let mut p: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut p);
        let shuffled = band.permute_sym(&p);
        (band, shuffled)
    }

    #[test]
    fn recovers_banded_structure() {
        let (_band, shuffled) = banded_shuffled(64, 3, 1);
        let g = Graph::from_pattern(&shuffled, 0.0);
        let p = rcm(&g);
        let reordered = shuffled.permute_sym(p.indices());
        assert!(
            bandwidth(&reordered, 1e-9) < bandwidth(&shuffled, 1e-9),
            "rcm {} vs shuffled {}",
            bandwidth(&reordered, 1e-9),
            bandwidth(&shuffled, 1e-9)
        );
    }

    #[test]
    fn near_optimal_on_path() {
        // a shuffled path graph should come back to bandwidth 1
        let n = 32;
        let (_b, shuffled) = banded_shuffled(n, 1, 2);
        let g = Graph::from_pattern(&shuffled, 0.0);
        let p = rcm(&g);
        let reordered = shuffled.permute_sym(p.indices());
        assert!(bandwidth(&reordered, 1e-9) <= 2);
    }

    #[test]
    fn is_valid_permutation_property() {
        check(15, |rng| {
            let n = 2 + rng.below(50);
            let mut m = Matrix::zeros(n, n);
            for _ in 0..(2 * n) {
                let i = rng.below(n);
                let j = rng.below(n);
                m.set(i, j, rng.gaussian_f32());
            }
            let g = Graph::from_pattern(&m, 0.0);
            let p = rcm(&g);
            if p.len() == n {
                Ok(())
            } else {
                Err(format!("perm length {} != {n}", p.len()))
            }
        });
    }

    #[test]
    fn never_increases_bandwidth_much_on_random() {
        // RCM on already-banded input must keep it banded
        let n = 48;
        let band = Matrix::from_fn(n, n, |i, j| {
            if i.abs_diff(j) <= 2 {
                1.0
            } else {
                0.0
            }
        });
        let g = Graph::from_pattern(&band, 0.0);
        let p = rcm(&g);
        let reordered = band.permute_sym(p.indices());
        assert!(bandwidth(&reordered, 1e-9) <= 4);
    }

    #[test]
    fn handles_empty_graph() {
        let m = Matrix::zeros(8, 8);
        let g = Graph::from_pattern(&m, 0.0);
        let p = rcm(&g);
        assert_eq!(p.len(), 8);
    }

    #[test]
    fn deterministic() {
        let (_b, shuffled) = banded_shuffled(32, 2, 3);
        let g = Graph::from_pattern(&shuffled, 0.0);
        assert_eq!(rcm(&g).indices(), rcm(&g).indices());
    }
}
