//! Compressed-sparse-row matrix — the runtime format for the spike matrix S
//! (row-contiguous spmv on the native hot path).
//!
//! Values are dtype-generic ([`WeightBuf`]): a spike matrix loaded from
//! the `HSB1` store can stay f16-resident, and `matvec_add`/`spmm_add`
//! widen each stored value in-register as it streams (one widen per nnz,
//! amortized over the k lanes of a batch). Indices are untouched — only
//! the resident value bytes narrow.

use crate::linalg::weightbuf::{Dtype, WeightBuf, WeightElem};
use crate::linalg::Matrix;
use crate::sparse::Coo;

#[derive(Clone, Debug)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub data: WeightBuf,
}

impl Csr {
    pub fn from_coo(coo: &Coo) -> Csr {
        let mut counts = vec![0u32; coo.rows + 1];
        for &r in &coo.ri {
            counts[r as usize + 1] += 1;
        }
        for i in 0..coo.rows {
            counts[i + 1] += counts[i];
        }
        let indptr = counts.clone();
        let mut cursor = counts;
        let nnz = coo.nnz();
        let mut indices = vec![0u32; nnz];
        let mut data = vec![0.0f32; nnz];
        for k in 0..nnz {
            let r = coo.ri[k] as usize;
            let pos = cursor[r] as usize;
            indices[pos] = coo.ci[k];
            data[pos] = coo.v[k];
            cursor[r] += 1;
        }
        Csr {
            rows: coo.rows,
            cols: coo.cols,
            indptr,
            indices,
            data: WeightBuf::F32(data.into()),
        }
    }

    /// Value dtype of the resident storage.
    pub fn dtype(&self) -> Dtype {
        self.data.dtype()
    }

    /// Bytes resident for the stored values (indices excluded).
    pub fn resident_value_bytes(&self) -> usize {
        self.data.resident_bytes()
    }

    /// Narrow the stored values to f16 in place (a no-op when already f16).
    pub fn narrow_to_f16(&mut self) {
        if self.data.dtype() != Dtype::F16 {
            self.data = self.data.to_f16();
        }
    }

    /// Widen the stored values to f32 in place (exact; a no-op when
    /// already f32).
    pub fn widen_to_f32(&mut self) {
        if self.data.dtype() != Dtype::F32 {
            self.data = self.data.to_f32();
        }
    }

    pub fn from_dense(m: &Matrix, threshold: f32) -> Csr {
        let mut coo = Coo::new(m.rows, m.cols);
        for i in 0..m.rows {
            for j in 0..m.cols {
                let v = m.at(i, j);
                if v.abs() > threshold {
                    coo.push(i, j, v);
                }
            }
        }
        Csr::from_coo(&coo)
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Structural validation — used when a CSR comes from untrusted bytes
    /// (the `HSB1` store) so corrupt indices surface as errors, not panics
    /// or out-of-bounds reads in the matvec hot path.
    pub fn validate(&self) -> Result<(), String> {
        if self.indptr.len() != self.rows + 1 {
            return Err(format!(
                "csr: indptr len {} != rows+1 {}",
                self.indptr.len(),
                self.rows + 1
            ));
        }
        if self.indptr[0] != 0 {
            return Err(format!("csr: indptr[0] = {} (want 0)", self.indptr[0]));
        }
        if self.indices.len() != self.data.len() {
            return Err(format!(
                "csr: {} indices vs {} values",
                self.indices.len(),
                self.data.len()
            ));
        }
        if *self.indptr.last().unwrap() as usize != self.data.len() {
            return Err(format!(
                "csr: indptr end {} != nnz {}",
                self.indptr.last().unwrap(),
                self.data.len()
            ));
        }
        if let Some(w) = self.indptr.windows(2).find(|w| w[0] > w[1]) {
            return Err(format!("csr: indptr not monotone at {} > {}", w[0], w[1]));
        }
        if let Some(&j) = self.indices.iter().find(|&&j| j as usize >= self.cols) {
            return Err(format!("csr: column index {j} >= cols {}", self.cols));
        }
        Ok(())
    }

    /// y += S x. Row loop with 4 independent accumulators — the gather
    /// x[indices[k]] is the bound; unrolling hides its latency
    /// (EXPERIMENTS.md §Perf).
    pub fn matvec_add(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        match &self.data {
            WeightBuf::F32(v) => spmv_add_w(&self.indptr, &self.indices, v.as_slice(), x, y),
            WeightBuf::F16(v) => spmv_add_w(&self.indptr, &self.indices, v.as_slice(), x, y),
        }
    }

    /// Y += S @ X for a row-major column block X [cols, k] → Y [rows, k]
    /// — the SpMM the batched apply engine runs. Each stored value becomes
    /// one contiguous k-wide axpy (the gather jumps rows of X, but every
    /// gathered row is k consecutive floats); the column loop is blocked
    /// so a wide batch never thrashes the X working set. f16-resident
    /// values widen once per nnz per column block.
    pub fn spmm_add(&self, x: &[f32], y: &mut [f32], k: usize) {
        // one span per public entry; `spmm_add_staged` has its own, and
        // both route here through the span-free inner body so a staged
        // call never double-counts the stage
        let _span = crate::obs::Span::enter(crate::obs::Stage::Spmm);
        crate::obs::count_flops(
            2 * self.nnz() as u64 * k as u64,
            self.resident_value_bytes() as u64,
        );
        self.spmm_add_inner(x, y, k);
    }

    fn spmm_add_inner(&self, x: &[f32], y: &mut [f32], k: usize) {
        assert_eq!(x.len(), self.cols * k, "input block shape mismatch");
        assert_eq!(y.len(), self.rows * k, "output block shape mismatch");
        if k == 1 {
            return self.matvec_add(x, y);
        }
        match &self.data {
            WeightBuf::F32(v) => spmm_add_w(&self.indptr, &self.indices, v.as_slice(), x, y, k),
            WeightBuf::F16(v) => spmm_add_w(&self.indptr, &self.indices, v.as_slice(), x, y, k),
        }
    }

    /// [`Csr::spmm_add`] with an f16 staging buffer: an f16-resident value
    /// run is pre-widened wholesale into `stage` once per call (exact), so
    /// the gather/axpy loop streams plain f32 values instead of converting
    /// per stored value per column block; f32-resident values skip the
    /// stage. Bit-identical to the unstaged call for either dtype.
    pub fn spmm_add_staged(&self, x: &[f32], y: &mut [f32], k: usize, stage: &mut Vec<f32>) {
        let _span = crate::obs::Span::enter(crate::obs::Stage::Spmm);
        crate::obs::count_flops(
            2 * self.nnz() as u64 * k as u64,
            self.resident_value_bytes() as u64,
        );
        match &self.data {
            WeightBuf::F32(_) => self.spmm_add_inner(x, y, k),
            WeightBuf::F16(v) => {
                assert_eq!(x.len(), self.cols * k, "input block shape mismatch");
                assert_eq!(y.len(), self.rows * k, "output block shape mismatch");
                let s = crate::linalg::weightbuf::widen_f16_into(v, stage);
                if k == 1 {
                    spmv_add_w(&self.indptr, &self.indices, s, x, y);
                } else {
                    spmm_add_w(&self.indptr, &self.indices, s, x, y, k);
                }
            }
        }
    }

    /// Value gradients with a frozen sparsity pattern, batched: for the
    /// loss L = ½‖Y − T‖² with Y = S X + …, the gradient of the stored
    /// value at (row i, column j) is Σ_c G[i,c]·X[j,c] — a k-wide dot over
    /// the row-major column blocks X [cols, k] and G [rows, k]. Accumulates
    /// into `out` (one slot per stored value, CSR order); k = 1 is the
    /// per-sample gradient g[i]·x[j].
    pub fn value_grads_add(&self, x: &[f32], g: &[f32], k: usize, out: &mut [f32]) {
        assert_eq!(x.len(), self.cols * k, "input block shape mismatch");
        assert_eq!(g.len(), self.rows * k, "gradient block shape mismatch");
        assert_eq!(out.len(), self.nnz());
        for i in 0..self.rows {
            let grow = &g[i * k..(i + 1) * k];
            if k == 1 && grow[0] == 0.0 {
                continue;
            }
            for kk in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                let j = self.indices[kk] as usize;
                out[kk] += crate::linalg::matrix::dot(grow, &x[j * k..(j + 1) * k], k);
            }
        }
    }

    /// y = S x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.rows];
        self.matvec_add(x, &mut y);
        y
    }

    pub fn to_dense(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for k in self.indptr[i] as usize..self.indptr[i + 1] as usize {
                // duplicates accumulate, matching Coo::to_dense semantics
                m.data[i * self.cols + self.indices[k] as usize] += self.data.at(k);
            }
        }
        m
    }
}

/// y += S x over raw CSR slices, generic over the value dtype.
///
/// Deliberately scalar (not routed through `linalg::simd`): the k = 1
/// spmv is gather-bound — each product needs `x[idx[..]]` loaded through
/// an index indirection, so there is no contiguous lane axis to
/// vectorize. The batched `spmm_add_w` is where the SIMD layer pays off
/// (the k columns are contiguous per stored value).
fn spmv_add_w<E: WeightElem>(indptr: &[u32], indices: &[u32], val: &[E], x: &[f32], y: &mut [f32]) {
    for (i, yi) in y.iter_mut().enumerate() {
        let lo = indptr[i] as usize;
        let hi = indptr[i + 1] as usize;
        let idx = &indices[lo..hi];
        let val = &val[lo..hi];
        let n = idx.len();
        let mut acc = [0.0f32; 4];
        let chunks = n / 4;
        for c in 0..chunks {
            let b = c * 4;
            for l in 0..4 {
                acc[l] += val[b + l].widen() * x[idx[b + l] as usize];
            }
        }
        let mut total = acc[0] + acc[1] + acc[2] + acc[3];
        for k in chunks * 4..n {
            total += val[k].widen() * x[idx[k] as usize];
        }
        *yi += total;
    }
}

/// Y += S X over raw CSR slices and a [cols, k] column block, generic
/// over the value dtype.
fn spmm_add_w<E: WeightElem>(
    indptr: &[u32],
    indices: &[u32],
    vals: &[E],
    x: &[f32],
    y: &mut [f32],
    k: usize,
) {
    let rows = indptr.len() - 1;
    let kt = crate::linalg::simd::kernels();
    const CB: usize = 128; // column block (floats per lane pass)
    for cb in (0..k).step_by(CB) {
        let cw = CB.min(k - cb);
        for i in 0..rows {
            let lo = indptr[i] as usize;
            let hi = indptr[i + 1] as usize;
            if lo == hi {
                continue;
            }
            let yrow = &mut y[i * k + cb..i * k + cb + cw];
            for (j, v) in indices[lo..hi].iter().zip(&vals[lo..hi]) {
                // one dispatched axpy per stored value: the k-lane axis
                // is contiguous, so SpMM is the same thin kernel as the
                // dense apply (values widen one scalar at a time — the
                // gather pattern leaves nothing to lane-batch here)
                let xrow = &x[*j as usize * k + cb..*j as usize * k + cb + cw];
                (kt.axpy_k)(v.widen(), xrow, yrow);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, slices_close};
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, n: usize, nnz: usize) -> Coo {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.below(n), rng.below(n), rng.gaussian_f32());
        }
        coo
    }

    #[test]
    fn from_coo_roundtrip_dense() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 10, 30);
        let csr = Csr::from_coo(&coo);
        assert_eq!(csr.to_dense().data, coo.to_dense().data);
    }

    #[test]
    fn spmv_matches_dense_property() {
        check(20, |rng| {
            let n = 2 + rng.below(40);
            let nnz = rng.below(3 * n + 1);
            let coo = random_coo(rng, n, nnz);
            let csr = Csr::from_coo(&coo);
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let expect = csr.to_dense().matvec(&x);
            let got = csr.matvec(&x);
            slices_close(&got, &expect, 1e-5, 1e-5, "spmv")
        });
    }

    #[test]
    fn from_dense_thresholds() {
        let mut m = Matrix::zeros(3, 3);
        m.set(0, 0, 5.0);
        m.set(1, 2, 0.001);
        let csr = Csr::from_dense(&m, 0.01);
        assert_eq!(csr.nnz(), 1);
        assert_eq!(csr.to_dense().at(0, 0), 5.0);
    }

    #[test]
    fn validate_accepts_built_and_rejects_corrupt() {
        let mut rng = Rng::new(7);
        let csr = Csr::from_coo(&random_coo(&mut rng, 12, 40));
        assert_eq!(csr.validate(), Ok(()));

        let mut bad = csr.clone();
        bad.indices[0] = 99; // column out of range
        assert!(bad.validate().is_err());

        let mut bad = csr.clone();
        bad.indptr[3] = bad.indptr[4] + 1; // non-monotone
        assert!(bad.validate().is_err());

        let mut bad = csr.clone();
        let mut vals = bad.data.to_vec();
        vals.pop(); // nnz mismatch
        bad.data = crate::linalg::WeightBuf::F32(vals.into());
        assert!(bad.validate().is_err());
    }

    #[test]
    fn value_grads_match_dense_outer_product() {
        // dense reference: dL/dS = g xᵀ restricted to the stored pattern
        check(10, |rng| {
            let n = 3 + rng.below(12);
            let coo = random_coo(rng, n, 2 * n);
            let csr = Csr::from_coo(&coo);
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let g: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            let mut got = vec![0.0f32; csr.nnz()];
            csr.value_grads_add(&x, &g, 1, &mut got);
            for i in 0..csr.rows {
                for k in csr.indptr[i] as usize..csr.indptr[i + 1] as usize {
                    let want = g[i] * x[csr.indices[k] as usize];
                    if (got[k] - want).abs() > 1e-5 {
                        return Err(format!("grad[{k}]: {} != {want}", got[k]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn spmm_matches_per_column_spmv() {
        check(15, |rng| {
            let n = 2 + rng.below(30);
            let k = 1 + rng.below(9);
            let csr = Csr::from_coo(&random_coo(rng, n, 3 * n));
            let cols: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.gaussian_f32()).collect())
                .collect();
            let mut x = vec![0.0f32; n * k];
            for (c, col) in cols.iter().enumerate() {
                for (j, &v) in col.iter().enumerate() {
                    x[j * k + c] = v;
                }
            }
            let mut y = vec![0.0f32; n * k];
            csr.spmm_add(&x, &mut y, k);
            for (c, col) in cols.iter().enumerate() {
                let expect = csr.matvec(col);
                let got: Vec<f32> = (0..n).map(|i| y[i * k + c]).collect();
                slices_close(&got, &expect, 1e-5, 1e-5, "spmm col")?;
            }
            Ok(())
        });
    }

    #[test]
    fn batched_value_grads_match_per_sample_sum() {
        check(10, |rng| {
            let n = 3 + rng.below(12);
            let k = 2 + rng.below(5);
            let csr = Csr::from_coo(&random_coo(rng, n, 2 * n));
            let xs: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.gaussian_f32()).collect())
                .collect();
            let gs: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..n).map(|_| rng.gaussian_f32()).collect())
                .collect();
            let mut xb = vec![0.0f32; n * k];
            let mut gb = vec![0.0f32; n * k];
            for c in 0..k {
                for j in 0..n {
                    xb[j * k + c] = xs[c][j];
                    gb[j * k + c] = gs[c][j];
                }
            }
            let mut batched = vec![0.0f32; csr.nnz()];
            csr.value_grads_add(&xb, &gb, k, &mut batched);
            let mut summed = vec![0.0f32; csr.nnz()];
            for c in 0..k {
                csr.value_grads_add(&xs[c], &gs[c], 1, &mut summed);
            }
            slices_close(&batched, &summed, 1e-4, 1e-4, "value grads")
        });
    }

    #[test]
    fn f16_spmm_bit_matches_quantized_f32() {
        // narrowed values must give bit-identical results to quantizing
        // the values in f32 — the kernel only widens, never reorders
        check(10, |rng| {
            let n = 2 + rng.below(24);
            let k = 1 + rng.below(8);
            let csr = Csr::from_coo(&random_coo(rng, n, 3 * n));
            let mut q = csr.clone();
            {
                let vals = q.data.as_f32_mut();
                crate::util::fp16::quantize_f16(vals);
            }
            let mut h = csr.clone();
            h.narrow_to_f16();
            assert_eq!(h.dtype(), crate::linalg::Dtype::F16);
            assert_eq!(h.resident_value_bytes() * 2, csr.resident_value_bytes());
            h.validate().map_err(|e| format!("f16 csr invalid: {e}"))?;
            let x: Vec<f32> = (0..n * k).map(|_| rng.gaussian_f32()).collect();
            let mut yq = vec![0.0f32; n * k];
            let mut yh = vec![0.0f32; n * k];
            q.spmm_add(&x, &mut yq, k);
            h.spmm_add(&x, &mut yh, k);
            if yq != yh {
                return Err("f16 spmm != quantized f32 spmm".into());
            }
            Ok(())
        });
    }

    #[test]
    fn staged_spmm_bit_matches_unstaged() {
        check(10, |rng| {
            let n = 2 + rng.below(24);
            let k = 1 + rng.below(8);
            let mut h = Csr::from_coo(&random_coo(rng, n, 3 * n));
            h.narrow_to_f16();
            let x: Vec<f32> = (0..n * k).map(|_| rng.gaussian_f32()).collect();
            let mut y1 = vec![0.5f32; n * k];
            let mut y2 = vec![0.5f32; n * k];
            let mut stage = vec![9.0f32; 1]; // undersized and stale
            h.spmm_add(&x, &mut y1, k);
            h.spmm_add_staged(&x, &mut y2, k, &mut stage);
            if y1 != y2 {
                return Err("staged spmm != unstaged (bitwise)".into());
            }
            Ok(())
        });
    }

    #[test]
    fn empty_rows_handled() {
        let mut coo = Coo::new(5, 5);
        coo.push(4, 4, 1.0);
        let csr = Csr::from_coo(&coo);
        let y = csr.matvec(&[1.0; 5]);
        assert_eq!(y, vec![0.0, 0.0, 0.0, 0.0, 1.0]);
    }
}
