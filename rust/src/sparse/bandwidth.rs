//! Bandwidth/profile metrics — quantifies what RCM buys (§5.4 of the paper:
//! "RCM brings the max entries closer to the diagonal").

use crate::linalg::Matrix;

/// Max |i − j| over entries with |value| > threshold.
pub fn bandwidth(m: &Matrix, threshold: f32) -> usize {
    let mut bw = 0usize;
    for i in 0..m.rows {
        for j in 0..m.cols {
            if m.at(i, j).abs() > threshold {
                bw = bw.max(i.abs_diff(j));
            }
        }
    }
    bw
}

/// Envelope/profile: sum over rows of (i − min column index with a nonzero).
pub fn profile(m: &Matrix, threshold: f32) -> usize {
    let mut total = 0usize;
    for i in 0..m.rows {
        let mut min_j = None;
        for j in 0..m.cols {
            if m.at(i, j).abs() > threshold {
                min_j = Some(j);
                break;
            }
        }
        if let Some(j) = min_j {
            total += i.saturating_sub(j);
        }
    }
    total
}

/// Fraction of magnitude mass within |i − j| <= band (diagonal concentration).
pub fn mass_within_band(m: &Matrix, band: usize) -> f64 {
    let mut inside = 0.0f64;
    let mut total = 0.0f64;
    for i in 0..m.rows {
        for j in 0..m.cols {
            let v = m.at(i, j).abs() as f64;
            total += v * v;
            if i.abs_diff(j) <= band {
                inside += v * v;
            }
        }
    }
    if total == 0.0 {
        0.0
    } else {
        inside / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_has_zero_bandwidth() {
        let m = Matrix::identity(5);
        assert_eq!(bandwidth(&m, 0.0), 0);
    }

    #[test]
    fn corner_entry_max_bandwidth() {
        let mut m = Matrix::zeros(6, 6);
        m.set(0, 5, 1.0);
        assert_eq!(bandwidth(&m, 0.0), 5);
    }

    #[test]
    fn profile_of_lower_triangle() {
        let m = Matrix::from_fn(4, 4, |i, j| if j <= i { 1.0 } else { 0.0 });
        // each row's first nonzero is column 0 => profile = 0+1+2+3
        assert_eq!(profile(&m, 0.0), 6);
    }

    #[test]
    fn mass_within_band_bounds() {
        let m = Matrix::randn(10, 10, 1);
        let f0 = mass_within_band(&m, 0);
        let f9 = mass_within_band(&m, 9);
        assert!(f0 >= 0.0 && f0 <= f9);
        assert!((f9 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn zero_matrix_mass() {
        let m = Matrix::zeros(4, 4);
        assert_eq!(mass_within_band(&m, 2), 0.0);
    }
}
