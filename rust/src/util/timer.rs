//! Bench timing harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated measurement with trimmed statistics, and an
//! aligned table printer used by every `benches/` target so each bench emits
//! the rows/series of the paper table it regenerates.

use std::time::{Duration, Instant};

/// Summary statistics over repeated timed runs.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub stddev_ns: f64,
}

/// Percentile of an ascending-sorted sample slice (nearest-rank) —
/// [`Stats`]' percentile rule, exported for any exact-sample consumer.
/// The observability histograms (`crate::obs`) intentionally do *not*
/// use this: they are lock-free log-bucketed counters with no retained
/// samples, so they report upper bucket bounds instead (see
/// `obs::histogram::percentile_from_counts`).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    sorted[((p * n as f64) as usize).min(n - 1)]
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| percentile_sorted(&ns, p);
        Stats {
            iters: n,
            mean_ns: mean,
            p50_ns: pct(0.50),
            p95_ns: pct(0.95),
            p99_ns: pct(0.99),
            min_ns: ns[0],
            stddev_ns: var.sqrt(),
        }
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Human-readable duration.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Time `f` with warmup; runs until `budget` is used or `max_iters` reached.
pub fn bench<F: FnMut()>(mut f: F, warmup: usize, budget: Duration, max_iters: usize) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget && samples.len() < max_iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    if samples.is_empty() {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    Stats::from_samples(samples)
}

/// Quick bench with sane defaults (3 warmup, 2s budget, 10k iters).
pub fn quick_bench<F: FnMut()>(f: F) -> Stats {
    bench(f, 3, Duration::from_secs(2), 10_000)
}

/// Aligned plain-text table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        print!("{self}");
    }
}

// Display rather than an inherent `to_string` (clippy: inherent_to_string)
// so the table composes with `format!`/`write!` and still gets
// `ToString::to_string` for free.
impl std::fmt::Display for Table {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String], f: &mut std::fmt::Formatter<'_>| -> std::fmt::Result {
            for (i, c) in cells.iter().enumerate() {
                write!(f, "{:<w$}  ", c, w = widths[i])?;
            }
            writeln!(f)
        };
        line(&self.headers, f)?;
        let total: usize = widths.iter().sum::<usize>() + widths.len() * 2;
        writeln!(f, "{}", "-".repeat(total))?;
        for row in &self.rows {
            line(row, f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles_ordering() {
        let s = Stats::from_samples((1..=100).map(|x| x as f64).collect());
        assert!(s.min_ns <= s.p50_ns);
        assert!(s.p50_ns <= s.p95_ns);
        assert!(s.p95_ns <= s.p99_ns);
        assert_eq!(s.iters, 100);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
    }

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let s = bench(
            || {
                for i in 0..1000 {
                    acc = acc.wrapping_add(i);
                }
            },
            1,
            Duration::from_millis(50),
            1000,
        );
        assert!(s.iters >= 1);
        assert!(s.mean_ns > 0.0);
        std::hint::black_box(acc);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "ppl"]);
        t.row(&["sHSS-RCM".into(), "1.64".into()]);
        t.row(&["dense".into(), "1.7".into()]);
        let s = t.to_string();
        assert!(s.contains("sHSS-RCM"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    fn percentile_sorted_nearest_rank() {
        let v: Vec<f64> = (1..=10).map(|x| x as f64).collect();
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 0.50), 6.0);
        assert_eq!(percentile_sorted(&v, 0.99), 10.0);
        assert_eq!(percentile_sorted(&[7.0], 0.999), 7.0);
    }

    #[test]
    fn table_displays_via_format() {
        let mut t = Table::new(&["a"]);
        t.row(&["x".into()]);
        assert!(format!("{t}").contains('x'));
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert!(fmt_ns(1.5e3).ends_with("us"));
        assert!(fmt_ns(2.5e6).ends_with("ms"));
        assert!(fmt_ns(3.0e9).ends_with('s'));
    }
}
