//! Shared substrates: PRNG, fp16 codec, JSON, CLI parsing, bench timing,
//! logging, and a tiny property-test driver.
//!
//! These exist as first-class modules because the build environment is fully
//! offline: the usual crates (rand, serde, clap, criterion, proptest) are not
//! available, and each substrate here is exercised by the rest of the stack.

pub mod binio;
pub mod cli;
pub mod fp16;
pub mod json;
pub mod logging;
pub mod mmap;
pub mod proptest;
pub mod rng;
pub mod timer;
