//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals; typed
//! getters with defaults; and usage generation. Used by the `hisolo` binary,
//! the examples, and every bench target.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — flags must be declared so
    /// `--flag value` vs `--key value` is unambiguous.
    pub fn parse_from<I: IntoIterator<Item = String>>(iter: I, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        out.flags.push(rest.to_string());
                    } else {
                        out.opts.insert(rest.to_string(), it.next().unwrap());
                    }
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn parse(flag_names: &[&str]) -> Args {
        Args::parse_from(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_path(&self, name: &str) -> Option<std::path::PathBuf> {
        self.get(name).map(std::path::PathBuf::from)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse_from(args.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn key_value_styles() {
        let a = parse(&["--rank", "32", "--sparsity=0.3"], &[]);
        assert_eq!(a.get_usize("rank", 0), 32);
        assert!((a.get_f64("sparsity", 0.0) - 0.3).abs() < 1e-12);
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse(&["compress", "--no-rcm", "--rank", "8", "w.hwt"], &["no-rcm"]);
        assert_eq!(a.subcommand(), Some("compress"));
        assert!(a.flag("no-rcm"));
        assert_eq!(a.positional()[1], "w.hwt");
        assert_eq!(a.get_usize("rank", 0), 8);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--verbose"], &[]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--fast", "--rank", "4"], &[]);
        assert!(a.flag("fast"));
        assert_eq!(a.get_usize("rank", 0), 4);
    }

    #[test]
    fn get_path_optional() {
        let a = parse(&["--from-store", "store/dir"], &[]);
        assert_eq!(
            a.get_path("from-store"),
            Some(std::path::PathBuf::from("store/dir"))
        );
        assert_eq!(a.get_path("absent"), None);
    }

    #[test]
    fn defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.get_usize("rank", 32), 32);
        assert_eq!(a.get_str("method", "shss-rcm"), "shss-rcm");
        assert!(!a.flag("x"));
    }
}
