//! Read-only memory-mapped files for the zero-copy store path.
//!
//! The build environment is fully offline (no `libc`/`memmap2` crates), so
//! this module declares the two syscall wrappers it needs — `mmap` and
//! `munmap` — directly against the C runtime std already links on unix.
//! The surface is deliberately tiny: [`Mmap::map`] maps a whole file
//! `PROT_READ`/`MAP_SHARED` and derefs to `[u8]`; dropping unmaps.
//!
//! Why `MAP_SHARED` for a read-only mapping: N serving processes that map
//! the same variant share one page-cache copy of the factor bytes, which is
//! the multi-process memory win the `HSB2` sharded store exists for
//! (`benches/store_load.rs --procs` measures it).
//!
//! Rollout safety mirrors the SIMD layer's `HISOLO_SIMD` kill-switch:
//! `HISOLO_MMAP=off|0|buffered` pins every reader to the buffered
//! (read-into-heap) path, and any mmap *failure* — unsupported platform,
//! filesystem that refuses mapping, empty file — degrades to buffered with
//! a single warning instead of failing the load ([`map_or_warn`]).

use std::fs::File;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// A read-only mapping of an entire file. `Send + Sync`: the mapping is
/// immutable for its lifetime and unmapped exactly once on drop.
pub struct Mmap {
    ptr: std::ptr::NonNull<u8>,
    len: usize,
}

// SAFETY: the mapping is PROT_READ and never handed out mutably; munmap
// happens once in Drop. Sharing &Mmap across threads is reading immutable
// memory.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

#[cfg(unix)]
mod sys {
    // std links the platform C runtime on unix targets, so declaring the
    // two symbols we need is dependency-free.
    extern "C" {
        pub fn mmap(
            addr: *mut core::ffi::c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut core::ffi::c_void;
        pub fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    }

    pub const PROT_READ: i32 = 1;
    pub const MAP_SHARED: i32 = 1;
    pub const MAP_FAILED: *mut core::ffi::c_void = usize::MAX as *mut core::ffi::c_void;
}

impl Mmap {
    /// Map `path` read-only in its entirety. Errors (rather than panics)
    /// on unsupported platforms, zero-length files, and syscall failure —
    /// callers fall back to the buffered reader.
    pub fn map(path: &Path) -> std::io::Result<Mmap> {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            let file = File::open(path)?;
            let len = file.metadata()?.len();
            if len == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidInput,
                    "mmap of empty file",
                ));
            }
            let len = usize::try_from(len).map_err(|_| {
                std::io::Error::new(std::io::ErrorKind::InvalidInput, "file too large to map")
            })?;
            // SAFETY: len > 0, fd is a freshly opened readable file; a
            // MAP_FAILED return is checked below. The fd may be closed
            // after mmap returns — the mapping keeps its own reference.
            let ptr = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    sys::PROT_READ,
                    sys::MAP_SHARED,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr == sys::MAP_FAILED || ptr.is_null() {
                return Err(std::io::Error::last_os_error());
            }
            Ok(Mmap {
                ptr: std::ptr::NonNull::new(ptr as *mut u8).unwrap(),
                len,
            })
        }
        #[cfg(not(unix))]
        {
            let _ = path;
            Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "mmap unsupported on this platform",
            ))
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        // SAFETY: ptr/len describe a live PROT_READ mapping until Drop.
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        // SAFETY: exactly the (addr, len) pair a successful mmap returned.
        unsafe {
            sys::munmap(self.ptr.as_ptr() as *mut core::ffi::c_void, self.len);
        }
    }
}

impl std::fmt::Debug for Mmap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mmap[{} bytes]", self.len)
    }
}

/// Whether the store readers should attempt to mmap at all, honouring the
/// `HISOLO_MMAP` kill-switch (`off`/`0`/`buffered` pins the buffered
/// reader; anything else — including unset — is `auto`). Read once.
pub fn mmap_enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| {
        if let Ok(v) = std::env::var("HISOLO_MMAP") {
            let v = v.to_ascii_lowercase();
            if v == "off" || v == "0" || v == "buffered" {
                return false;
            }
        }
        cfg!(unix)
    })
}

static MMAP_FALLBACK_WARNED: AtomicBool = AtomicBool::new(false);

/// Try to map `path`, honouring the kill-switch; on any failure warn once
/// per process and return `None` so the caller serves from the buffered
/// reader instead. A degraded load path is a log line, never an outage.
pub fn map_or_warn(path: &Path) -> Option<std::sync::Arc<Mmap>> {
    if !mmap_enabled() {
        return None;
    }
    match Mmap::map(path) {
        Ok(m) => Some(std::sync::Arc::new(m)),
        Err(e) => {
            if !MMAP_FALLBACK_WARNED.swap(true, Ordering::Relaxed) {
                crate::log_warn!(
                    "mmap of {} failed ({e}); falling back to buffered store reads \
                     (further fallbacks silent)",
                    path.display()
                );
            }
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("hisolo-mmap-{}-{name}", std::process::id()));
        let mut f = File::create(&p).unwrap();
        f.write_all(bytes).unwrap();
        p
    }

    #[test]
    #[cfg(unix)]
    fn maps_file_contents_exactly() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let p = tmp("contents", &data);
        let m = Mmap::map(&p).unwrap();
        assert_eq!(m.len(), data.len());
        assert_eq!(&m[..], &data[..]);
        drop(m);
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    #[cfg(unix)]
    fn empty_file_refuses_cleanly() {
        let p = tmp("empty", b"");
        assert!(Mmap::map(&p).is_err());
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn missing_file_is_an_error_not_a_panic() {
        assert!(Mmap::map(Path::new("/nonexistent/hisolo-mmap-test")).is_err());
    }

    #[test]
    #[cfg(unix)]
    fn mapping_is_shareable_across_threads() {
        let data = vec![7u8; 4096];
        let p = tmp("threads", &data);
        let m = std::sync::Arc::new(Mmap::map(&p).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                std::thread::spawn(move || m.iter().map(|&b| b as u64).sum::<u64>())
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 7 * 4096);
        }
        std::fs::remove_file(&p).unwrap();
    }
}
