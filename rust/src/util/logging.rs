//! Minimal leveled logger writing to stderr, controlled by `HISOLO_LOG`
//! (error|warn|info|debug; default info). Kept allocation-free on the
//! disabled path so hot loops can carry debug logging.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

fn init_level() -> u8 {
    let lvl = match std::env::var("HISOLO_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

#[inline]
pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_level();
    }
    (level as u8) <= cur
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Info, format_args!("hello {}", 42));
        crate::log_debug!("debug {}", 1);
    }
}
