//! Minimal leveled logger writing to stderr, controlled by `HISOLO_LOG`
//! (off|error|warn|info|debug; default info). `off` silences every level
//! — benches and tests set it so the coordinator's metrics reporter
//! thread stays quiet in captured output. Unrecognized values warn once
//! to stderr and fall back to `info` instead of being silently eaten.
//! Kept allocation-free on the disabled path so hot loops can carry
//! debug logging.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

// Stored encoding: 0 = off, 1..=4 = Level + 1, UNINIT = not yet read
// from the environment. `off` must sort below Error, hence the shift.
const OFF: u8 = 0;
const UNINIT: u8 = 255;

static LEVEL: AtomicU8 = AtomicU8::new(UNINIT);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
static BAD_VALUE_WARNING: std::sync::Once = std::sync::Once::new();

fn encode(level: Option<Level>) -> u8 {
    match level {
        None => OFF,
        Some(l) => l as u8 + 1,
    }
}

/// Parse an `HISOLO_LOG` value. Outer `None` = unrecognized; inner
/// `None` = logging off.
pub fn parse_level(value: &str) -> Option<Option<Level>> {
    match value {
        "off" | "none" | "0" => Some(None),
        "error" => Some(Some(Level::Error)),
        "warn" | "warning" => Some(Some(Level::Warn)),
        "info" => Some(Some(Level::Info)),
        "debug" => Some(Some(Level::Debug)),
        _ => None,
    }
}

/// Override the level programmatically (`None` = off). Tests and benches
/// use this to silence the reporter without touching the environment.
pub fn set_level(level: Option<Level>) {
    LEVEL.store(encode(level), Ordering::Relaxed);
}

fn init_level() -> u8 {
    let enc = match std::env::var("HISOLO_LOG") {
        Err(_) => encode(Some(Level::Info)),
        Ok(v) => match parse_level(&v) {
            Some(l) => encode(l),
            None => {
                // direct eprintln: the logger itself is what's misconfigured
                BAD_VALUE_WARNING.call_once(|| {
                    eprintln!(
                        "[logging] unrecognized HISOLO_LOG={v:?} \
                         (expected off|error|warn|info|debug); using info"
                    );
                });
                encode(Some(Level::Info))
            }
        },
    };
    LEVEL.store(enc, Ordering::Relaxed);
    enc
}

#[inline]
pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == UNINIT {
        cur = init_level();
    }
    encode(Some(level)) <= cur
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn parse_accepts_all_spellings() {
        assert_eq!(parse_level("off"), Some(None));
        assert_eq!(parse_level("none"), Some(None));
        assert_eq!(parse_level("0"), Some(None));
        assert_eq!(parse_level("error"), Some(Some(Level::Error)));
        assert_eq!(parse_level("warn"), Some(Some(Level::Warn)));
        assert_eq!(parse_level("warning"), Some(Some(Level::Warn)));
        assert_eq!(parse_level("info"), Some(Some(Level::Info)));
        assert_eq!(parse_level("debug"), Some(Some(Level::Debug)));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn set_level_off_disables_everything() {
        // LEVEL is process-global, so restore it before returning: other
        // tests sharing the binary must see their configured level.
        let prev = LEVEL.load(Ordering::Relaxed);
        set_level(None);
        assert!(!enabled(Level::Error));
        assert!(!enabled(Level::Debug));
        set_level(Some(Level::Warn));
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        LEVEL.store(prev, Ordering::Relaxed);
    }

    #[test]
    fn log_does_not_panic() {
        log(Level::Info, format_args!("hello {}", 42));
        crate::log_debug!("debug {}", 1);
    }
}
