//! Shared little-endian binary-io substrate for the on-disk formats.
//!
//! Both weight containers — `HWT1` ([`crate::model::weights`], the
//! python⇄rust contract) and `HSB1` ([`crate::store`], the native
//! compressed-artifact store) — speak the same primitives: a 4-byte magic,
//! u32 length-prefixed strings, one-byte dtype tags, and little-endian
//! integers. This module is the single home for that plumbing, plus the
//! CRC32 used by the `HSB1` integrity footer.
//!
//! Two styles are provided:
//! - stream helpers over `std::io::{Read, Write}` for file-at-a-time IO;
//! - [`ByteReader`], a bounds-checked cursor over an in-memory buffer, for
//!   formats that read the whole file once and then parse sections in place
//!   (no per-field syscalls, no intermediate copies).

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Dtype tag shared by `HWT1` tensors and `HSB1` matrix sections.
pub const DT_F32: u8 = 0;
/// fp16 payload (decoded through [`crate::util::fp16`]).
pub const DT_F16: u8 = 1;
pub const DT_I32: u8 = 2;

// ---------------------------------------------------------------- streams

/// Read and verify a 4-byte magic; `what` names the format for the error.
pub fn check_magic<R: Read>(r: &mut R, magic: &[u8; 4], what: &str) -> Result<()> {
    let mut got = [0u8; 4];
    r.read_exact(&mut got)
        .with_context(|| format!("reading {what} magic"))?;
    if &got != magic {
        bail!("bad {what} magic {got:?} (want {magic:?})");
    }
    Ok(())
}

pub fn read_u8<R: Read>(r: &mut R) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

pub fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Read exactly `n` bytes into a fresh buffer.
pub fn read_exact_vec<R: Read>(r: &mut R, n: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

/// Fill `buf` as far as the stream allows, retrying through short reads
/// and `EINTR`, and return how many bytes were actually read (`< buf.len()`
/// only at end-of-stream). This is the robust read loop every header peek
/// shares: a signal landing mid-`read` or a filesystem returning short
/// counts must never be mistaken for a truncated file.
pub fn read_full<R: Read>(r: &mut R, buf: &mut [u8]) -> std::io::Result<usize> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => break,
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(filled)
}

/// Read the first `n` bytes of `path` through [`read_full`]. Returns the
/// (possibly shorter, at EOF) prefix; IO errors propagate.
pub fn read_file_prefix(path: &std::path::Path, n: usize) -> std::io::Result<Vec<u8>> {
    let mut f = std::fs::File::open(path)?;
    let mut buf = vec![0u8; n];
    let got = read_full(&mut f, &mut buf)?;
    buf.truncate(got);
    Ok(buf)
}

/// Read a u32 length-prefixed utf-8 string.
pub fn read_string<R: Read>(r: &mut R) -> Result<String> {
    let len = read_u32(r)? as usize;
    let buf = read_exact_vec(r, len)?;
    String::from_utf8(buf).context("length-prefixed string not utf-8")
}

pub fn write_u8<W: Write>(w: &mut W, v: u8) -> Result<()> {
    w.write_all(&[v])?;
    Ok(())
}

pub fn write_u32<W: Write>(w: &mut W, v: u32) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub fn write_u64<W: Write>(w: &mut W, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

/// Write a u32 length-prefixed utf-8 string.
pub fn write_string<W: Write>(w: &mut W, s: &str) -> Result<()> {
    write_u32(w, s.len() as u32)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

// ----------------------------------------------------- in-memory encoding

pub fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a u32 length-prefixed utf-8 string.
pub fn put_string(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

// -------------------------------------------------------------- ByteReader

/// Bounds-checked little-endian cursor over an in-memory buffer.
///
/// Every accessor fails with a position-annotated error instead of
/// panicking, so corrupt or truncated files surface as `Err` all the way up.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Borrow the next `n` bytes (zero-copy) and advance.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!(
                "truncated: wanted {n} bytes at offset {}, only {} left",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    pub fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(f64::from_le_bytes(a))
    }

    /// u32 length-prefixed utf-8 string.
    pub fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).context("length-prefixed string not utf-8")
    }

    /// Verify a 4-byte magic; `what` names the format for the error.
    pub fn expect_magic(&mut self, magic: &[u8; 4], what: &str) -> Result<()> {
        let got = self.take(4).with_context(|| format!("reading {what} magic"))?;
        if got != magic {
            bail!("bad {what} magic {got:?} (want {magic:?})");
        }
        Ok(())
    }
}

// ------------------------------------------------------------------ crc32

const CRC_TABLE: [u32; 256] = crc_table();

const fn crc_table() -> [u32; 256] {
    let mut t = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        t[i] = c;
        i += 1;
    }
    t
}

/// CRC-32 (IEEE 802.3, the zlib/gzip polynomial) of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_check_value() {
        // the standard CRC-32 check vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc32_detects_single_bit_flip() {
        let mut data = b"hierarchical sparse plus low rank".to_vec();
        let before = crc32(&data);
        data[7] ^= 0x20;
        assert_ne!(before, crc32(&data));
    }

    #[test]
    fn stream_roundtrip() {
        let mut out: Vec<u8> = Vec::new();
        out.extend_from_slice(b"HSB1");
        write_u32(&mut out, 7).unwrap();
        write_u64(&mut out, u64::MAX - 1).unwrap();
        write_string(&mut out, "layer0.wq").unwrap();
        write_u8(&mut out, DT_F16).unwrap();

        let mut r: &[u8] = &out;
        check_magic(&mut r, b"HSB1", "test").unwrap();
        assert_eq!(read_u32(&mut r).unwrap(), 7);
        assert_eq!(read_u64(&mut r).unwrap(), u64::MAX - 1);
        assert_eq!(read_string(&mut r).unwrap(), "layer0.wq");
        assert_eq!(read_u8(&mut r).unwrap(), DT_F16);
    }

    #[test]
    fn bad_magic_rejected() {
        let mut r: &[u8] = b"NOPE....";
        assert!(check_magic(&mut r, b"HSB1", "test").is_err());
    }

    #[test]
    fn byte_reader_roundtrip_and_bounds() {
        let mut out = Vec::new();
        put_u16(&mut out, 0xBEEF);
        put_u32(&mut out, 12345);
        put_u64(&mut out, 1 << 40);
        put_f64(&mut out, -2.5);
        put_string(&mut out, "spike");

        let mut r = ByteReader::new(&out);
        assert_eq!(r.u16().unwrap(), 0xBEEF);
        assert_eq!(r.u32().unwrap(), 12345);
        assert_eq!(r.u64().unwrap(), 1 << 40);
        assert_eq!(r.f64().unwrap(), -2.5);
        assert_eq!(r.string().unwrap(), "spike");
        assert_eq!(r.remaining(), 0);
        let e = r.take(1).unwrap_err();
        assert!(format!("{e}").contains("truncated"), "{e}");
    }

    /// A reader that returns one byte per call and injects `Interrupted`
    /// before every other read — the short-read/EINTR storm `read_full`
    /// must ride out.
    struct HostileReader {
        data: Vec<u8>,
        pos: usize,
        interrupt_next: bool,
    }

    impl Read for HostileReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.interrupt_next {
                self.interrupt_next = false;
                return Err(std::io::Error::new(
                    std::io::ErrorKind::Interrupted,
                    "signal",
                ));
            }
            self.interrupt_next = true;
            if self.pos >= self.data.len() || buf.is_empty() {
                return Ok(0);
            }
            buf[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn read_full_survives_short_reads_and_eintr() {
        let data: Vec<u8> = (0..100u8).collect();
        let mut r = HostileReader {
            data: data.clone(),
            pos: 0,
            interrupt_next: true,
        };
        let mut buf = vec![0u8; 64];
        assert_eq!(read_full(&mut r, &mut buf).unwrap(), 64);
        assert_eq!(&buf[..], &data[..64]);
        // EOF: the remaining 36 bytes fill, the count reports the shortfall
        let mut rest = vec![0u8; 64];
        assert_eq!(read_full(&mut r, &mut rest).unwrap(), 36);
        assert_eq!(&rest[..36], &data[64..]);
    }

    #[test]
    fn read_file_prefix_clamps_to_file_length() {
        let p = std::env::temp_dir().join(format!("hisolo-binio-prefix-{}", std::process::id()));
        std::fs::write(&p, b"HSBM1234").unwrap();
        assert_eq!(read_file_prefix(&p, 4).unwrap(), b"HSBM");
        assert_eq!(read_file_prefix(&p, 64).unwrap(), b"HSBM1234");
        std::fs::remove_file(&p).unwrap();
        assert!(read_file_prefix(&p, 4).is_err());
    }

    #[test]
    fn byte_reader_truncated_string() {
        let mut out = Vec::new();
        put_u32(&mut out, 100); // claims 100 bytes, provides 3
        out.extend_from_slice(b"abc");
        let mut r = ByteReader::new(&out);
        assert!(r.string().is_err());
    }
}
