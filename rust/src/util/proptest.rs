//! Tiny property-test driver (proptest is unavailable offline).
//!
//! `check(cases, |rng| ...)` runs a closure over `cases` seeded RNGs; on
//! failure it reports the seed so the case can be replayed with
//! `replay(seed, ...)`. Used by the linalg/sparse/hss invariant suites.

use crate::util::rng::Rng;

/// Run `prop` for `cases` deterministic seeds; panic with the failing seed.
pub fn check<F: FnMut(&mut Rng) -> Result<(), String>>(cases: u64, mut prop: F) {
    for seed in 0..cases {
        let mut rng = Rng::new(0xD15EA5Eu64.wrapping_add(seed.wrapping_mul(0x9E3779B97F4A7C15)));
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Replay a single failing seed (for debugging).
pub fn replay<F: FnMut(&mut Rng) -> Result<(), String>>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(0xD15EA5E + seed * 0x9E3779B97F4A7C15);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed at seed {seed}: {msg}");
    }
}

/// Assertion helper: relative closeness for floats with context.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= atol + rtol * b.abs().max(a.abs()) {
        Ok(())
    } else {
        Err(format!("{what}: {a} != {b} (rtol {rtol}, atol {atol})"))
    }
}

/// Assertion helper: element-wise slice closeness.
pub fn slices_close(a: &[f32], b: &[f32], rtol: f32, atol: f32, what: &str) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("{what}: length {} != {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > atol + rtol * y.abs().max(x.abs()) {
            return Err(format!("{what}[{i}]: {x} != {y}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true_property() {
        check(20, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        check(5, |rng| {
            if rng.uniform() < 2.0 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn close_helpers() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-6, 0.0, "x").is_err());
        assert!(slices_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6, "v").is_ok());
        assert!(slices_close(&[1.0], &[1.0, 2.0], 1e-6, 1e-6, "v").is_err());
    }
}
