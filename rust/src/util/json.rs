//! Minimal JSON value model, parser, and writer.
//!
//! Used for `artifacts/manifest.json` (read) and for the bench/eval table
//! emitters (write). Covers the full JSON grammar we produce and consume;
//! numbers are f64 (the manifest only holds ints/floats/strings/arrays/maps).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // multi-byte utf-8: copy raw continuation bytes
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// --- writer ----------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Convenience constructors for the writer side.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
            "model_config": {"vocab": 256, "d_model": 256},
            "executables": {"dense": {"file": "m.hlo.txt", "batch": 8,
                "inputs": [{"name": "tokens", "dtype": "i32", "shape": [8, 128]}]}}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(
            j.get("model_config").unwrap().get("vocab").unwrap().as_usize(),
            Some(256)
        );
        let inputs = j
            .get("executables")
            .unwrap()
            .get("dense")
            .unwrap()
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(inputs[0].get("name").unwrap().as_str(), Some("tokens"));
        assert_eq!(
            inputs[0].get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
            Some(128)
        );
    }

    #[test]
    fn roundtrip_through_display() {
        let text = r#"{"a":[1,2.5,-3],"b":"x\ny","c":true,"d":null,"e":{}}"#;
        let j = Json::parse(text).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{\"a\":1} x").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café naïve""#).unwrap();
        assert_eq!(j.as_str(), Some("café naïve"));
    }
}
