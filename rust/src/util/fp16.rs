//! IEEE 754 binary16 codec.
//!
//! The paper's storage numbers are fp16; weights cross the python⇄rust
//! boundary as f16 or f32 (`.hwt`), and all storage accounting in
//! [`crate::compress`] counts 2 bytes per value.

/// Convert f32 -> f16 bit pattern (round-to-nearest-even).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;

    if exp == 0xff {
        // inf / nan
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | m | ((mant >> 13) as u16 & 0x03ff);
    }
    // re-bias
    let unbiased = exp - 127;
    let half_exp = unbiased + 15;
    if half_exp >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if half_exp <= 0 {
        // subnormal or zero
        if half_exp < -10 {
            return sign;
        }
        let m = mant | 0x0080_0000; // implicit bit
        let shift = 14 - half_exp; // 14..24
        let half_mant = (m >> shift) as u16;
        // round
        let round_bit = 1u32 << (shift - 1);
        if (m & round_bit) != 0 && (m & (round_bit - 1) | (half_mant as u32 & 1)) != 0 {
            return sign | (half_mant + 1);
        }
        return sign | half_mant;
    }
    let half_mant = (mant >> 13) as u16;
    let mut out = sign | ((half_exp as u16) << 10) | half_mant;
    // round-to-nearest-even on the truncated 13 bits
    let round_bit = 1u32 << 12;
    if (mant & round_bit) != 0 && ((mant & (round_bit - 1)) != 0 || (half_mant & 1) != 0) {
        out = out.wrapping_add(1);
    }
    out
}

/// Convert f16 bit pattern -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // subnormal: normalize
            let mut e = -1i32;
            let mut m = m;
            while m & 0x0400 == 0 {
                m <<= 1;
                e += 1;
            }
            let m = (m & 0x03ff) << 13;
            let e = (127 - 15 - e) as u32;
            sign | (e << 23) | m
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, m) => sign | 0x7f80_0000 | (m << 13) | 0x0040_0000,
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Round-trip f32 through f16 precision (quantize in place).
pub fn quantize_f16(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = f16_to_f32(f32_to_f16(*x));
    }
}

/// Decode a little-endian f16 buffer.
pub fn decode_f16_le(bytes: &[u8]) -> Vec<f32> {
    bytes
        .chunks_exact(2)
        .map(|c| f16_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect()
}

/// Encode f32s as little-endian f16 bytes.
pub fn encode_f16_le(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 2);
    for &x in xs {
        out.extend_from_slice(&f32_to_f16(x).to_le_bytes());
    }
    out
}

/// Decode a little-endian f16 buffer into raw bit patterns — the store's
/// zero-widening load path (no f32 buffer is ever allocated).
pub fn decode_f16_bits_le(bytes: &[u8]) -> Vec<u16> {
    bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect()
}

/// Encode raw f16 bit patterns as little-endian bytes — the store's
/// lossless save path for factors that are already f16-resident.
pub fn encode_f16_bits_le(bits: &[u16]) -> Vec<u8> {
    let mut out = Vec::with_capacity(bits.len() * 2);
    for &h in bits {
        out.extend_from_slice(&h.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exact_small_values() {
        for &v in &[0.0f32, 1.0, -1.0, 0.5, 2.0, -0.25, 1024.0, 0.099976] {
            let rt = f16_to_f32(f32_to_f16(v));
            assert!((rt - v).abs() <= v.abs() * 1e-3 + 1e-6, "{v} -> {rt}");
        }
    }

    #[test]
    fn special_values() {
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f32_to_f16(0.0), 0);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        assert_eq!(f32_to_f16(1e9), 0x7c00); // overflow -> inf
    }

    #[test]
    fn subnormals() {
        let tiny = 3.0e-5f32; // subnormal in f16
        let rt = f16_to_f32(f32_to_f16(tiny));
        assert!((rt - tiny).abs() < 6e-8, "{tiny} -> {rt}");
        assert_eq!(f16_to_f32(f32_to_f16(1e-12)), 0.0); // underflow -> 0
    }

    #[test]
    fn roundtrip_error_bounded_random() {
        let mut r = Rng::new(11);
        for _ in 0..20_000 {
            let v = r.gaussian_f32() * 8.0;
            let rt = f16_to_f32(f32_to_f16(v));
            // half precision: 11-bit significand => rel error <= 2^-11
            assert!(
                (rt - v).abs() <= v.abs() * (1.0 / 2048.0) + 1e-7,
                "{v} -> {rt}"
            );
        }
    }

    #[test]
    fn idempotent_quantization() {
        let mut r = Rng::new(12);
        for _ in 0..5_000 {
            let v = r.gaussian_f32();
            let once = f16_to_f32(f32_to_f16(v));
            let twice = f16_to_f32(f32_to_f16(once));
            assert_eq!(once.to_bits(), twice.to_bits());
        }
    }

    #[test]
    fn buffer_codec_roundtrip() {
        let xs = vec![1.0f32, -2.5, 0.125, 65504.0];
        let enc = encode_f16_le(&xs);
        let dec = decode_f16_le(&enc);
        assert_eq!(dec, xs);
    }

    #[test]
    fn bits_codec_is_lossless_and_agrees_with_widening_codec() {
        let xs = vec![1.0f32, -2.5, 0.12345, 3.0e-5, 65504.0];
        let enc = encode_f16_le(&xs);
        let bits = decode_f16_bits_le(&enc);
        // raw bits round-trip to identical bytes (no requantization)
        assert_eq!(encode_f16_bits_le(&bits), enc);
        // widening the bits matches the widening decoder exactly
        let widened: Vec<f32> = bits.iter().map(|&h| f16_to_f32(h)).collect();
        assert_eq!(widened, decode_f16_le(&enc));
    }

    #[test]
    fn subnormal_boundary_values() {
        // smallest f16 subnormal is 2^-24; it must round-trip exactly
        let min_sub = 2.0f32.powi(-24);
        assert_eq!(f32_to_f16(min_sub), 0x0001);
        assert_eq!(f16_to_f32(0x0001), min_sub);
        assert_eq!(f16_to_f32(f32_to_f16(-min_sub)), -min_sub);

        // largest f16 subnormal (2^-14 - 2^-24 = 1023 * 2^-24)
        let max_sub = 1023.0 * 2.0f32.powi(-24);
        assert_eq!(f32_to_f16(max_sub), 0x03ff);
        assert_eq!(f16_to_f32(0x03ff), max_sub);

        // smallest f16 normal sits right above
        let min_norm = 2.0f32.powi(-14);
        assert_eq!(f32_to_f16(min_norm), 0x0400);
        assert_eq!(f16_to_f32(0x0400), min_norm);

        // every subnormal bit pattern round-trips through f32 exactly
        for h in 1u16..0x0400 {
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "pattern {h:#06x}");
        }
    }

    #[test]
    fn subnormal_underflow_ties_to_even() {
        // 2^-25 is exactly halfway between 0 and the smallest subnormal
        // 2^-24: round-to-nearest-even picks 0 (even)
        assert_eq!(f32_to_f16(2.0f32.powi(-25)), 0);
        assert_eq!(f32_to_f16(-2.0f32.powi(-25)), 0x8000);
        // anything strictly above the midpoint rounds up to the subnormal
        let above = f32::from_bits(2.0f32.powi(-25).to_bits() + 1);
        assert_eq!(f32_to_f16(above), 0x0001);
        // 3 * 2^-25 is halfway between subnormals 1 and 2: ties to 2 (even)
        assert_eq!(f32_to_f16(3.0 * 2.0f32.powi(-25)), 0x0002);
    }

    #[test]
    fn infinities_roundtrip_and_saturate() {
        assert_eq!(f16_to_f32(0x7c00), f32::INFINITY);
        assert_eq!(f16_to_f32(0xfc00), f32::NEG_INFINITY);
        assert_eq!(f32_to_f16(f16_to_f32(0x7c00)), 0x7c00);
        assert_eq!(f32_to_f16(f16_to_f32(0xfc00)), 0xfc00);
        // overflow past the max finite f16 (65504) saturates to ±inf
        assert_eq!(f32_to_f16(65520.0), 0x7c00);
        assert_eq!(f32_to_f16(-65520.0), 0xfc00);
        assert_eq!(f32_to_f16(f32::MAX), 0x7c00);
        // max finite value itself survives
        assert_eq!(f32_to_f16(65504.0), 0x7bff);
        assert_eq!(f16_to_f32(0x7bff), 65504.0);
    }

    #[test]
    fn nan_payloads_preserved() {
        // a quiet NaN with payload bits survives the f16 -> f32 -> f16 trip
        for h in [0x7e00u16, 0x7e55, 0x7fff, 0xfe00, 0xffab] {
            let f = f16_to_f32(h);
            assert!(f.is_nan(), "{h:#06x}");
            assert_eq!(f32_to_f16(f), h, "payload lost for {h:#06x}");
        }
        // f32 NaNs map to f16 NaNs with the quiet bit forced on
        let q = f32_to_f16(f32::NAN);
        assert_eq!(q & 0x7c00, 0x7c00);
        assert_ne!(q & 0x03ff, 0, "NaN must not collapse to infinity");
        // a signalling-style payload that would truncate to zero mantissa
        // still decodes as NaN thanks to the forced quiet bit
        let snan = f32::from_bits(0x7f80_0001);
        assert!(f16_to_f32(f32_to_f16(snan)).is_nan());
    }

    #[test]
    fn round_to_nearest_even_ties() {
        // 1 + 2^-11 is exactly halfway between f16(1.0) and the next
        // representable value: RNE keeps the even mantissa (1.0)
        let tie_down = 1.0 + 2.0f32.powi(-11);
        assert_eq!(f32_to_f16(tie_down), 0x3c00);
        // 1 + 3*2^-11 is halfway between mantissa 1 and 2: RNE picks 2
        let tie_up = 1.0 + 3.0 * 2.0f32.powi(-11);
        assert_eq!(f32_to_f16(tie_up), 0x3c02);
        // just above/below the midpoint resolves by magnitude, not parity
        let above = f32::from_bits(tie_down.to_bits() + 1);
        assert_eq!(f32_to_f16(above), 0x3c01);
        let below = f32::from_bits(tie_down.to_bits() - 1);
        assert_eq!(f32_to_f16(below), 0x3c00);
        // tie at an odd mantissa rounding up must carry into the exponent:
        // 2047.5 is halfway between f16(2047) = 0x67ff and f16(2048) = 0x6800
        assert_eq!(f32_to_f16(2047.0), 0x67ff);
        assert_eq!(f32_to_f16(2047.5), 0x6800);
        assert_eq!(f16_to_f32(f32_to_f16(2047.0)), 2047.0);
        assert_eq!(f16_to_f32(f32_to_f16(2047.5)), 2048.0);
    }
}
