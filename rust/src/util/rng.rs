//! Deterministic PRNG: splitmix64 seeding + xoshiro256** core, with the
//! float/Gaussian helpers the compression stack needs (Gaussian sketches for
//! randomized SVD, synthetic workloads, property tests).

/// xoshiro256** PRNG seeded via splitmix64. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Gaussian from Box-Muller
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Standard Gaussian via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.spare.take() {
            return g;
        }
        // rejection-free polar-less form; u1 in (0,1]
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = std::f64::consts::TAU * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fill a slice with standard Gaussians.
    pub fn fill_gaussian(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32();
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(4);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
