//! The seven methods the paper's §5 compares under one formulation.

use std::fmt;
use std::str::FromStr;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// uncompressed baseline ("Original" in Fig 3)
    Dense,
    /// truncated exact SVD (§3.2)
    Svd,
    /// randomized SVD (§3.3)
    Rsvd,
    /// sparse + exact SVD on the residual (§3.4, "sSVD")
    SSvd,
    /// sparse + randomized SVD on the residual (§3.5, "sR-SVD")
    SRsvd,
    /// sparse + hierarchical low rank (§4.5, "sHSS")
    SHss,
    /// sHSS with Reverse Cuthill–McKee reordering ("sHSS-RCM")
    SHssRcm,
}

impl Method {
    pub const ALL: [Method; 7] = [
        Method::Dense,
        Method::Svd,
        Method::Rsvd,
        Method::SSvd,
        Method::SRsvd,
        Method::SHss,
        Method::SHssRcm,
    ];

    /// The methods plotted in the paper's Fig 3.
    pub const FIG3: [Method; 5] = [
        Method::Dense,
        Method::SSvd,
        Method::SRsvd,
        Method::SHss,
        Method::SHssRcm,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Method::Dense => "dense",
            Method::Svd => "svd",
            Method::Rsvd => "rsvd",
            Method::SSvd => "ssvd",
            Method::SRsvd => "srsvd",
            Method::SHss => "shss",
            Method::SHssRcm => "shss-rcm",
        }
    }

    /// Label as printed in the paper's figures.
    pub fn paper_label(&self) -> &'static str {
        match self {
            Method::Dense => "Original",
            Method::Svd => "SVD",
            Method::Rsvd => "R-SVD",
            Method::SSvd => "sSVD",
            Method::SRsvd => "sR-SVD",
            Method::SHss => "sHSS",
            Method::SHssRcm => "sHSS-RCM",
        }
    }

    pub fn uses_sparsity(&self) -> bool {
        matches!(
            self,
            Method::SSvd | Method::SRsvd | Method::SHss | Method::SHssRcm
        )
    }

    pub fn is_hierarchical(&self) -> bool {
        matches!(self, Method::SHss | Method::SHssRcm)
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Method {
    type Err = String;

    fn from_str(s: &str) -> Result<Method, String> {
        match s.to_ascii_lowercase().as_str() {
            "dense" | "original" => Ok(Method::Dense),
            "svd" => Ok(Method::Svd),
            "rsvd" | "r-svd" => Ok(Method::Rsvd),
            "ssvd" | "s-svd" => Ok(Method::SSvd),
            "srsvd" | "sr-svd" => Ok(Method::SRsvd),
            "shss" => Ok(Method::SHss),
            "shss-rcm" | "shssrcm" => Ok(Method::SHssRcm),
            other => Err(format!(
                "unknown method '{other}' (expected one of: dense svd rsvd ssvd srsvd shss shss-rcm)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for m in Method::ALL {
            assert_eq!(m.name().parse::<Method>().unwrap(), m);
        }
    }

    #[test]
    fn parse_aliases() {
        assert_eq!("Original".parse::<Method>().unwrap(), Method::Dense);
        assert_eq!("sR-SVD".parse::<Method>().unwrap(), Method::SRsvd);
        assert!("bogus".parse::<Method>().is_err());
    }

    #[test]
    fn classification() {
        assert!(Method::SHssRcm.is_hierarchical());
        assert!(!Method::SSvd.is_hierarchical());
        assert!(Method::SSvd.uses_sparsity());
        assert!(!Method::Svd.uses_sparsity());
    }
}
