//! Whole-model compression pipeline: apply one method/config to every
//! q/k/v projection (the paper's targeted 1.6B-parameter subset, scaled).

use crate::compress::{CompressedMatrix, Compressor, CompressorConfig, Method};
use crate::linalg::Matrix;

/// Per-layer compression report (one row of the paper's layer table).
pub struct LayerReport {
    pub name: String,
    pub method: Method,
    pub rel_error: f64,
    pub params: usize,
    pub bytes: usize,
    pub dense_bytes: usize,
    pub compressed: CompressedMatrix,
}

impl LayerReport {
    pub fn storage_ratio(&self) -> f64 {
        self.bytes as f64 / self.dense_bytes as f64
    }
}

/// Compress each named square projection. `projections` are (name, W) pairs
/// where W multiplies activations as rows(X)·W; internally the compressor
/// operates on A = Wᵀ (column-vector convention), matching the AOT exporter.
pub fn compress_model_qkv(
    projections: &[(String, Matrix)],
    method: Method,
    cfg: CompressorConfig,
) -> Vec<LayerReport> {
    let comp = Compressor::new(cfg);
    projections
        .iter()
        .map(|(name, w)| {
            let a = w.transpose();
            let c = comp.compress(&a, method);
            LayerReport {
                name: name.clone(),
                method,
                rel_error: c.rel_error(&a),
                params: c.params(),
                bytes: c.bytes(),
                dense_bytes: a.data.len() * crate::hss::storage::VALUE_BYTES,
                compressed: c,
            }
        })
        .collect()
}

/// Persist a pipeline result as one `HSB1` store file (method and
/// compression-time error recorded per entry, so a later
/// `CompressedModel::from_store` needs no dense weights). Returns the byte
/// count written.
pub fn save_reports(reports: &[LayerReport], path: &std::path::Path) -> anyhow::Result<u64> {
    let mut w = crate::store::StoreWriter::new();
    for r in reports {
        w.push_with_meta(&r.name, &r.compressed, Some(r.method), r.rel_error);
    }
    w.finish(path)
}

/// Aggregate totals over layer reports.
pub struct PipelineSummary {
    pub total_params: usize,
    pub total_bytes: usize,
    pub total_dense_bytes: usize,
    pub mean_rel_error: f64,
}

pub fn summarize(reports: &[LayerReport]) -> PipelineSummary {
    let total_params = reports.iter().map(|r| r.params).sum();
    let total_bytes = reports.iter().map(|r| r.bytes).sum();
    let total_dense_bytes = reports.iter().map(|r| r.dense_bytes).sum();
    let mean_rel_error = if reports.is_empty() {
        0.0
    } else {
        reports.iter().map(|r| r.rel_error).sum::<f64>() / reports.len() as f64
    };
    PipelineSummary {
        total_params,
        total_bytes,
        total_dense_bytes,
        mean_rel_error,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_projections(n: usize, layers: usize) -> Vec<(String, Matrix)> {
        let mut out = Vec::new();
        for l in 0..layers {
            for p in ["wq", "wk", "wv"] {
                out.push((
                    format!("layer{l}.{p}"),
                    Matrix::randn(n, n, (l * 3 + p.len()) as u64),
                ));
            }
        }
        out
    }

    #[test]
    fn compresses_all_projections() {
        // 64x64: below that the COO index overhead can exceed dense fp16,
        // which is expected behaviour (documented in hss::storage)
        let projs = fake_projections(64, 2);
        let reports = compress_model_qkv(
            &projs,
            Method::SHssRcm,
            CompressorConfig {
                rank: 4,
                sparsity: 0.1,
                depth: 2,
                ..Default::default()
            },
        );
        assert_eq!(reports.len(), 6);
        for r in &reports {
            assert!(r.storage_ratio() < 1.0, "{}: {}", r.name, r.storage_ratio());
            assert!(r.rel_error.is_finite());
        }
    }

    #[test]
    fn summary_totals_add_up() {
        let projs = fake_projections(32, 2);
        let reports = compress_model_qkv(&projs, Method::SSvd, CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            ..Default::default()
        });
        let s = summarize(&reports);
        assert_eq!(s.total_bytes, reports.iter().map(|r| r.bytes).sum::<usize>());
        assert!(s.total_dense_bytes > s.total_bytes);
        assert!(s.mean_rel_error > 0.0);
    }

    #[test]
    fn save_reports_roundtrips_through_store() {
        let projs = fake_projections(32, 1);
        let reports = compress_model_qkv(
            &projs,
            Method::SHssRcm,
            CompressorConfig {
                rank: 4,
                sparsity: 0.1,
                depth: 1,
                min_leaf: 4,
                ..Default::default()
            },
        );
        let dir = std::env::temp_dir().join("hisolo_test_pipeline_save");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("qkv.hsb1");
        let written = save_reports(&reports, &path).unwrap();
        assert!(written > 0);
        let file = crate::store::StoreFile::open(&path).unwrap();
        assert_eq!(file.len(), 3);
        for r in &reports {
            let m = file.load(&r.name).unwrap();
            assert_eq!(m.params(), r.params, "{}", r.name);
            assert_eq!(file.meta(&r.name).unwrap().method, Some(Method::SHssRcm));
        }
    }

    #[test]
    fn dense_method_ratio_one() {
        let projs = fake_projections(16, 1);
        let reports =
            compress_model_qkv(&projs, Method::Dense, CompressorConfig::default());
        let s = summarize(&reports);
        assert_eq!(s.total_bytes, s.total_dense_bytes);
        assert!(s.mean_rel_error < 1e-10);
    }
}
