//! Whole-model compression pipeline: apply one method/config to every
//! q/k/v projection (the paper's targeted 1.6B-parameter subset, scaled).

use crate::compress::{CompressedMatrix, Compressor, CompressorConfig, Method};
use crate::linalg::Matrix;

/// Per-layer compression report (one row of the paper's layer table).
pub struct LayerReport {
    pub name: String,
    pub method: Method,
    pub rel_error: f64,
    pub params: usize,
    pub bytes: usize,
    pub dense_bytes: usize,
    pub compressed: CompressedMatrix,
}

impl LayerReport {
    pub fn storage_ratio(&self) -> f64 {
        self.bytes as f64 / self.dense_bytes as f64
    }
}

/// Compress each named square projection. `projections` are (name, W) pairs
/// where W multiplies activations as rows(X)·W; internally the compressor
/// operates on A = Wᵀ (column-vector convention), matching the AOT exporter.
pub fn compress_model_qkv(
    projections: &[(String, Matrix)],
    method: Method,
    cfg: CompressorConfig,
) -> Vec<LayerReport> {
    let comp = Compressor::new(cfg);
    projections
        .iter()
        .map(|(name, w)| {
            let a = w.transpose();
            let c = comp.compress(&a, method);
            LayerReport {
                name: name.clone(),
                method,
                rel_error: c.rel_error(&a),
                params: c.params(),
                bytes: c.bytes(),
                dense_bytes: a.data.len() * crate::hss::storage::VALUE_BYTES,
                compressed: c,
            }
        })
        .collect()
}

/// Refine stage: fine-tune every report's factors against its dense
/// teacher on per-layer calibration activations (layer index =
/// report position / 3, since reports run layer-major in q/k/v order and
/// all three projections of a layer consume the same post-ln1 input).
/// Reports are updated in place — `compressed` holds the refined factors
/// and `rel_error` the post-refinement reconstruction error — so the
/// result can flow straight into [`save_reports`]. Returns one
/// calibration report per projection.
///
/// The 3·L projections are independent, so they fan out across scoped
/// worker threads (`cfg.threads`, 0 = all cores — the same work-stealing
/// pattern as `perplexity_parallel`), each thread driving the batched
/// apply/gradient kernels. Every projection seeds its own RNG from the
/// config, so the result is identical at any thread count.
pub fn refine_reports(
    reports: &mut [LayerReport],
    projections: &[(String, Matrix)],
    activations: &[Vec<Vec<f32>>],
    cfg: &crate::train::TrainConfig,
) -> Vec<crate::train::CalibrationReport> {
    assert_eq!(
        reports.len(),
        projections.len(),
        "one projection per report"
    );
    assert!(
        activations.len() * 3 >= reports.len(),
        "activations cover {} layers but reports span {}",
        activations.len(),
        reports.len().div_ceil(3)
    );
    for (i, rep) in reports.iter().enumerate() {
        // index pairing alone would silently calibrate against the wrong
        // teacher if a caller reorders either list — fail loudly instead
        assert_eq!(
            rep.name, projections[i].0,
            "report/projection order mismatch at {i}"
        );
    }
    let threads = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        cfg.threads
    }
    .min(reports.len())
    .max(1);

    let refine_one = |i: usize, rep: &mut LayerReport| {
        let teacher = projections[i].1.transpose();
        let xs: &[Vec<f32>] = &activations[i / 3];
        let cal = crate::train::calibrate_matrix(&rep.name, &teacher, &mut rep.compressed, xs, cfg);
        rep.rel_error = cal.rel_err_after;
        cal
    };

    if threads <= 1 {
        return reports
            .iter_mut()
            .enumerate()
            .map(|(i, rep)| refine_one(i, rep))
            .collect();
    }

    // work-stealing queue of (index, &mut report); results reassemble in
    // projection order afterwards
    let queue: std::sync::Mutex<Vec<(usize, &mut LayerReport)>> =
        std::sync::Mutex::new(reports.iter_mut().enumerate().collect());
    let results: std::sync::Mutex<Vec<(usize, crate::train::CalibrationReport)>> =
        std::sync::Mutex::new(Vec::with_capacity(projections.len()));
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let item = queue.lock().unwrap().pop();
                let Some((i, rep)) = item else { break };
                let cal = refine_one(i, rep);
                results.lock().unwrap().push((i, cal));
            });
        }
    });
    let mut out = results.into_inner().unwrap();
    out.sort_by_key(|(i, _)| *i);
    out.into_iter().map(|(_, cal)| cal).collect()
}

/// Persist a pipeline result as one `HSB1` store file (method and
/// compression-time error recorded per entry, so a later
/// `CompressedModel::from_store` needs no dense weights). Returns the byte
/// count written. Files saved this way carry save-sequence 0; retention-
/// exact saves go through [`save_reports_seq`] (what
/// `ModelStore::save_model` stamps).
pub fn save_reports(reports: &[LayerReport], path: &std::path::Path) -> anyhow::Result<u64> {
    save_reports_seq(reports, path, 0)
}

/// [`save_reports`] with an explicit save-sequence number in the `HSB1`
/// header, so `ModelStore::prune` can order variants exactly.
pub fn save_reports_seq(
    reports: &[LayerReport],
    path: &std::path::Path,
    save_seq: u64,
) -> anyhow::Result<u64> {
    let mut w = crate::store::StoreWriter::new();
    w.set_save_seq(save_seq);
    for r in reports {
        w.push_with_meta(&r.name, &r.compressed, Some(r.method), r.rel_error);
    }
    w.finish(path)
}

/// Aggregate totals over layer reports.
pub struct PipelineSummary {
    pub total_params: usize,
    pub total_bytes: usize,
    pub total_dense_bytes: usize,
    pub mean_rel_error: f64,
}

pub fn summarize(reports: &[LayerReport]) -> PipelineSummary {
    let total_params = reports.iter().map(|r| r.params).sum();
    let total_bytes = reports.iter().map(|r| r.bytes).sum();
    let total_dense_bytes = reports.iter().map(|r| r.dense_bytes).sum();
    let mean_rel_error = if reports.is_empty() {
        0.0
    } else {
        reports.iter().map(|r| r.rel_error).sum::<f64>() / reports.len() as f64
    };
    PipelineSummary {
        total_params,
        total_bytes,
        total_dense_bytes,
        mean_rel_error,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_projections(n: usize, layers: usize) -> Vec<(String, Matrix)> {
        let mut out = Vec::new();
        for l in 0..layers {
            for p in ["wq", "wk", "wv"] {
                out.push((
                    format!("layer{l}.{p}"),
                    Matrix::randn(n, n, (l * 3 + p.len()) as u64),
                ));
            }
        }
        out
    }

    #[test]
    fn compresses_all_projections() {
        // 64x64: below that the COO index overhead can exceed dense fp16,
        // which is expected behaviour (documented in hss::storage)
        let projs = fake_projections(64, 2);
        let reports = compress_model_qkv(
            &projs,
            Method::SHssRcm,
            CompressorConfig {
                rank: 4,
                sparsity: 0.1,
                depth: 2,
                ..Default::default()
            },
        );
        assert_eq!(reports.len(), 6);
        for r in &reports {
            assert!(r.storage_ratio() < 1.0, "{}: {}", r.name, r.storage_ratio());
            assert!(r.rel_error.is_finite());
        }
    }

    #[test]
    fn summary_totals_add_up() {
        let projs = fake_projections(32, 2);
        let reports = compress_model_qkv(&projs, Method::SSvd, CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            ..Default::default()
        });
        let s = summarize(&reports);
        assert_eq!(s.total_bytes, reports.iter().map(|r| r.bytes).sum::<usize>());
        assert!(s.total_dense_bytes > s.total_bytes);
        assert!(s.mean_rel_error > 0.0);
    }

    #[test]
    fn save_reports_roundtrips_through_store() {
        let projs = fake_projections(32, 1);
        let reports = compress_model_qkv(
            &projs,
            Method::SHssRcm,
            CompressorConfig {
                rank: 4,
                sparsity: 0.1,
                depth: 1,
                min_leaf: 4,
                ..Default::default()
            },
        );
        let dir = std::env::temp_dir().join("hisolo_test_pipeline_save");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("qkv.hsb1");
        let written = save_reports(&reports, &path).unwrap();
        assert!(written > 0);
        let file = crate::store::StoreFile::open(&path).unwrap();
        assert_eq!(file.len(), 3);
        for r in &reports {
            let m = file.load(&r.name).unwrap();
            assert_eq!(m.params(), r.params, "{}", r.name);
            assert_eq!(file.meta(&r.name).unwrap().method, Some(Method::SHssRcm));
        }
    }

    #[test]
    fn refine_stage_updates_reports_in_place() {
        let projs = fake_projections(32, 1);
        let mut reports = compress_model_qkv(
            &projs,
            Method::SSvd,
            CompressorConfig {
                rank: 4,
                sparsity: 0.05,
                ..Default::default()
            },
        );
        let before: Vec<f64> = reports.iter().map(|r| r.rel_error).collect();
        let mut rng = crate::util::rng::Rng::new(11);
        let xs: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..32).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let activations = vec![xs];
        let cfg = crate::train::TrainConfig {
            steps: 100,
            ..Default::default()
        };
        let cals = refine_reports(&mut reports, &projs, &activations, &cfg);
        assert_eq!(cals.len(), 3);
        for ((rep, cal), b) in reports.iter().zip(&cals).zip(&before) {
            assert!(cal.steps_run > 0, "{}", rep.name);
            assert!(rep.rel_error < *b, "{}: {} !< {b}", rep.name, rep.rel_error);
            // the report's matrix really is the refined one
            let a = projs
                .iter()
                .find(|(n, _)| *n == rep.name)
                .unwrap()
                .1
                .transpose();
            assert!((rep.compressed.rel_error(&a) - rep.rel_error).abs() < 1e-12);
        }
    }

    #[test]
    fn parallel_refine_matches_sequential() {
        // the fan-out must be a pure scheduling change: per-projection
        // RNGs are seeded from the config, so any thread count produces
        // bit-identical factors and reports
        let projs = fake_projections(32, 2);
        let mk = || {
            compress_model_qkv(
                &projs,
                Method::SSvd,
                CompressorConfig {
                    rank: 4,
                    sparsity: 0.05,
                    ..Default::default()
                },
            )
        };
        let mut rng = crate::util::rng::Rng::new(13);
        let xs: Vec<Vec<f32>> = (0..48)
            .map(|_| (0..32).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let activations = vec![xs.clone(), xs];
        let cfg_seq = crate::train::TrainConfig {
            steps: 40,
            threads: 1,
            ..Default::default()
        };
        let cfg_par = crate::train::TrainConfig {
            threads: 4,
            ..cfg_seq
        };
        let mut seq = mk();
        let cals_seq = refine_reports(&mut seq, &projs, &activations, &cfg_seq);
        let mut par = mk();
        let cals_par = refine_reports(&mut par, &projs, &activations, &cfg_par);
        assert_eq!(cals_seq.len(), cals_par.len());
        for ((a, b), (ca, cb)) in seq.iter().zip(&par).zip(cals_seq.iter().zip(&cals_par)) {
            assert_eq!(ca.name, cb.name, "report order must be projection order");
            assert_eq!(ca.steps_run, cb.steps_run);
            assert_eq!(
                crate::train::grad::copy_params(&a.compressed),
                crate::train::grad::copy_params(&b.compressed),
                "{}",
                a.name
            );
            assert_eq!(a.rel_error, b.rel_error);
        }
    }

    #[test]
    fn dense_method_ratio_one() {
        let projs = fake_projections(16, 1);
        let reports =
            compress_model_qkv(&projs, Method::Dense, CompressorConfig::default());
        let s = summarize(&reports);
        assert_eq!(s.total_bytes, s.total_dense_bytes);
        assert!(s.mean_rel_error < 1e-10);
    }
}
