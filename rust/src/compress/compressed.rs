//! The runtime representation every method compresses into, with the three
//! operations all experiments need: apply, storage, error.

use crate::hss::matvec::Workspace;
use crate::hss::storage::{INDEX_BYTES, VALUE_BYTES};
use crate::hss::HssNode;
use crate::linalg::norms::rel_fro_error;
use crate::linalg::Matrix;
use crate::sparse::Csr;

/// A compressed square matrix.
pub enum CompressedMatrix {
    /// the uncompressed baseline
    Dense { w: Matrix },
    /// (optionally sparse-plus-) low-rank: W ≈ S + L·R
    LowRank {
        l: Matrix,
        r: Matrix,
        sparse: Option<Csr>,
    },
    /// sparse-plus-HSS tree (sHSS / sHSS-RCM)
    Hss { tree: HssNode },
}

impl CompressedMatrix {
    pub fn n(&self) -> usize {
        match self {
            CompressedMatrix::Dense { w } => w.rows,
            CompressedMatrix::LowRank { l, .. } => l.rows,
            CompressedMatrix::Hss { tree } => tree.n(),
        }
    }

    /// y = W x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.n()];
        let mut ws = self.workspace();
        self.matvec_with(x, &mut y, &mut ws);
        y
    }

    /// Pre-sized scratch for allocation-free repeated single-vector
    /// applies (grows on demand if a wider batch comes through).
    pub fn workspace(&self) -> BatchWorkspace {
        self.workspace_for(1)
    }

    /// Scratch pre-sized for batches of `k` columns.
    pub fn workspace_for(&self, k: usize) -> BatchWorkspace {
        match self {
            CompressedMatrix::Hss { tree } => BatchWorkspace {
                hss: Workspace::for_node_batch(tree, k),
                t: Vec::new(),
                stage: Vec::new(),
            },
            CompressedMatrix::LowRank { r, .. } => BatchWorkspace {
                hss: Workspace::default(),
                t: vec![0.0; r.rows * k],
                stage: Vec::new(),
            },
            CompressedMatrix::Dense { .. } => BatchWorkspace {
                hss: Workspace::default(),
                t: Vec::new(),
                stage: Vec::new(),
            },
        }
    }

    /// Y = W X for a row-major column block of independent inputs
    /// (X, Y of shape [n, k]; column c is input c) — the primary apply
    /// path for every variant: one CSR SpMM plus thin dense
    /// block-multiplies for LowRank, a single blocked tree walk for HSS.
    pub fn apply_batch(&self, x: &Matrix, y: &mut Matrix, ws: &mut BatchWorkspace) {
        assert_eq!(x.rows, self.n(), "input block has {} rows, matrix n = {}", x.rows, self.n());
        assert_eq!((y.rows, y.cols), (x.rows, x.cols), "output block shape mismatch");
        self.apply_batch_with(&x.data, &mut y.data, x.cols, ws);
    }

    /// Slice form of [`CompressedMatrix::apply_batch`]: `x`/`y` are
    /// length-n·k row-major [n, k] blocks.
    pub fn apply_batch_with(&self, x: &[f32], y: &mut [f32], k: usize, ws: &mut BatchWorkspace) {
        assert!(k > 0, "empty batch");
        match self {
            // Dense (and the thin LowRank factors below) keep the inline
            // per-lane widening: staging a whole n×n (or n×rank) factor
            // would hold a persistent f32 copy that erodes the f16
            // resident-memory halving. Small blocks — HSS leaves and
            // couplings, CSR value runs — go through the shared stage.
            CompressedMatrix::Dense { w } => w.apply_batch_into(x, y, k),
            CompressedMatrix::LowRank { l, r, sparse } => {
                // Y = L (R X) [+ S X] — two thin block-multiplies
                let BatchWorkspace { t, stage, .. } = ws;
                if t.len() < r.rows * k {
                    t.resize(r.rows * k, 0.0);
                }
                let tb = &mut t[..r.rows * k];
                {
                    // the `lowrank` stage is exactly the two thin factor
                    // multiplies; the sparse correction reports as `spmm`
                    let _span = crate::obs::Span::enter(crate::obs::Stage::LowRank);
                    crate::obs::count_flops(
                        r.apply_flops(k) + l.apply_flops(k),
                        (r.resident_bytes() + l.resident_bytes()) as u64,
                    );
                    r.apply_batch_into(x, tb, k);
                    l.apply_batch_into(tb, y, k);
                }
                if let Some(s) = sparse {
                    s.spmm_add_staged(x, y, k, stage);
                }
            }
            CompressedMatrix::Hss { tree } => tree.apply_batch_with(x, y, k, &mut ws.hss),
        }
    }

    /// y = W x with reusable workspace — the k = 1 case of
    /// [`CompressedMatrix::apply_batch`] (request-path form).
    pub fn matvec_with(&self, x: &[f32], y: &mut [f32], ws: &mut BatchWorkspace) {
        self.apply_batch_with(x, y, 1, ws);
    }

    /// Dense matrix this representation stands for (testing/eval only).
    /// Always f32 — f16-resident factors are widened on the way out.
    pub fn reconstruct(&self) -> Matrix {
        match self {
            CompressedMatrix::Dense { w } => w.widen(),
            CompressedMatrix::LowRank { l, r, sparse } => {
                let mut m = l.widen().matmul(&r.widen());
                if let Some(s) = sparse {
                    m = m.add(&s.to_dense());
                }
                m
            }
            CompressedMatrix::Hss { tree } => tree.reconstruct(),
        }
    }

    /// Narrow every resident weight buffer to f16 in place (idempotent).
    /// The apply kernels then widen lane-by-lane; accumulation stays f32,
    /// so results are bit-identical to applying the fp16-quantized values
    /// at f32 residency — only the memory halves.
    pub fn narrow_to_f16(&mut self) {
        match self {
            CompressedMatrix::Dense { w } => w.narrow_to_f16(),
            CompressedMatrix::LowRank { l, r, sparse } => {
                l.narrow_to_f16();
                r.narrow_to_f16();
                if let Some(s) = sparse {
                    s.narrow_to_f16();
                }
            }
            CompressedMatrix::Hss { tree } => tree.narrow_to_f16(),
        }
    }

    /// Widen every resident weight buffer back to f32 in place (exact;
    /// idempotent) — required before `train::calibrate` touches the
    /// factors (training is f32-only; `finetune` narrows again on save).
    pub fn widen_to_f32(&mut self) {
        match self {
            CompressedMatrix::Dense { w } => w.widen_to_f32(),
            CompressedMatrix::LowRank { l, r, sparse } => {
                l.widen_to_f32();
                r.widen_to_f32();
                if let Some(s) = sparse {
                    s.widen_to_f32();
                }
            }
            CompressedMatrix::Hss { tree } => tree.widen_to_f32(),
        }
    }

    /// Dtype of the resident weight buffers (narrow/widen keep every
    /// factor of a matrix uniform).
    pub fn weights_dtype(&self) -> crate::linalg::Dtype {
        match self {
            CompressedMatrix::Dense { w } => w.dtype(),
            CompressedMatrix::LowRank { l, .. } => l.dtype(),
            CompressedMatrix::Hss { tree } => tree.weights_dtype(),
        }
    }

    /// Bytes actually resident for this matrix's weight values at their
    /// current dtype (sparse-index/permutation overhead excluded — it is
    /// dtype-independent and reported by [`CompressedMatrix::bytes`]).
    pub fn resident_weight_bytes(&self) -> usize {
        match self {
            CompressedMatrix::Dense { w } => w.resident_bytes(),
            CompressedMatrix::LowRank { l, r, sparse } => {
                l.resident_bytes()
                    + r.resident_bytes()
                    + sparse.as_ref().map_or(0, |s| s.resident_value_bytes())
            }
            CompressedMatrix::Hss { tree } => tree.resident_weight_bytes(),
        }
    }

    /// Relative Frobenius reconstruction error vs the original.
    pub fn rel_error(&self, original: &Matrix) -> f64 {
        rel_fro_error(&self.reconstruct(), original)
    }

    /// Stored parameter count (values only).
    pub fn params(&self) -> usize {
        match self {
            CompressedMatrix::Dense { w } => w.data.len(),
            CompressedMatrix::LowRank { l, r, sparse } => {
                l.data.len() + r.data.len() + sparse.as_ref().map_or(0, |s| s.nnz())
            }
            CompressedMatrix::Hss { tree } => tree.storage().params,
        }
    }

    /// Total bytes at fp16 including index overhead.
    pub fn bytes(&self) -> usize {
        match self {
            CompressedMatrix::Dense { w } => w.data.len() * VALUE_BYTES,
            CompressedMatrix::LowRank { l, r, sparse } => {
                (l.data.len() + r.data.len()) * VALUE_BYTES
                    + sparse
                        .as_ref()
                        .map_or(0, |s| s.nnz() * (VALUE_BYTES + 2 * INDEX_BYTES))
            }
            CompressedMatrix::Hss { tree } => tree.storage().bytes,
        }
    }

    /// params / dense-params — the paper's storage axis (< 1 means
    /// compression). Use [`CompressedMatrix::bytes`] for the
    /// index-overhead-aware byte count.
    pub fn storage_ratio(&self) -> f64 {
        self.params() as f64 / (self.n() * self.n()) as f64
    }
}

/// Scratch reused across `apply_batch` / `matvec_with` calls; sized for
/// the widest batch seen so far and grown on demand — a default (empty)
/// workspace is valid for any matrix and warms up on first use.
///
/// `stage` is the f16 staging buffer for sparse value runs (the HSS tree
/// carries its own, per-block-sized, inside [`Workspace`]): f16-resident
/// weights are widened wholesale into it once per apply call so the hot
/// kernels run their pure-f32 form, instead of converting inside the
/// inner loop per column block.
#[derive(Default)]
pub struct BatchWorkspace {
    hss: Workspace,
    t: Vec<f32>,
    stage: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, CompressorConfig, Method};
    use crate::util::proptest::slices_close;
    use crate::util::rng::Rng;

    fn spiky(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut a = Matrix::randn(n, n, seed).scale(0.05);
        for _ in 0..2 * n {
            let i = rng.below(n);
            let j = rng.below(n);
            a.data[i * n + j] += rng.gaussian_f32();
        }
        a
    }

    #[test]
    fn lowrank_matvec_with_sparse() {
        let w = spiky(48, 1);
        let cfg = CompressorConfig {
            rank: 8,
            sparsity: 0.2,
            ..Default::default()
        };
        let c = Compressor::new(cfg).compress(&w, Method::SSvd);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..48).map(|_| rng.gaussian_f32()).collect();
        let expect = c.reconstruct().matvec(&x);
        slices_close(&c.matvec(&x), &expect, 1e-4, 1e-4, "ssvd matvec").unwrap();
    }

    #[test]
    fn storage_ordering_dense_vs_compressed() {
        let w = spiky(64, 3);
        let comp = Compressor::new(CompressorConfig {
            rank: 4,
            sparsity: 0.05,
            depth: 2,
            ..Default::default()
        });
        let dense = comp.compress(&w, Method::Dense);
        for m in [Method::Svd, Method::SSvd, Method::SHss, Method::SHssRcm] {
            let c = comp.compress(&w, m);
            assert!(
                c.bytes() < dense.bytes(),
                "{m:?} bytes {} !< dense {}",
                c.bytes(),
                dense.bytes()
            );
        }
    }

    #[test]
    fn workspace_reuse_stable() {
        let w = spiky(32, 4);
        let comp = Compressor::new(CompressorConfig {
            rank: 6,
            sparsity: 0.1,
            depth: 2,
            ..Default::default()
        });
        for m in Method::ALL {
            let c = comp.compress(&w, m);
            let mut ws = c.workspace();
            let x: Vec<f32> = (0..32).map(|i| (i as f32).cos()).collect();
            let mut y1 = vec![0.0; 32];
            let mut y2 = vec![0.0; 32];
            c.matvec_with(&x, &mut y1, &mut ws);
            c.matvec_with(&x, &mut y2, &mut ws);
            assert_eq!(y1, y2, "{m:?}");
        }
    }

    #[test]
    fn apply_batch_equals_per_column_matvec_all_variants() {
        // Dense / LowRank+CSR / a permuted depth-3 HSS tree, k drawn from
        // 1 (degenerate) up to 9 — batched and per-vector answers must
        // agree to well within 1e-6 relative
        use crate::util::proptest::check;
        check(8, |rng| {
            let n = 48 + 16 * rng.below(2);
            let w = spiky(n, rng.next_u64());
            let comp = Compressor::new(CompressorConfig {
                rank: 6,
                sparsity: 0.1,
                depth: 3,
                min_leaf: 4,
                ..Default::default()
            });
            for m in [Method::Dense, Method::SSvd, Method::SHssRcm] {
                let c = comp.compress(&w, m);
                if let (Method::SHssRcm, CompressedMatrix::Hss { tree }) = (m, &c) {
                    if tree.depth() != 3 {
                        return Err(format!("want a depth-3 tree, got {}", tree.depth()));
                    }
                }
                let k = 1 + rng.below(9);
                // modest input scale keeps the float-reordering gap between
                // the dot and axpy kernels far inside the 1e-6 budget
                let mut x = Matrix::zeros(n, k);
                for v in x.data.iter_mut() {
                    *v = 0.1 * rng.gaussian_f32();
                }
                let mut y = Matrix::zeros(n, k);
                let mut ws = c.workspace_for(k);
                c.apply_batch(&x, &mut y, &mut ws);
                for col in 0..k {
                    let expect = c.matvec(&x.col(col));
                    slices_close(&y.col(col), &expect, 1e-6, 1e-6, &format!("{m:?} col {col}"))?;
                }
            }
            Ok(())
        });
    }

    /// Satellite property test: f16-resident `apply_batch` pins against
    /// the f32 reference for all three variants (permuted depth-3 HSS
    /// included). Two claims: (a) vs the *unquantized* f32 model the
    /// drift is bounded by the fp16 round-trip; (b) vs the f32 model with
    /// fp16-quantized values the result is bit-identical — the widened
    /// kernels change residency, not arithmetic.
    #[test]
    fn f16_apply_batch_matches_f32_reference_all_variants() {
        use crate::util::proptest::check;
        check(8, |rng| {
            let n = 48 + 16 * rng.below(2);
            let w = spiky(n, rng.next_u64());
            let comp = Compressor::new(CompressorConfig {
                rank: 6,
                sparsity: 0.1,
                depth: 3,
                min_leaf: 4,
                ..Default::default()
            });
            for m in [Method::Dense, Method::SSvd, Method::SHssRcm] {
                let c = comp.compress(&w, m);
                if let (Method::SHssRcm, CompressedMatrix::Hss { tree }) = (m, &c) {
                    if tree.depth() != 3 {
                        return Err(format!("want a depth-3 tree, got {}", tree.depth()));
                    }
                }
                let mut h = c.clone_shallow();
                h.narrow_to_f16();
                if h.weights_dtype() != crate::linalg::Dtype::F16 {
                    return Err(format!("{m:?}: narrow left dtype {}", h.weights_dtype()));
                }
                if 2 * h.resident_weight_bytes() != c.resident_weight_bytes() {
                    return Err(format!(
                        "{m:?}: resident {} !*2= {}",
                        h.resident_weight_bytes(),
                        c.resident_weight_bytes()
                    ));
                }
                // format accounting must not change with residency
                if h.params() != c.params() || h.bytes() != c.bytes() {
                    return Err(format!("{m:?}: narrow changed params/bytes accounting"));
                }

                let k = 1 + rng.below(9);
                let mut x = Matrix::zeros(n, k);
                for v in x.data.iter_mut() {
                    *v = 0.1 * rng.gaussian_f32();
                }
                let mut y32 = Matrix::zeros(n, k);
                let mut ws32 = c.workspace_for(k);
                c.apply_batch(&x, &mut y32, &mut ws32);
                let mut y16 = Matrix::zeros(n, k);
                let mut ws16 = h.workspace_for(k);
                h.apply_batch(&x, &mut y16, &mut ws16);

                // (a) fp16 round-trip tolerance vs the unquantized model
                slices_close(&y16.data, &y32.data, 2e-2, 2e-2, &format!("{m:?} f16 vs f32"))?;

                // (b) bit-identical to quantize-then-apply at f32 residency
                let mut q = h.clone_shallow();
                q.widen_to_f32();
                let mut yq = Matrix::zeros(n, k);
                let mut wsq = q.workspace_for(k);
                q.apply_batch(&x, &mut yq, &mut wsq);
                if yq.data != y16.data {
                    return Err(format!("{m:?}: f16 apply != quantized f32 apply (bitwise)"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn params_positive_and_sane() {
        let w = spiky(32, 5);
        let comp = Compressor::new(CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 2,
            ..Default::default()
        });
        for m in Method::ALL {
            let c = comp.compress(&w, m);
            assert!(c.params() > 0, "{m:?}");
            assert!(c.params() <= 2 * 32 * 32, "{m:?} params {}", c.params());
        }
    }
}
