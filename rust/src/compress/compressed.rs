//! The runtime representation every method compresses into, with the three
//! operations all experiments need: apply, storage, error.

use crate::hss::matvec::Workspace;
use crate::hss::storage::{INDEX_BYTES, VALUE_BYTES};
use crate::hss::HssNode;
use crate::linalg::norms::rel_fro_error;
use crate::linalg::Matrix;
use crate::sparse::Csr;

/// A compressed square matrix.
pub enum CompressedMatrix {
    /// the uncompressed baseline
    Dense { w: Matrix },
    /// (optionally sparse-plus-) low-rank: W ≈ S + L·R
    LowRank {
        l: Matrix,
        r: Matrix,
        sparse: Option<Csr>,
    },
    /// sparse-plus-HSS tree (sHSS / sHSS-RCM)
    Hss { tree: HssNode },
}

impl CompressedMatrix {
    pub fn n(&self) -> usize {
        match self {
            CompressedMatrix::Dense { w } => w.rows,
            CompressedMatrix::LowRank { l, .. } => l.rows,
            CompressedMatrix::Hss { tree } => tree.n(),
        }
    }

    /// y = W x.
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.n()];
        let mut ws = self.workspace();
        self.matvec_with(x, &mut y, &mut ws);
        y
    }

    /// Pre-sized scratch for allocation-free repeated applies.
    pub fn workspace(&self) -> ApplyWorkspace {
        match self {
            CompressedMatrix::Hss { tree } => ApplyWorkspace {
                hss: Workspace::for_node(tree),
                t: Vec::new(),
            },
            CompressedMatrix::LowRank { r, .. } => ApplyWorkspace {
                hss: Workspace::default(),
                t: vec![0.0; r.rows],
            },
            CompressedMatrix::Dense { .. } => ApplyWorkspace {
                hss: Workspace::default(),
                t: Vec::new(),
            },
        }
    }

    /// y = W x with reusable workspace (request-path form).
    pub fn matvec_with(&self, x: &[f32], y: &mut [f32], ws: &mut ApplyWorkspace) {
        match self {
            CompressedMatrix::Dense { w } => w.matvec_into(x, y),
            CompressedMatrix::LowRank { l, r, sparse } => {
                // y = L (R x) [+ S x]
                if ws.t.len() < r.rows {
                    ws.t.resize(r.rows, 0.0);
                }
                let t = &mut ws.t[..r.rows];
                r.matvec_into(x, t);
                l.matvec_into(t, y);
                if let Some(s) = sparse {
                    s.matvec_add(x, y);
                }
            }
            CompressedMatrix::Hss { tree } => tree.matvec_with(x, y, &mut ws.hss),
        }
    }

    /// Column-batched apply.
    pub fn matmat(&self, x_cols: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut ws = self.workspace();
        x_cols
            .iter()
            .map(|x| {
                let mut y = vec![0.0; self.n()];
                self.matvec_with(x, &mut y, &mut ws);
                y
            })
            .collect()
    }

    /// Dense matrix this representation stands for (testing/eval only).
    pub fn reconstruct(&self) -> Matrix {
        match self {
            CompressedMatrix::Dense { w } => w.clone(),
            CompressedMatrix::LowRank { l, r, sparse } => {
                let mut m = l.matmul(r);
                if let Some(s) = sparse {
                    m = m.add(&s.to_dense());
                }
                m
            }
            CompressedMatrix::Hss { tree } => tree.reconstruct(),
        }
    }

    /// Relative Frobenius reconstruction error vs the original.
    pub fn rel_error(&self, original: &Matrix) -> f64 {
        rel_fro_error(&self.reconstruct(), original)
    }

    /// Stored parameter count (values only).
    pub fn params(&self) -> usize {
        match self {
            CompressedMatrix::Dense { w } => w.data.len(),
            CompressedMatrix::LowRank { l, r, sparse } => {
                l.data.len() + r.data.len() + sparse.as_ref().map_or(0, |s| s.nnz())
            }
            CompressedMatrix::Hss { tree } => tree.storage().params,
        }
    }

    /// Total bytes at fp16 including index overhead.
    pub fn bytes(&self) -> usize {
        match self {
            CompressedMatrix::Dense { w } => w.data.len() * VALUE_BYTES,
            CompressedMatrix::LowRank { l, r, sparse } => {
                (l.data.len() + r.data.len()) * VALUE_BYTES
                    + sparse
                        .as_ref()
                        .map_or(0, |s| s.nnz() * (VALUE_BYTES + 2 * INDEX_BYTES))
            }
            CompressedMatrix::Hss { tree } => tree.storage().bytes,
        }
    }

    /// params / dense-params — the paper's storage axis (< 1 means
    /// compression). Use [`CompressedMatrix::bytes`] for the
    /// index-overhead-aware byte count.
    pub fn storage_ratio(&self) -> f64 {
        self.params() as f64 / (self.n() * self.n()) as f64
    }
}

/// Scratch reused across `matvec_with` calls.
pub struct ApplyWorkspace {
    hss: Workspace,
    t: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, CompressorConfig, Method};
    use crate::util::proptest::slices_close;
    use crate::util::rng::Rng;

    fn spiky(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut a = Matrix::randn(n, n, seed).scale(0.05);
        for _ in 0..2 * n {
            let i = rng.below(n);
            let j = rng.below(n);
            a.data[i * n + j] += rng.gaussian_f32();
        }
        a
    }

    #[test]
    fn lowrank_matvec_with_sparse() {
        let w = spiky(48, 1);
        let cfg = CompressorConfig {
            rank: 8,
            sparsity: 0.2,
            ..Default::default()
        };
        let c = Compressor::new(cfg).compress(&w, Method::SSvd);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..48).map(|_| rng.gaussian_f32()).collect();
        let expect = c.reconstruct().matvec(&x);
        slices_close(&c.matvec(&x), &expect, 1e-4, 1e-4, "ssvd matvec").unwrap();
    }

    #[test]
    fn storage_ordering_dense_vs_compressed() {
        let w = spiky(64, 3);
        let comp = Compressor::new(CompressorConfig {
            rank: 4,
            sparsity: 0.05,
            depth: 2,
            ..Default::default()
        });
        let dense = comp.compress(&w, Method::Dense);
        for m in [Method::Svd, Method::SSvd, Method::SHss, Method::SHssRcm] {
            let c = comp.compress(&w, m);
            assert!(
                c.bytes() < dense.bytes(),
                "{m:?} bytes {} !< dense {}",
                c.bytes(),
                dense.bytes()
            );
        }
    }

    #[test]
    fn workspace_reuse_stable() {
        let w = spiky(32, 4);
        let comp = Compressor::new(CompressorConfig {
            rank: 6,
            sparsity: 0.1,
            depth: 2,
            ..Default::default()
        });
        for m in Method::ALL {
            let c = comp.compress(&w, m);
            let mut ws = c.workspace();
            let x: Vec<f32> = (0..32).map(|i| (i as f32).cos()).collect();
            let mut y1 = vec![0.0; 32];
            let mut y2 = vec![0.0; 32];
            c.matvec_with(&x, &mut y1, &mut ws);
            c.matvec_with(&x, &mut y2, &mut ws);
            assert_eq!(y1, y2, "{m:?}");
        }
    }

    #[test]
    fn params_positive_and_sane() {
        let w = spiky(32, 5);
        let comp = Compressor::new(CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 2,
            ..Default::default()
        });
        for m in Method::ALL {
            let c = comp.compress(&w, m);
            assert!(c.params() > 0, "{m:?}");
            assert!(c.params() <= 2 * 32 * 32, "{m:?} params {}", c.params());
        }
    }
}
