//! Unified compression API over all methods the paper compares:
//! dense, SVD, R-SVD, sSVD, sR-SVD, sHSS, sHSS-RCM (§3–§4).
//!
//! [`Compressor::compress`] produces a [`CompressedMatrix`] exposing
//! `matvec`/`apply_batch`, storage accounting, and reconstruction error —
//! the three axes every experiment in §5 sweeps.

pub mod compressed;
pub mod config;
pub mod method;
pub mod pipeline;

pub use compressed::{BatchWorkspace, CompressedMatrix};
pub use config::CompressorConfig;
pub use method::Method;
pub use pipeline::{compress_model_qkv, LayerReport};

use crate::linalg::rsvd::{randomized_svd, RsvdOptions};
use crate::linalg::svd::truncated_svd;
use crate::linalg::Matrix;
use crate::sparse::{top_p_extract, Csr};

/// Factory for [`CompressedMatrix`] values under one [`CompressorConfig`].
#[derive(Clone, Debug, Default)]
pub struct Compressor {
    pub cfg: CompressorConfig,
}

impl Compressor {
    pub fn new(cfg: CompressorConfig) -> Compressor {
        Compressor { cfg }
    }

    /// Compress a square matrix with the chosen method.
    pub fn compress(&self, w: &Matrix, method: Method) -> CompressedMatrix {
        let cfg = &self.cfg;
        match method {
            Method::Dense => CompressedMatrix::Dense { w: w.clone() },
            Method::Svd => {
                let (l, r) = truncated_svd(w, cfg.rank, cfg.tol);
                CompressedMatrix::LowRank { l, r, sparse: None }
            }
            Method::Rsvd => {
                let (l, r) = randomized_svd(w, cfg.rank, cfg.tol, self.rsvd_opts());
                CompressedMatrix::LowRank { l, r, sparse: None }
            }
            Method::SSvd => {
                let (s, resid) = top_p_extract(w, cfg.sparsity);
                let (l, r) = truncated_svd(&resid, cfg.rank, cfg.tol);
                CompressedMatrix::LowRank {
                    l,
                    r,
                    sparse: Some(Csr::from_coo(&s)),
                }
            }
            Method::SRsvd => {
                let (s, resid) = top_p_extract(w, cfg.sparsity);
                let (l, r) = randomized_svd(&resid, cfg.rank, cfg.tol, self.rsvd_opts());
                CompressedMatrix::LowRank {
                    l,
                    r,
                    sparse: Some(Csr::from_coo(&s)),
                }
            }
            Method::SHss => CompressedMatrix::Hss {
                tree: crate::hss::build(w, &cfg.hss_options(false)),
            },
            Method::SHssRcm => CompressedMatrix::Hss {
                tree: crate::hss::build(w, &cfg.hss_options(true)),
            },
        }
    }

    fn rsvd_opts(&self) -> RsvdOptions {
        RsvdOptions {
            oversample: self.cfg.oversample,
            power_iters: self.cfg.power_iters,
            seed: self.cfg.seed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::rel_fro_error;
    use crate::util::rng::Rng;

    fn trained_like(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let u = Matrix::randn(n, 6, seed + 1);
        let v = Matrix::randn(6, n, seed + 2);
        let mut a = u.matmul(&v).scale(0.1);
        for _ in 0..3 * n {
            let i = rng.below(n);
            let j = rng.below(n);
            a.data[i * n + j] += 2.0 * rng.gaussian_f32();
        }
        a
    }

    #[test]
    fn all_methods_produce_working_matvec() {
        let w = trained_like(64, 1);
        let cfg = CompressorConfig {
            rank: 8,
            sparsity: 0.1,
            depth: 2,
            ..Default::default()
        };
        let comp = Compressor::new(cfg);
        let mut rng = Rng::new(2);
        let x: Vec<f32> = (0..64).map(|_| rng.gaussian_f32()).collect();
        for m in Method::ALL {
            let c = comp.compress(&w, m);
            let y = c.matvec(&x);
            assert_eq!(y.len(), 64, "{m:?}");
            assert!(y.iter().all(|v| v.is_finite()), "{m:?}");
        }
    }

    #[test]
    fn dense_method_is_exact() {
        let w = trained_like(32, 3);
        let c = Compressor::default().compress(&w, Method::Dense);
        assert!(c.rel_error(&w) < 1e-12);
        assert!((c.storage_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sparse_variants_beat_plain_on_spiky() {
        // spikes make plain SVD suffer; sparse extraction rescues it
        let w = trained_like(64, 4);
        let cfg = CompressorConfig {
            rank: 6,
            sparsity: 0.1,
            ..Default::default()
        };
        let comp = Compressor::new(cfg);
        let e_svd = comp.compress(&w, Method::Svd).rel_error(&w);
        let e_ssvd = comp.compress(&w, Method::SSvd).rel_error(&w);
        assert!(e_ssvd < e_svd, "sSVD {e_ssvd} vs SVD {e_svd}");
    }

    #[test]
    fn rsvd_close_to_svd() {
        let w = trained_like(48, 5);
        let cfg = CompressorConfig {
            rank: 8,
            power_iters: 2,
            oversample: 10,
            ..Default::default()
        };
        let comp = Compressor::new(cfg);
        let e_exact = comp.compress(&w, Method::SSvd).rel_error(&w);
        let e_rand = comp.compress(&w, Method::SRsvd).rel_error(&w);
        assert!(e_rand <= e_exact * 1.3 + 1e-4, "{e_rand} vs {e_exact}");
    }

    #[test]
    fn matvec_matches_reconstruction_for_all() {
        let w = trained_like(32, 6);
        let cfg = CompressorConfig {
            rank: 6,
            sparsity: 0.15,
            depth: 2,
            ..Default::default()
        };
        let comp = Compressor::new(cfg);
        let mut rng = Rng::new(7);
        let x: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
        for m in Method::ALL {
            let c = comp.compress(&w, m);
            let rec = c.reconstruct();
            let expect = rec.matvec(&x);
            let got = c.matvec(&x);
            for (a, b) in got.iter().zip(&expect) {
                assert!((a - b).abs() < 1e-3, "{m:?}: {a} vs {b}");
            }
            let _ = rel_fro_error(&rec, &w); // smoke: reconstruct well-formed
        }
    }
}
