//! Compression configuration shared across all methods.

use crate::hss::HssOptions;
use crate::linalg::rsvd::RsvdOptions;

/// Parameters sweeping the paper's experiment axes: rank, sparsity (sp10/
/// sp20/sp30), HSS depth, tolerance (fixed 1e-6 in the paper).
#[derive(Clone, Copy, Debug)]
pub struct CompressorConfig {
    /// outer rank k (512 at d=4096 in the paper ⇒ d/8 scaled here)
    pub rank: usize,
    /// fraction of entries carved into S (0.10 / 0.20 / 0.30 in the paper)
    pub sparsity: f64,
    /// §4.5-literal per-level re-extraction (ablation; see HssOptions)
    pub sparse_per_level: bool,
    /// HSS split levels (paper Algorithm 1 uses 3; Fig 2 reports depth 4)
    pub depth: usize,
    /// singular-value drop tolerance (paper: 1e-6)
    pub tol: f32,
    /// HSS recursion floor
    pub min_leaf: usize,
    /// |residual| quantile forming the RCM graph
    pub pattern_quantile: f64,
    /// randomized-SVD oversampling / power iterations / seed
    pub oversample: usize,
    pub power_iters: usize,
    pub seed: u64,
    /// use randomized SVD inside the HSS builder (paper §4.5)
    pub hss_rsvd: bool,
}

impl Default for CompressorConfig {
    fn default() -> Self {
        CompressorConfig {
            rank: 32,
            sparsity: 0.1,
            sparse_per_level: false,
            depth: 3,
            tol: 1e-6,
            min_leaf: 16,
            pattern_quantile: 0.90,
            oversample: 8,
            power_iters: 1,
            seed: 0x5EED,
            hss_rsvd: true,
        }
    }
}

impl CompressorConfig {
    pub fn hss_options(&self, use_rcm: bool) -> HssOptions {
        HssOptions {
            rank: self.rank,
            sparsity: self.sparsity,
            sparse_per_level: self.sparse_per_level,
            depth: self.depth,
            tol: self.tol,
            use_rcm,
            min_leaf: self.min_leaf,
            pattern_quantile: self.pattern_quantile,
            rsvd: self.hss_rsvd,
            rsvd_opts: RsvdOptions {
                oversample: self.oversample,
                power_iters: self.power_iters,
                seed: self.seed,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = CompressorConfig::default();
        assert_eq!(c.depth, 3);
        assert!((c.tol - 1e-6).abs() < 1e-12);
    }

    #[test]
    fn hss_options_propagate() {
        let c = CompressorConfig {
            rank: 64,
            sparsity: 0.3,
            depth: 4,
            ..Default::default()
        };
        let o = c.hss_options(true);
        assert_eq!(o.rank, 64);
        assert_eq!(o.depth, 4);
        assert!(o.use_rcm);
        assert!(!c.hss_options(false).use_rcm);
    }
}
