//! Permutations with the conventions the paper's matvec needs:
//! `apply` gathers (`y[i] = x[p[i]]`, i.e. the row permutation `P·x` where
//! `P = I[p, :]`), `apply_inv` scatters back (`y[p[i]] = x[i]`).

#[derive(Clone, Debug, PartialEq)]
pub struct Permutation {
    p: Vec<usize>,
}

impl Permutation {
    pub fn identity(n: usize) -> Permutation {
        Permutation {
            p: (0..n).collect(),
        }
    }

    /// Construct from indices; panics if not a valid permutation.
    pub fn from_vec(p: Vec<usize>) -> Permutation {
        let n = p.len();
        let mut seen = vec![false; n];
        for &i in &p {
            assert!(i < n && !seen[i], "invalid permutation");
            seen[i] = true;
        }
        Permutation { p }
    }

    pub fn len(&self) -> usize {
        self.p.len()
    }

    pub fn is_empty(&self) -> bool {
        self.p.is_empty()
    }

    pub fn indices(&self) -> &[usize] {
        &self.p
    }

    pub fn is_identity(&self) -> bool {
        self.p.iter().enumerate().all(|(i, &v)| i == v)
    }

    /// The inverse permutation q with q[p[i]] = i.
    pub fn inverse(&self) -> Permutation {
        let mut q = vec![0usize; self.p.len()];
        for (i, &v) in self.p.iter().enumerate() {
            q[v] = i;
        }
        Permutation { p: q }
    }

    /// Gather: y[i] = x[p[i]]  (this is x_shuffled = P x in the paper).
    pub fn apply<T: Copy>(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.p.len());
        self.p.iter().map(|&i| x[i]).collect()
    }

    /// Gather into a preallocated buffer.
    pub fn apply_into<T: Copy>(&self, x: &[T], y: &mut [T]) {
        assert_eq!(x.len(), self.p.len());
        assert_eq!(y.len(), self.p.len());
        for (yi, &i) in y.iter_mut().zip(&self.p) {
            *yi = x[i];
        }
    }

    /// Scatter: y[p[i]] = x[i]  (this is y = Pᵀ x_shuffled in the paper).
    pub fn apply_inv<T: Copy>(&self, x: &[T]) -> Vec<T>
    where
        T: Default + Clone,
    {
        assert_eq!(x.len(), self.p.len());
        let mut y = vec![T::default(); x.len()];
        self.apply_inv_into(x, &mut y);
        y
    }

    /// Scatter into a preallocated buffer.
    pub fn apply_inv_into<T: Copy>(&self, x: &[T], y: &mut [T]) {
        assert_eq!(x.len(), self.p.len());
        assert_eq!(y.len(), self.p.len());
        for (xi, &i) in x.iter().zip(&self.p) {
            y[i] = *xi;
        }
    }

    /// Row-gather over a row-major column block: `Y.row(i) = X.row(p[i])`
    /// for X, Y of shape [n, k] stored as length-n·k slices — the batched
    /// form of [`Permutation::apply_into`] the blocked HSS traversal uses
    /// to permute all k batch columns in one pass.
    pub fn apply_cols_into<T: Copy>(&self, x: &[T], y: &mut [T], k: usize) {
        let n = self.p.len();
        assert_eq!(x.len(), n * k, "input block shape mismatch");
        assert_eq!(y.len(), n * k, "output block shape mismatch");
        for (i, &src) in self.p.iter().enumerate() {
            y[i * k..(i + 1) * k].copy_from_slice(&x[src * k..(src + 1) * k]);
        }
    }

    /// Row-scatter over a row-major column block: `Y.row(p[i]) = X.row(i)`
    /// — the batched form of [`Permutation::apply_inv_into`].
    pub fn apply_inv_cols_into<T: Copy>(&self, x: &[T], y: &mut [T], k: usize) {
        let n = self.p.len();
        assert_eq!(x.len(), n * k, "input block shape mismatch");
        assert_eq!(y.len(), n * k, "output block shape mismatch");
        for (i, &dst) in self.p.iter().enumerate() {
            y[dst * k..(dst + 1) * k].copy_from_slice(&x[i * k..(i + 1) * k]);
        }
    }

    /// Compose: (self ∘ other)(x) == self.apply(other.apply(x)).
    pub fn compose(&self, other: &Permutation) -> Permutation {
        assert_eq!(self.len(), other.len());
        // (self∘other).apply(x)[i] = other.apply(x)[self.p[i]] = x[other.p[self.p[i]]]
        Permutation {
            p: self.p.iter().map(|&i| other.p[i]).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn random_perm(rng: &mut Rng, n: usize) -> Permutation {
        let mut p: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut p);
        Permutation::from_vec(p)
    }

    #[test]
    fn apply_then_inverse_is_identity() {
        check(20, |rng| {
            let n = 1 + rng.below(64);
            let p = random_perm(rng, n);
            let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let y = p.apply_inv(&p.apply(&x));
            if y == x {
                Ok(())
            } else {
                Err("p⁻¹(p(x)) != x".into())
            }
        });
    }

    #[test]
    fn inverse_matches_apply_inv() {
        check(20, |rng| {
            let n = 1 + rng.below(32);
            let p = random_perm(rng, n);
            let x: Vec<f32> = (0..n).map(|i| (i * i) as f32).collect();
            if p.inverse().apply(&x) == p.apply_inv(&x) {
                Ok(())
            } else {
                Err("inverse().apply != apply_inv".into())
            }
        });
    }

    #[test]
    fn compose_semantics() {
        check(20, |rng| {
            let n = 2 + rng.below(20);
            let p = random_perm(rng, n);
            let q = random_perm(rng, n);
            let x: Vec<u32> = (0..n as u32).collect();
            let via_compose = p.compose(&q).apply(&x);
            let via_seq = p.apply(&q.apply(&x));
            if via_compose == via_seq {
                Ok(())
            } else {
                Err("compose mismatch".into())
            }
        });
    }

    #[test]
    fn cols_roundtrip_and_match_per_column_apply() {
        check(20, |rng| {
            let n = 1 + rng.below(32);
            let k = 1 + rng.below(8);
            let p = random_perm(rng, n);
            let x: Vec<f32> = (0..n * k).map(|i| i as f32).collect();
            let mut shuffled = vec![0.0f32; n * k];
            p.apply_cols_into(&x, &mut shuffled, k);
            // column c of the block permutes exactly like a lone vector
            for c in 0..k {
                let col: Vec<f32> = (0..n).map(|i| x[i * k + c]).collect();
                let expect = p.apply(&col);
                for i in 0..n {
                    if shuffled[i * k + c] != expect[i] {
                        return Err(format!("apply_cols[{i},{c}] mismatch"));
                    }
                }
            }
            // scatter undoes gather: apply_inv_cols(apply_cols(x)) == x
            let mut back = vec![0.0f32; n * k];
            p.apply_inv_cols_into(&shuffled, &mut back, k);
            if back != x {
                return Err("apply_inv_cols(apply_cols(x)) != x".into());
            }
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "invalid permutation")]
    fn rejects_duplicates() {
        Permutation::from_vec(vec![0, 0, 1]);
    }

    #[test]
    fn identity_checks() {
        let id = Permutation::identity(5);
        assert!(id.is_identity());
        assert_eq!(id.inverse(), id);
    }
}
