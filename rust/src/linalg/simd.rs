//! Explicit-width SIMD kernel layer with runtime CPU dispatch.
//!
//! Every hot kernel in the serving path (`apply_batch_*`, `spmm_add`,
//! `gemm_nt_add`, attention score/softmax·V, f16 widening, the fused
//! residual+layernorm epilogue) routes through the fn-pointer table
//! returned by [`kernels()`]. The table is selected once, lazily, from
//! runtime CPU detection:
//!
//! * **x86_64** — AVX2 arms, taken when `avx2`, `fma` and `f16c` are all
//!   detected (`is_x86_feature_detected!`). FMA presence gates the level
//!   but the arms deliberately use separate mul+add so results stay
//!   bit-identical to the scalar fallback (see the ULP contract below).
//! * **aarch64** — NEON arms for the mul/add kernels; the transcendental
//!   and widening entries reuse the scalar arms (bit-compatible by
//!   construction).
//! * **anywhere else / `HISOLO_SIMD=off`** — the scalar arms, which are
//!   the always-correct reference implementation.
//!
//! # ULP contract
//!
//! Every SIMD arm is **bit-identical** to its scalar arm (0 ULP), not
//! merely close. This is load-bearing: the repo's test suite pins
//! f16-resident kernels bitwise against quantized-f32, staged against
//! unstaged, and batched attention against the per-window loop — a
//! kernel arm that reassociates differently per level would make those
//! contracts level-dependent. The rules that make 0 ULP hold:
//!
//! * no FMA contraction — every arm does separate mul then add;
//! * reductions use a fixed 8-lane accumulator shape mirrored by the
//!   scalar arm, folded by the shared [`hsum8_tree`], with remainder
//!   elements added sequentially *after* the tree;
//! * `exp` is the same polynomial (magic-number round-to-nearest-even,
//!   Cody–Waite argument reduction, degree-5 Horner) evaluated with the
//!   same operation order in both arms;
//! * f16→f32 widening via F16C `VCVTPH2PS` matches the software codec in
//!   `util::fp16` for all 65536 bit patterns (both quiet NaNs by setting
//!   the same bit and preserve payloads; an exhaustive test pins this).
//!
//! Because the arms are interchangeable bit-for-bit, [`force_level`] is
//! a sound public hook: benches race Scalar vs the detected best, and
//! the `HISOLO_SIMD=off` env override simply pins the scalar table.
//!
//! # How to add an arch
//!
//! 1. Add a [`SimdLevel`] variant and a `static` [`KernelDispatch`]
//!    table for it. Partial tables are fine — point entries you have not
//!    vectorized at the scalar arms (the NEON table does this for
//!    `exp_softmax_row`, `widen_f16_lanes` and `layernorm_row`).
//! 2. Mirror the scalar arm structure exactly: 8-lane accumulators,
//!    mul+add (no FMA), tree-then-tail reduction. Run the arm-equality
//!    property tests below on real hardware before enabling detection.
//! 3. Wire detection into `detect_level()` behind `cfg(target_arch)`.
//!
//! Lane width is pinned at [`LANES`] = 8 f32 lanes (one AVX2 vector, two
//! NEON vectors); [`padded_k`] rounds batch widths up so the k-lane
//! loops carry no scalar tail.

use std::sync::atomic::{AtomicU8, Ordering};

/// Pinned f32 lane count of the kernel layer (one AVX2 register).
pub const LANES: usize = 8;

/// Chunk size (in elements) used by callers that stage f16 weights into
/// f32 stack buffers between kernel calls. A multiple of [`LANES`], so
/// chunk boundaries never split an 8-lane group and chunked reductions
/// are bit-identical to one full-slice pass.
pub const DOT_CHUNK: usize = 256;

/// Round a batch width up to the lane multiple so the k-lane loops have
/// no scalar tail. Width 0/1 is left alone: the k = 1 path is the
/// dedicated matvec code, not the lane loop.
#[inline]
pub fn padded_k(k: usize) -> usize {
    if k <= 1 {
        k
    } else {
        k.div_ceil(LANES) * LANES
    }
}

/// Instruction-set level of the active dispatch table.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SimdLevel {
    /// Portable scalar arms — the reference implementation.
    Scalar,
    /// x86_64 AVX2 (+F16C widening; FMA detected but unused, see docs).
    Avx2,
    /// aarch64 NEON (mul/add kernels; transcendentals use scalar arms).
    Neon,
}

impl SimdLevel {
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    fn code(self) -> u8 {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Avx2 => 2,
            SimdLevel::Neon => 3,
        }
    }

    fn from_code(c: u8) -> SimdLevel {
        match c {
            2 => SimdLevel::Avx2,
            3 => SimdLevel::Neon,
            _ => SimdLevel::Scalar,
        }
    }
}

/// The dispatch table: one safe fn pointer per kernel. Selected once per
/// process (or overridden via [`force_level`]); call sites fetch it once
/// per outer kernel call, not per inner iteration.
pub struct KernelDispatch {
    pub level: SimdLevel,
    /// `acc[l] += a[i*8+l] * b[i*8+l]` over the multiple-of-8 prefix of
    /// `a`/`b` (callers pass multiple-of-8 slices). The accumulator is
    /// carried across calls so chunked staging reduces identically to
    /// one pass; fold with [`hsum8_tree`], then add tail elements
    /// sequentially.
    pub dot8_acc: fn(&[f32], &[f32], &mut [f32; 8]),
    /// Four simultaneous `dot8_acc` against four B rows sharing one A
    /// row: `acc[j][l] += a[i*8+l] * b[j][i*8+l]`. Each column's
    /// accumulator is bit-identical to a standalone `dot8_acc`.
    pub gemm_nt_microkernel: fn(&[f32], [&[f32]; 4], &mut [[f32; 8]; 4]),
    /// `y[i] += a * x[i]` (element-independent, so any arm is bitwise).
    pub axpy_k: fn(f32, &[f32], &mut [f32]),
    /// Four fused axpys from four consecutive stride-`k` rows of `x4`:
    /// `y[i] += (c0*x0[i] + c1*x1[i]) + (c2*x2[i] + c3*x3[i])` — the
    /// pairwise sum order is part of the contract.
    pub axpy4_k: fn(&[f32; 4], &[f32], usize, &mut [f32]),
    /// `y[i] += x[i]`.
    pub add_k: fn(&[f32], &mut [f32]),
    /// f16 bits → f32, one output per input (`dst.len() == src.len()`).
    /// The single widening primitive: every f16 call-site pattern
    /// (inline lane widening, staging, CSR value runs) routes here.
    pub widen_f16_lanes: fn(&[u16], &mut [f32]),
    /// In-place fused softmax over one score row: scale, subtract the
    /// row max, exponentiate (polynomial exp, flush below ≈ −87.33),
    /// normalize. Inputs must be finite (attention scores are).
    pub exp_softmax_row: fn(&mut [f32], f32),
    /// One layernorm row: mean/variance via the 8-lane tree reduction,
    /// then `out[j] = (row[j] - mu) * inv * g[j] + b[j]` with
    /// `inv = 1/sqrt(var + eps)`.
    pub layernorm_row: fn(&[f32], &[f32], &[f32], f32, &mut [f32]),
}

/// Fold the 8-lane accumulator with the canonical pairwise tree. The
/// tree shape is fixed and shared by every arm:
/// `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`.
#[inline]
pub fn hsum8_tree(acc: &[f32; 8]) -> f32 {
    ((acc[0] + acc[4]) + (acc[2] + acc[6])) + ((acc[1] + acc[5]) + (acc[3] + acc[7]))
}

/// Full dot product of two f32 slices via the dispatched `dot8_acc`:
/// 8-lane accumulation over the lane prefix, tree fold, sequential tail.
#[inline]
pub fn dot_k(a: &[f32], b: &[f32]) -> f32 {
    let kt = kernels();
    let n = a.len().min(b.len());
    let n8 = n / LANES * LANES;
    let mut acc = [0.0f32; 8];
    (kt.dot8_acc)(&a[..n8], &b[..n8], &mut acc);
    let mut total = hsum8_tree(&acc);
    for i in n8..n {
        total += a[i] * b[i];
    }
    total
}

/// Dispatched `y += a * x` over `min(x.len(), y.len())` elements.
#[inline]
pub fn axpy_k(a: f32, x: &[f32], y: &mut [f32]) {
    (kernels().axpy_k)(a, x, y)
}

// --- dispatch state -------------------------------------------------------

/// 0 = uninitialized; otherwise `SimdLevel::code()`.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

fn detect_level() -> SimdLevel {
    if let Ok(v) = std::env::var("HISOLO_SIMD") {
        let v = v.to_ascii_lowercase();
        if v == "off" || v == "0" || v == "scalar" {
            return SimdLevel::Scalar;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
            && is_x86_feature_detected!("f16c")
        {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is baseline on aarch64.
        return SimdLevel::Neon;
    }
    #[allow(unreachable_code)]
    SimdLevel::Scalar
}

fn level_supported(l: SimdLevel) -> bool {
    match l {
        SimdLevel::Scalar => true,
        SimdLevel::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                is_x86_feature_detected!("avx2")
                    && is_x86_feature_detected!("fma")
                    && is_x86_feature_detected!("f16c")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        SimdLevel::Neon => cfg!(target_arch = "aarch64"),
    }
}

/// The active dispatch level (detects on first call, honouring the
/// `HISOLO_SIMD` env override: `off`/`0`/`scalar` pins the fallback).
pub fn active_level() -> SimdLevel {
    let c = ACTIVE.load(Ordering::Relaxed);
    if c != 0 {
        return SimdLevel::from_code(c);
    }
    let l = detect_level();
    // Racing initializers all compute the same value; last store wins.
    ACTIVE.store(l.code(), Ordering::Relaxed);
    l
}

/// Force a specific dispatch level; returns the previous one so callers
/// can restore it. Requests for a level the CPU does not support are
/// ignored (the previous level stays active). Sound to flip at any time
/// because every arm is bit-identical (see the module ULP contract) —
/// the benches use this to race Scalar against the detected best.
pub fn force_level(l: SimdLevel) -> SimdLevel {
    let prev = active_level();
    if level_supported(l) {
        ACTIVE.store(l.code(), Ordering::Relaxed);
    }
    prev
}

/// The active kernel table. Fetch once per outer kernel call.
pub fn kernels() -> &'static KernelDispatch {
    match active_level() {
        SimdLevel::Scalar => &SCALAR,
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => &avx2::TABLE,
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => &neon::TABLE,
        #[allow(unreachable_patterns)]
        _ => &SCALAR,
    }
}

// --- shared exp polynomial constants --------------------------------------
// Degree-5 polynomial exp (avx_mathfun lineage): magic-number RNE, two-term
// Cody–Waite reduction, Horner evaluation. Both arms use these constants
// with the identical operation order.

#[allow(clippy::excessive_precision)]
mod expc {
    pub const LOG2E: f32 = 1.44269504088896341;
    pub const C1: f32 = 0.693359375;
    pub const C2: f32 = -2.12194440e-4;
    pub const P0: f32 = 1.9875691500e-4;
    pub const P1: f32 = 1.3981999507e-3;
    pub const P2: f32 = 8.3334519073e-3;
    pub const P3: f32 = 4.1665795894e-2;
    pub const P4: f32 = 1.6666665459e-1;
    pub const P5: f32 = 5.0000001201e-1;
    /// 1.5 * 2^23: adding then subtracting rounds to the nearest integer
    /// (ties to even) for |v| < 2^22.
    pub const MAGIC: f32 = 12582912.0;
    /// Below this, exp underflows past subnormals; lanes flush to zero.
    pub const LO: f32 = -87.33655;
}

/// Scalar polynomial exp — the per-element formula both arms evaluate.
/// Valid for finite x ≤ 0 (softmax feeds `x - max`); flushes to 0 below
/// [`expc::LO`]. Mul+add only, so the AVX2 lanes reproduce it exactly.
#[inline]
fn exp_poly(x: f32) -> f32 {
    use expc::*;
    if x < LO {
        return 0.0;
    }
    let t = x * LOG2E + MAGIC;
    let n = t - MAGIC;
    let r = (x - n * C1) - n * C2;
    let mut y = P0;
    y = y * r + P1;
    y = y * r + P2;
    y = y * r + P3;
    y = y * r + P4;
    y = y * r + P5;
    y = y * (r * r) + r;
    y += 1.0;
    let pow2n = f32::from_bits((((n as i32) + 127) << 23) as u32);
    y * pow2n
}

// --- scalar arms ----------------------------------------------------------
// Written to mirror the SIMD lane structure exactly: 8-lane groups, the
// same pairwise sum orders, tree-then-tail reductions.

fn dot8_acc_scalar(a: &[f32], b: &[f32], acc: &mut [f32; 8]) {
    let n = a.len().min(b.len()) / LANES * LANES;
    let mut i = 0;
    while i < n {
        for l in 0..LANES {
            acc[l] += a[i + l] * b[i + l];
        }
        i += LANES;
    }
}

fn gemm_nt_microkernel_scalar(a: &[f32], b: [&[f32]; 4], acc: &mut [[f32; 8]; 4]) {
    let n = a.len() / LANES * LANES;
    let mut i = 0;
    while i < n {
        for (j, bj) in b.iter().enumerate() {
            for l in 0..LANES {
                acc[j][l] += a[i + l] * bj[i + l];
            }
        }
        i += LANES;
    }
}

fn axpy_k_scalar(a: f32, x: &[f32], y: &mut [f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

fn axpy4_k_scalar(c: &[f32; 4], x4: &[f32], k: usize, y: &mut [f32]) {
    let x0 = &x4[..k];
    let x1 = &x4[k..2 * k];
    let x2 = &x4[2 * k..3 * k];
    let x3 = &x4[3 * k..4 * k];
    for (i, yi) in y.iter_mut().enumerate().take(k) {
        let t01 = c[0] * x0[i] + c[1] * x1[i];
        let t23 = c[2] * x2[i] + c[3] * x3[i];
        *yi += t01 + t23;
    }
}

fn add_k_scalar(x: &[f32], y: &mut [f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += xi;
    }
}

fn widen_f16_lanes_scalar(src: &[u16], dst: &mut [f32]) {
    for (d, &h) in dst.iter_mut().zip(src) {
        *d = crate::util::fp16::f16_to_f32(h);
    }
}

fn exp_softmax_row_scalar(p: &mut [f32], scale: f32) {
    if p.is_empty() {
        return;
    }
    for v in p.iter_mut() {
        *v *= scale;
    }
    // Row max: max is order-insensitive for finite inputs, so a simple
    // sequential scan matches the vector lane+reduce result.
    let mut m = f32::NEG_INFINITY;
    for &v in p.iter() {
        m = if m > v { m } else { v };
    }
    // exp + sum: 8-lane accumulators over the lane prefix, tree fold,
    // sequential tail — mirrors the AVX2 arm.
    let n8 = p.len() / LANES * LANES;
    let mut acc = [0.0f32; 8];
    let mut i = 0;
    while i < n8 {
        for l in 0..LANES {
            let e = exp_poly(p[i + l] - m);
            p[i + l] = e;
            acc[l] += e;
        }
        i += LANES;
    }
    let mut denom = hsum8_tree(&acc);
    for v in p[n8..].iter_mut() {
        let e = exp_poly(*v - m);
        *v = e;
        denom += e;
    }
    let inv = 1.0 / denom;
    for v in p.iter_mut() {
        *v *= inv;
    }
}

fn layernorm_row_scalar(row: &[f32], g: &[f32], b: &[f32], eps: f32, out: &mut [f32]) {
    let n = row.len();
    let n8 = n / LANES * LANES;
    let mut acc = [0.0f32; 8];
    let mut i = 0;
    while i < n8 {
        for l in 0..LANES {
            acc[l] += row[i + l];
        }
        i += LANES;
    }
    let mut sum = hsum8_tree(&acc);
    for &v in &row[n8..] {
        sum += v;
    }
    let mu = sum / n as f32;
    let mut vacc = [0.0f32; 8];
    i = 0;
    while i < n8 {
        for l in 0..LANES {
            let d = row[i + l] - mu;
            vacc[l] += d * d;
        }
        i += LANES;
    }
    let mut vsum = hsum8_tree(&vacc);
    for &v in &row[n8..] {
        let d = v - mu;
        vsum += d * d;
    }
    let var = vsum / n as f32;
    let inv = 1.0 / (var + eps).sqrt();
    for j in 0..n {
        out[j] = (row[j] - mu) * inv * g[j] + b[j];
    }
}

static SCALAR: KernelDispatch = KernelDispatch {
    level: SimdLevel::Scalar,
    dot8_acc: dot8_acc_scalar,
    gemm_nt_microkernel: gemm_nt_microkernel_scalar,
    axpy_k: axpy_k_scalar,
    axpy4_k: axpy4_k_scalar,
    add_k: add_k_scalar,
    widen_f16_lanes: widen_f16_lanes_scalar,
    exp_softmax_row: exp_softmax_row_scalar,
    layernorm_row: layernorm_row_scalar,
};

// --- AVX2 arms ------------------------------------------------------------
// Every arm mirrors its scalar twin operation-for-operation: separate
// mul+add (no FMA contraction), the same 8-lane accumulator shapes, and
// scalar tails that reuse the exact scalar expressions. The `unsafe fn`s
// carry `#[target_feature]`; the safe wrappers installed in the table are
// sound because the table is only selected when detection succeeded.

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::*;
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    unsafe fn dot8_acc_impl(a: &[f32], b: &[f32], acc: &mut [f32; 8]) {
        let n = a.len().min(b.len()) / LANES * LANES;
        let mut av = _mm256_loadu_ps(acc.as_ptr());
        let mut i = 0;
        while i < n {
            let x = _mm256_loadu_ps(a.as_ptr().add(i));
            let y = _mm256_loadu_ps(b.as_ptr().add(i));
            av = _mm256_add_ps(av, _mm256_mul_ps(x, y));
            i += LANES;
        }
        _mm256_storeu_ps(acc.as_mut_ptr(), av);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn gemm_nt_microkernel_impl(a: &[f32], b: [&[f32]; 4], acc: &mut [[f32; 8]; 4]) {
        let n = a.len() / LANES * LANES;
        let mut c0 = _mm256_loadu_ps(acc[0].as_ptr());
        let mut c1 = _mm256_loadu_ps(acc[1].as_ptr());
        let mut c2 = _mm256_loadu_ps(acc[2].as_ptr());
        let mut c3 = _mm256_loadu_ps(acc[3].as_ptr());
        let mut i = 0;
        while i < n {
            let av = _mm256_loadu_ps(a.as_ptr().add(i));
            c0 = _mm256_add_ps(c0, _mm256_mul_ps(av, _mm256_loadu_ps(b[0].as_ptr().add(i))));
            c1 = _mm256_add_ps(c1, _mm256_mul_ps(av, _mm256_loadu_ps(b[1].as_ptr().add(i))));
            c2 = _mm256_add_ps(c2, _mm256_mul_ps(av, _mm256_loadu_ps(b[2].as_ptr().add(i))));
            c3 = _mm256_add_ps(c3, _mm256_mul_ps(av, _mm256_loadu_ps(b[3].as_ptr().add(i))));
            i += LANES;
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), c0);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), c1);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), c2);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), c3);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn axpy_k_impl(a: f32, x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let n8 = n / LANES * LANES;
        let av = _mm256_set1_ps(a);
        let mut i = 0;
        while i < n8 {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            let r = _mm256_add_ps(yv, _mm256_mul_ps(av, xv));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), r);
            i += LANES;
        }
        while i < n {
            y[i] += a * x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn axpy4_k_impl(c: &[f32; 4], x4: &[f32], k: usize, y: &mut [f32]) {
        let n = k.min(y.len());
        let n8 = n / LANES * LANES;
        let c0 = _mm256_set1_ps(c[0]);
        let c1 = _mm256_set1_ps(c[1]);
        let c2 = _mm256_set1_ps(c[2]);
        let c3 = _mm256_set1_ps(c[3]);
        let x0 = x4.as_ptr();
        let x1 = x4.as_ptr().add(k);
        let x2 = x4.as_ptr().add(2 * k);
        let x3 = x4.as_ptr().add(3 * k);
        let mut i = 0;
        while i < n8 {
            let t01 = _mm256_add_ps(
                _mm256_mul_ps(c0, _mm256_loadu_ps(x0.add(i))),
                _mm256_mul_ps(c1, _mm256_loadu_ps(x1.add(i))),
            );
            let t23 = _mm256_add_ps(
                _mm256_mul_ps(c2, _mm256_loadu_ps(x2.add(i))),
                _mm256_mul_ps(c3, _mm256_loadu_ps(x3.add(i))),
            );
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, _mm256_add_ps(t01, t23)));
            i += LANES;
        }
        while i < n {
            let t01 = c[0] * x4[i] + c[1] * x4[k + i];
            let t23 = c[2] * x4[2 * k + i] + c[3] * x4[3 * k + i];
            y[i] += t01 + t23;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn add_k_impl(x: &[f32], y: &mut [f32]) {
        let n = x.len().min(y.len());
        let n8 = n / LANES * LANES;
        let mut i = 0;
        while i < n8 {
            let xv = _mm256_loadu_ps(x.as_ptr().add(i));
            let yv = _mm256_loadu_ps(y.as_ptr().add(i));
            _mm256_storeu_ps(y.as_mut_ptr().add(i), _mm256_add_ps(yv, xv));
            i += LANES;
        }
        while i < n {
            y[i] += x[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2,f16c")]
    unsafe fn widen_f16_lanes_impl(src: &[u16], dst: &mut [f32]) {
        let n = src.len().min(dst.len());
        let n8 = n / LANES * LANES;
        let mut i = 0;
        while i < n8 {
            let h = _mm_loadu_si128(src.as_ptr().add(i) as *const __m128i);
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_cvtph_ps(h));
            i += LANES;
        }
        while i < n {
            dst[i] = crate::util::fp16::f16_to_f32(src[i]);
            i += 1;
        }
    }

    /// Eight-lane polynomial exp — same constants and operation order as
    /// the scalar `exp_poly`, lanes below `expc::LO` masked to zero.
    #[target_feature(enable = "avx2")]
    unsafe fn exp8(x: __m256) -> __m256 {
        use super::expc::*;
        let t = _mm256_add_ps(_mm256_mul_ps(x, _mm256_set1_ps(LOG2E)), _mm256_set1_ps(MAGIC));
        let n = _mm256_sub_ps(t, _mm256_set1_ps(MAGIC));
        let r = _mm256_sub_ps(
            _mm256_sub_ps(x, _mm256_mul_ps(n, _mm256_set1_ps(C1))),
            _mm256_mul_ps(n, _mm256_set1_ps(C2)),
        );
        let mut y = _mm256_set1_ps(P0);
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(P1));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(P2));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(P3));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(P4));
        y = _mm256_add_ps(_mm256_mul_ps(y, r), _mm256_set1_ps(P5));
        let r2 = _mm256_mul_ps(r, r);
        y = _mm256_add_ps(_mm256_mul_ps(y, r2), r);
        y = _mm256_add_ps(y, _mm256_set1_ps(1.0));
        let ni = _mm256_cvtps_epi32(n);
        let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
            ni,
            _mm256_set1_epi32(127),
        )));
        let e = _mm256_mul_ps(y, pow2);
        let flush = _mm256_cmp_ps::<_CMP_LT_OQ>(x, _mm256_set1_ps(LO));
        _mm256_andnot_ps(flush, e)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn exp_softmax_row_impl(p: &mut [f32], scale: f32) {
        if p.is_empty() {
            return;
        }
        let n = p.len();
        let n8 = n / LANES * LANES;
        let sv = _mm256_set1_ps(scale);
        let mut i = 0;
        while i < n8 {
            let v = _mm256_loadu_ps(p.as_ptr().add(i));
            _mm256_storeu_ps(p.as_mut_ptr().add(i), _mm256_mul_ps(v, sv));
            i += LANES;
        }
        while i < n {
            p[i] *= scale;
            i += 1;
        }
        let mut m = f32::NEG_INFINITY;
        let mut mv = _mm256_set1_ps(f32::NEG_INFINITY);
        i = 0;
        while i < n8 {
            mv = _mm256_max_ps(mv, _mm256_loadu_ps(p.as_ptr().add(i)));
            i += LANES;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), mv);
        for &l in &lanes {
            m = if m > l { m } else { l };
        }
        while i < n {
            let v = p[i];
            m = if m > v { m } else { v };
            i += 1;
        }
        let mvv = _mm256_set1_ps(m);
        let mut acc = _mm256_setzero_ps();
        i = 0;
        while i < n8 {
            let x = _mm256_sub_ps(_mm256_loadu_ps(p.as_ptr().add(i)), mvv);
            let e = exp8(x);
            _mm256_storeu_ps(p.as_mut_ptr().add(i), e);
            acc = _mm256_add_ps(acc, e);
            i += LANES;
        }
        let mut accs = [0.0f32; 8];
        _mm256_storeu_ps(accs.as_mut_ptr(), acc);
        let mut denom = hsum8_tree(&accs);
        while i < n {
            let e = exp_poly(p[i] - m);
            p[i] = e;
            denom += e;
            i += 1;
        }
        let inv = 1.0 / denom;
        let iv = _mm256_set1_ps(inv);
        i = 0;
        while i < n8 {
            let v = _mm256_loadu_ps(p.as_ptr().add(i));
            _mm256_storeu_ps(p.as_mut_ptr().add(i), _mm256_mul_ps(v, iv));
            i += LANES;
        }
        while i < n {
            p[i] *= inv;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn layernorm_row_impl(row: &[f32], g: &[f32], b: &[f32], eps: f32, out: &mut [f32]) {
        let n = row.len();
        let n8 = n / LANES * LANES;
        let mut accv = _mm256_setzero_ps();
        let mut i = 0;
        while i < n8 {
            accv = _mm256_add_ps(accv, _mm256_loadu_ps(row.as_ptr().add(i)));
            i += LANES;
        }
        let mut accs = [0.0f32; 8];
        _mm256_storeu_ps(accs.as_mut_ptr(), accv);
        let mut sum = hsum8_tree(&accs);
        while i < n {
            sum += row[i];
            i += 1;
        }
        let mu = sum / n as f32;
        let muv = _mm256_set1_ps(mu);
        let mut vaccv = _mm256_setzero_ps();
        i = 0;
        while i < n8 {
            let d = _mm256_sub_ps(_mm256_loadu_ps(row.as_ptr().add(i)), muv);
            vaccv = _mm256_add_ps(vaccv, _mm256_mul_ps(d, d));
            i += LANES;
        }
        let mut vaccs = [0.0f32; 8];
        _mm256_storeu_ps(vaccs.as_mut_ptr(), vaccv);
        let mut vsum = hsum8_tree(&vaccs);
        while i < n {
            let d = row[i] - mu;
            vsum += d * d;
            i += 1;
        }
        let var = vsum / n as f32;
        let inv = 1.0 / (var + eps).sqrt();
        let iv = _mm256_set1_ps(inv);
        i = 0;
        while i < n8 {
            let d = _mm256_sub_ps(_mm256_loadu_ps(row.as_ptr().add(i)), muv);
            let scaled = _mm256_mul_ps(_mm256_mul_ps(d, iv), _mm256_loadu_ps(g.as_ptr().add(i)));
            let r = _mm256_add_ps(scaled, _mm256_loadu_ps(b.as_ptr().add(i)));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += LANES;
        }
        while i < n {
            out[i] = (row[i] - mu) * inv * g[i] + b[i];
            i += 1;
        }
    }

    // Safe wrappers: only reachable through TABLE, which `kernels()`
    // returns only after runtime detection confirmed avx2+fma+f16c.
    fn dot8_acc(a: &[f32], b: &[f32], acc: &mut [f32; 8]) {
        unsafe { dot8_acc_impl(a, b, acc) }
    }
    fn gemm_nt_microkernel(a: &[f32], b: [&[f32]; 4], acc: &mut [[f32; 8]; 4]) {
        unsafe { gemm_nt_microkernel_impl(a, b, acc) }
    }
    fn axpy_k(a: f32, x: &[f32], y: &mut [f32]) {
        unsafe { axpy_k_impl(a, x, y) }
    }
    fn axpy4_k(c: &[f32; 4], x4: &[f32], k: usize, y: &mut [f32]) {
        unsafe { axpy4_k_impl(c, x4, k, y) }
    }
    fn add_k(x: &[f32], y: &mut [f32]) {
        unsafe { add_k_impl(x, y) }
    }
    fn widen_f16_lanes(src: &[u16], dst: &mut [f32]) {
        unsafe { widen_f16_lanes_impl(src, dst) }
    }
    fn exp_softmax_row(p: &mut [f32], scale: f32) {
        unsafe { exp_softmax_row_impl(p, scale) }
    }
    fn layernorm_row(row: &[f32], g: &[f32], b: &[f32], eps: f32, out: &mut [f32]) {
        unsafe { layernorm_row_impl(row, g, b, eps, out) }
    }

    pub(super) static TABLE: KernelDispatch = KernelDispatch {
        level: SimdLevel::Avx2,
        dot8_acc,
        gemm_nt_microkernel,
        axpy_k,
        axpy4_k,
        add_k,
        widen_f16_lanes,
        exp_softmax_row,
        layernorm_row,
    };
}

// --- NEON arms ------------------------------------------------------------
// Only the pure mul/add kernels are vectorized; the transcendental and
// widening entries point at the scalar arms (bit-compatible by
// definition — see "How to add an arch" in the module docs). NEON is
// baseline on aarch64, so the intrinsic calls are always valid there.

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::*;
    use std::arch::aarch64::*;

    fn dot8_acc(a: &[f32], b: &[f32], acc: &mut [f32; 8]) {
        unsafe {
            let n = a.len().min(b.len()) / LANES * LANES;
            let mut lo = vld1q_f32(acc.as_ptr());
            let mut hi = vld1q_f32(acc.as_ptr().add(4));
            let mut i = 0;
            while i < n {
                let a0 = vld1q_f32(a.as_ptr().add(i));
                let a1 = vld1q_f32(a.as_ptr().add(i + 4));
                let b0 = vld1q_f32(b.as_ptr().add(i));
                let b1 = vld1q_f32(b.as_ptr().add(i + 4));
                lo = vaddq_f32(lo, vmulq_f32(a0, b0));
                hi = vaddq_f32(hi, vmulq_f32(a1, b1));
                i += LANES;
            }
            vst1q_f32(acc.as_mut_ptr(), lo);
            vst1q_f32(acc.as_mut_ptr().add(4), hi);
        }
    }

    fn gemm_nt_microkernel(a: &[f32], b: [&[f32]; 4], acc: &mut [[f32; 8]; 4]) {
        unsafe {
            let n = a.len() / LANES * LANES;
            let mut c: [[float32x4_t; 2]; 4] = [[vdupq_n_f32(0.0); 2]; 4];
            for (j, accj) in acc.iter().enumerate() {
                c[j][0] = vld1q_f32(accj.as_ptr());
                c[j][1] = vld1q_f32(accj.as_ptr().add(4));
            }
            let mut i = 0;
            while i < n {
                let a0 = vld1q_f32(a.as_ptr().add(i));
                let a1 = vld1q_f32(a.as_ptr().add(i + 4));
                for (j, bj) in b.iter().enumerate() {
                    let b0 = vld1q_f32(bj.as_ptr().add(i));
                    let b1 = vld1q_f32(bj.as_ptr().add(i + 4));
                    c[j][0] = vaddq_f32(c[j][0], vmulq_f32(a0, b0));
                    c[j][1] = vaddq_f32(c[j][1], vmulq_f32(a1, b1));
                }
                i += LANES;
            }
            for (j, accj) in acc.iter_mut().enumerate() {
                vst1q_f32(accj.as_mut_ptr(), c[j][0]);
                vst1q_f32(accj.as_mut_ptr().add(4), c[j][1]);
            }
        }
    }

    fn axpy_k(a: f32, x: &[f32], y: &mut [f32]) {
        unsafe {
            let n = x.len().min(y.len());
            let n4 = n / 4 * 4;
            let av = vdupq_n_f32(a);
            let mut i = 0;
            while i < n4 {
                let xv = vld1q_f32(x.as_ptr().add(i));
                let yv = vld1q_f32(y.as_ptr().add(i));
                vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(yv, vmulq_f32(av, xv)));
                i += 4;
            }
            while i < n {
                y[i] += a * x[i];
                i += 1;
            }
        }
    }

    fn axpy4_k(c: &[f32; 4], x4: &[f32], k: usize, y: &mut [f32]) {
        unsafe {
            let n = k.min(y.len());
            let n4 = n / 4 * 4;
            let c0 = vdupq_n_f32(c[0]);
            let c1 = vdupq_n_f32(c[1]);
            let c2 = vdupq_n_f32(c[2]);
            let c3 = vdupq_n_f32(c[3]);
            let mut i = 0;
            while i < n4 {
                let t01 = vaddq_f32(
                    vmulq_f32(c0, vld1q_f32(x4.as_ptr().add(i))),
                    vmulq_f32(c1, vld1q_f32(x4.as_ptr().add(k + i))),
                );
                let t23 = vaddq_f32(
                    vmulq_f32(c2, vld1q_f32(x4.as_ptr().add(2 * k + i))),
                    vmulq_f32(c3, vld1q_f32(x4.as_ptr().add(3 * k + i))),
                );
                let yv = vld1q_f32(y.as_ptr().add(i));
                vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(yv, vaddq_f32(t01, t23)));
                i += 4;
            }
            while i < n {
                let t01 = c[0] * x4[i] + c[1] * x4[k + i];
                let t23 = c[2] * x4[2 * k + i] + c[3] * x4[3 * k + i];
                y[i] += t01 + t23;
                i += 1;
            }
        }
    }

    fn add_k(x: &[f32], y: &mut [f32]) {
        unsafe {
            let n = x.len().min(y.len());
            let n4 = n / 4 * 4;
            let mut i = 0;
            while i < n4 {
                let xv = vld1q_f32(x.as_ptr().add(i));
                let yv = vld1q_f32(y.as_ptr().add(i));
                vst1q_f32(y.as_mut_ptr().add(i), vaddq_f32(yv, xv));
                i += 4;
            }
            while i < n {
                y[i] += x[i];
                i += 1;
            }
        }
    }

    pub(super) static TABLE: KernelDispatch = KernelDispatch {
        level: SimdLevel::Neon,
        dot8_acc,
        gemm_nt_microkernel,
        axpy_k,
        axpy4_k,
        add_k,
        widen_f16_lanes: widen_f16_lanes_scalar,
        exp_softmax_row: exp_softmax_row_scalar,
        layernorm_row: layernorm_row_scalar,
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn assert_bits_eq(s: &[f32], b: &[f32], what: &str) {
        assert_eq!(s.len(), b.len(), "{what}: length mismatch");
        for (i, (x, y)) in s.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: bit mismatch at {i}: {x} vs {y}"
            );
        }
    }

    /// Run `f` under the scalar table, then under `best`, restoring the
    /// previous level; returns (scalar result, best result).
    fn race<T>(best: SimdLevel, mut f: impl FnMut() -> T) -> (T, T) {
        let prev = force_level(SimdLevel::Scalar);
        let s = f();
        force_level(best);
        let b = f();
        force_level(prev);
        (s, b)
    }

    /// All dispatched kernels must be bit-identical between the scalar
    /// arm and the best detected arm, across odd lengths, unaligned
    /// offsets, lane-remainder tails, empty inputs and f16 inputs. One
    /// test (not one per kernel) because `force_level` is process-global
    /// and the test harness runs tests concurrently.
    #[test]
    fn simd_arms_bit_match_scalar_reference() {
        let best = active_level();
        let mut rng = Rng::new(0xD15EA5E);
        let lens = [0usize, 1, 2, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 67];
        for &len in &lens {
            for &off in &[0usize, 1, 3] {
                let n = off + len;
                let mut a = vec![0.0f32; n];
                let mut b = vec![0.0f32; n.max(off + 4 * len.max(1))];
                let mut y0 = vec![0.0f32; n];
                rng.fill_gaussian(&mut a);
                rng.fill_gaussian(&mut b);
                rng.fill_gaussian(&mut y0);
                let a = &a[off..];
                let coef = [a.first().copied().unwrap_or(0.5), -0.25, 1.5, -2.0];

                // dot8_acc over the lane prefix (carried accumulator)
                let n8 = len / LANES * LANES;
                let (s, v) = race(best, || {
                    let mut acc = [0.1f32; 8];
                    (kernels().dot8_acc)(&a[..n8], &b[off..off + n8], &mut acc);
                    acc
                });
                assert_bits_eq(&s, &v, "dot8_acc");

                // gemm_nt_microkernel: 4 B rows, carried accumulators
                if 4 * len + off <= b.len() {
                    let b4 = &b[off..off + 4 * len];
                    let (s, v) = race(best, || {
                        let mut acc = [[0.25f32; 8]; 4];
                        let rows = [
                            &b4[..n8],
                            &b4[len..len + n8],
                            &b4[2 * len..2 * len + n8],
                            &b4[3 * len..3 * len + n8],
                        ];
                        (kernels().gemm_nt_microkernel)(&a[..n8], rows, &mut acc);
                        acc
                    });
                    for j in 0..4 {
                        assert_bits_eq(&s[j], &v[j], "gemm_nt_microkernel");
                    }
                    // each column must equal a standalone dot8_acc
                    let mut acc1 = [0.25f32; 8];
                    (kernels().dot8_acc)(&a[..n8], &b4[..n8], &mut acc1);
                    assert_bits_eq(&acc1, &v[0], "microkernel column vs dot8_acc");
                }

                // axpy_k / add_k
                let (s, v) = race(best, || {
                    let mut y = y0[off.min(y0.len())..].to_vec();
                    (kernels().axpy_k)(1.75, a, &mut y);
                    (kernels().add_k)(a, &mut y);
                    y
                });
                assert_bits_eq(&s, &v, "axpy_k/add_k");

                // axpy4_k from 4 stride-len rows
                if len > 0 && 4 * len + off <= b.len() {
                    let (s, v) = race(best, || {
                        let mut y = vec![0.5f32; len];
                        (kernels().axpy4_k)(&coef, &b[off..off + 4 * len], len, &mut y);
                        y
                    });
                    assert_bits_eq(&s, &v, "axpy4_k");
                }

                // exp_softmax_row on finite scores
                let (s, v) = race(best, || {
                    let mut p: Vec<f32> = a.iter().map(|&x| 3.0 * x).collect();
                    (kernels().exp_softmax_row)(&mut p, 0.37);
                    p
                });
                assert_bits_eq(&s, &v, "exp_softmax_row");

                // layernorm_row
                if len > 0 {
                    let g: Vec<f32> = (0..len).map(|i| 1.0 + 0.01 * i as f32).collect();
                    let bb: Vec<f32> = (0..len).map(|i| -0.02 * i as f32).collect();
                    let (s, v) = race(best, || {
                        let mut out = vec![0.0f32; len];
                        (kernels().layernorm_row)(a, &g, &bb, 1e-5, &mut out);
                        out
                    });
                    assert_bits_eq(&s, &v, "layernorm_row");
                }

                // widen_f16_lanes on round-tripped gaussian values
                let h: Vec<u16> = a.iter().map(|&x| crate::util::fp16::f32_to_f16(x)).collect();
                let (s, v) = race(best, || {
                    let mut out = vec![0.0f32; h.len()];
                    (kernels().widen_f16_lanes)(&h, &mut out);
                    out
                });
                assert_bits_eq(&s, &v, "widen_f16_lanes");
            }
        }

        // exhaustive f16 widening: the active arm must match the software
        // codec for every one of the 65536 bit patterns (incl. NaNs,
        // which both quiet the same way — payload compared bitwise).
        let all: Vec<u16> = (0..=u16::MAX).collect();
        let (s, v) = race(best, || {
            let mut out = vec![0.0f32; all.len()];
            (kernels().widen_f16_lanes)(&all, &mut out);
            out
        });
        assert_bits_eq(&s, &v, "widen_f16_lanes exhaustive");
        for (h, w) in all.iter().zip(&v) {
            assert_eq!(
                w.to_bits(),
                crate::util::fp16::f16_to_f32(*h).to_bits(),
                "widen arm vs fp16 codec at bits {h:#06x}"
            );
        }

        // chunk-carry invariance: dot8_acc split at any lane boundary
        // reduces identically to one full pass (the staging loops in
        // matrix.rs rely on this).
        let mut a = vec![0.0f32; 80];
        let mut b = vec![0.0f32; 80];
        rng.fill_gaussian(&mut a);
        rng.fill_gaussian(&mut b);
        let mut whole = [0.0f32; 8];
        (kernels().dot8_acc)(&a, &b, &mut whole);
        for &split in &[8usize, 24, 40, 72] {
            let mut acc = [0.0f32; 8];
            (kernels().dot8_acc)(&a[..split], &b[..split], &mut acc);
            (kernels().dot8_acc)(&a[split..], &b[split..], &mut acc);
            assert_bits_eq(&whole, &acc, "dot8_acc chunk carry");
        }
    }

    #[test]
    fn polynomial_exp_tracks_reference_exp() {
        for i in 0..=8700 {
            let x = -(i as f64) * 0.01;
            let e = exp_poly(x as f32) as f64;
            let r = x.exp();
            let rel = ((e - r) / r).abs();
            assert!(rel < 1e-6, "exp_poly({x}) = {e}, want {r} (rel {rel:.2e})");
        }
        assert_eq!(exp_poly(0.0), 1.0);
        assert_eq!(exp_poly(-0.0), 1.0);
        assert_eq!(exp_poly(-100.0), 0.0);
    }

    #[test]
    fn exp_softmax_row_matches_naive_softmax() {
        let mut rng = Rng::new(7);
        let mut x = vec![0.0f32; 97];
        rng.fill_gaussian(&mut x);
        let scale = 0.125f32;
        let naive: Vec<f64> = {
            let m = x
                .iter()
                .map(|&v| (v * scale) as f64)
                .fold(f64::NEG_INFINITY, f64::max);
            let e: Vec<f64> = x.iter().map(|&v| ((v * scale) as f64 - m).exp()).collect();
            let d: f64 = e.iter().sum();
            e.into_iter().map(|v| v / d).collect()
        };
        let mut p = x.clone();
        (kernels().exp_softmax_row)(&mut p, scale);
        let sum: f64 = p.iter().map(|&v| v as f64).sum();
        assert!((sum - 1.0).abs() < 1e-5, "softmax row sums to {sum}");
        for (a, b) in p.iter().zip(&naive) {
            assert!((*a as f64 - b).abs() < 1e-6, "softmax {a} vs naive {b}");
        }
        // empty rows are a no-op, not a panic
        (kernels().exp_softmax_row)(&mut [], 1.0);
    }

    #[test]
    fn padded_k_rounds_to_lane_multiples() {
        assert_eq!(padded_k(0), 0);
        assert_eq!(padded_k(1), 1);
        assert_eq!(padded_k(2), 8);
        assert_eq!(padded_k(8), 8);
        assert_eq!(padded_k(9), 16);
        assert_eq!(padded_k(32), 32);
        assert_eq!(padded_k(33), 40);
    }
}
