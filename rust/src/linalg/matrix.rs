//! Row-major dense matrix with the operations the stack needs.
//!
//! Matmul is cache-blocked with a transposed-B microkernel; `matvec` and
//! `matvec_into` are the allocation-free hot-path variants used by the HSS
//! apply and the transformer forward pass.
//!
//! Storage is dtype-generic ([`WeightBuf`]): factor matrices loaded from
//! the `HSB1` store can stay f16-resident, and every batched kernel
//! (`apply_batch_{into,add,t_into}`, the matvec family, `gemm_nt_add`)
//! widens elements lane-by-lane in-register. Activations and accumulators
//! are always f32 — only the resident weights narrow. f32-resident
//! matrices behave exactly as before (`.data` derefs to `[f32]`);
//! structural f32-only ops (`transpose`, `slice`, `row`, …) panic on an
//! f16-resident matrix, which must be [`Matrix::widen`]ed first.

use crate::linalg::simd;
use crate::linalg::weightbuf::{Dtype, WeightBuf, WeightElem};
use crate::util::rng::Rng;
use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: WeightBuf,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{}, {})", self.rows, self.cols, self.data.dtype())
    }
}

/// matmul block sizes (tuned in EXPERIMENTS.md §Perf)
const MC: usize = 64;
const NC: usize = 256;

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: WeightBuf::F32(vec![0.0; rows * cols].into()),
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix {
            rows,
            cols,
            data: WeightBuf::F32(data.into()),
        }
    }

    /// Build an f16-resident matrix from raw binary16 bit patterns — the
    /// store's zero-widening load path.
    pub fn from_f16_bits(rows: usize, cols: usize, bits: Vec<u16>) -> Matrix {
        assert_eq!(bits.len(), rows * cols, "data length mismatch");
        Matrix {
            rows,
            cols,
            data: WeightBuf::F16(bits.into()),
        }
    }

    pub fn from_fn<F: FnMut(usize, usize) -> f32>(rows: usize, cols: usize, mut f: F) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix::from_vec(rows, cols, data)
    }

    /// Element dtype of the resident storage.
    pub fn dtype(&self) -> Dtype {
        self.data.dtype()
    }

    /// Bytes this matrix keeps resident for its values.
    pub fn resident_bytes(&self) -> usize {
        self.data.resident_bytes()
    }

    /// Narrow the resident storage to f16 in place (round-to-nearest-even;
    /// a no-op when already f16). The widened kernels then stream u16
    /// weights directly.
    pub fn narrow_to_f16(&mut self) {
        if self.data.dtype() != Dtype::F16 {
            self.data = self.data.to_f16();
        }
    }

    /// Widen the resident storage to f32 in place (exact; a no-op when
    /// already f32) — required before training or any structural
    /// f32-only op.
    pub fn widen_to_f32(&mut self) {
        if self.data.dtype() != Dtype::F32 {
            self.data = self.data.to_f32();
        }
    }

    /// f32-resident copy (exact for either source dtype).
    pub fn widen(&self) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.to_f32(),
        }
    }

    pub fn identity(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Build a row-major [n, k] column block from k equal-length column
    /// vectors (column c of the result is `cols[c]`) — the batch layout
    /// `apply_batch` consumes.
    pub fn from_cols(cols: &[Vec<f32>]) -> Matrix {
        let k = cols.len();
        assert!(k > 0, "from_cols needs at least one column");
        let n = cols[0].len();
        let mut m = Matrix::zeros(n, k);
        for (c, col) in cols.iter().enumerate() {
            assert_eq!(col.len(), n, "ragged column lengths");
            for (i, &v) in col.iter().enumerate() {
                m.data[i * k + c] = v;
            }
        }
        m
    }

    /// Copy column `c` out into a vector (the inverse of
    /// [`Matrix::from_cols`]); widens if the matrix is f16-resident.
    pub fn col(&self, c: usize) -> Vec<f32> {
        assert!(c < self.cols, "column out of range");
        (0..self.rows).map(|i| self.data.at(i * self.cols + c)).collect()
    }

    /// Standard-Gaussian random matrix (deterministic by seed).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_gaussian(&mut m.data);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data.at(i * self.cols + j)
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // simple blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Copy a contiguous submatrix [r0..r1) x [c0..c1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Write `src` into the block starting at (r0, c0). Full-width blocks
    /// (the attention/logits write-back shape) are one contiguous
    /// `copy_from_slice`; narrower blocks copy row slices.
    pub fn set_block(&mut self, r0: usize, c0: usize, src: &Matrix) {
        assert!(r0 + src.rows <= self.rows && c0 + src.cols <= self.cols);
        if c0 == 0 && src.cols == self.cols {
            let start = r0 * self.cols;
            self.data[start..start + src.rows * src.cols].copy_from_slice(&src.data);
            return;
        }
        for i in 0..src.rows {
            self.row_mut(r0 + i)[c0..c0 + src.cols].copy_from_slice(src.row(i));
        }
    }

    // --- arithmetic ---------------------------------------------------------

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.iter().map(|a| a * s).collect())
    }

    /// C = A @ B, cache-blocked over a transposed copy of B.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let mut c = Matrix::zeros(self.rows, b.cols);
        self.matmul_into(b, &mut c);
        c
    }

    /// C = A @ B without allocating C (C must be pre-sized; it is overwritten).
    pub fn matmul_into(&self, b: &Matrix, c: &mut Matrix) {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        assert_eq!((c.rows, c.cols), (self.rows, b.cols), "output shape mismatch");
        let bt = b.transpose();
        self.matmul_bt_into(&bt, c);
    }

    /// C = A @ Bᵀ given B already transposed — the dot-product microkernel.
    /// Either operand may be f16-resident (widened in-register); C is f32.
    pub fn matmul_bt_into(&self, bt: &Matrix, c: &mut Matrix) {
        assert_eq!(self.cols, bt.cols, "inner dim mismatch");
        assert_eq!((c.rows, c.cols), (self.rows, bt.rows));
        c.data.fill(0.0);
        self.matmul_bt_add(bt, c);
    }

    /// C += A @ Bᵀ given B already transposed — the accumulating form the
    /// batched gradient kernel reduces every rank-k factor update to.
    pub fn matmul_bt_add(&self, bt: &Matrix, c: &mut Matrix) {
        assert_eq!(self.cols, bt.cols, "inner dim mismatch");
        assert_eq!((c.rows, c.cols), (self.rows, bt.rows));
        let out = c.data.as_f32_mut();
        match (&self.data, &bt.data) {
            (WeightBuf::F32(a), WeightBuf::F32(b)) => {
                gemm_nt_add_w(a.as_slice(), b.as_slice(), self.rows, bt.rows, self.cols, out)
            }
            (WeightBuf::F32(a), WeightBuf::F16(b)) => {
                gemm_nt_add_w(a.as_slice(), b.as_slice(), self.rows, bt.rows, self.cols, out)
            }
            (WeightBuf::F16(a), WeightBuf::F32(b)) => {
                gemm_nt_add_w(a.as_slice(), b.as_slice(), self.rows, bt.rows, self.cols, out)
            }
            (WeightBuf::F16(a), WeightBuf::F16(b)) => {
                gemm_nt_add_w(a.as_slice(), b.as_slice(), self.rows, bt.rows, self.cols, out)
            }
        }
    }

    /// y = A @ x (allocates y).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A @ x without allocation; y is overwritten.
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        match &self.data {
            WeightBuf::F32(w) => matvec_into_w(w.as_slice(), self.rows, self.cols, x, y),
            WeightBuf::F16(w) => matvec_into_w(w.as_slice(), self.rows, self.cols, x, y),
        }
    }

    /// y += A @ x.
    pub fn matvec_add(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        match &self.data {
            WeightBuf::F32(w) => matvec_add_w(w.as_slice(), self.rows, self.cols, x, y),
            WeightBuf::F16(w) => matvec_add_w(w.as_slice(), self.rows, self.cols, x, y),
        }
    }

    /// y = Aᵀ @ x without materializing the transpose.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// y = Aᵀ @ x into a preallocated buffer (y is overwritten) — the
    /// allocation-free form the training backward pass runs in its hot loop.
    pub fn matvec_t_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        match &self.data {
            WeightBuf::F32(w) => matvec_t_add_w(w.as_slice(), self.rows, self.cols, x, y),
            WeightBuf::F16(w) => matvec_t_add_w(w.as_slice(), self.rows, self.cols, x, y),
        }
    }

    // --- batched column-block apply ----------------------------------------
    //
    // The batched hot path works on row-major column blocks: a block of k
    // independent input vectors is one `&[f32]` of length n·k where column
    // c of input row j lives at `x[j*k + c]` (i.e. a row-major [n, k]
    // matrix whose columns are the batch). Row ranges of such a block are
    // contiguous, which is what lets the HSS traversal split a batch at a
    // node boundary without copying.

    /// Y += A @ X for a row-major column block X [cols, k] → Y [rows, k].
    /// The k=1 case degenerates to the dot-kernel matvec; for k > 1 the
    /// inner loop is a 4-way-unrolled axpy over the contiguous k lane,
    /// with X kept hot in cache by blocking over A's columns. f16-resident
    /// weights are widened once per element and reused across all k lanes
    /// — the batch is what amortizes the u16 → f32 conversion.
    /// Flop count of one `apply_batch_*` call at batch width `k`: one
    /// multiply plus one add per weight element per lane. Instrumented
    /// call sites feed this to [`crate::obs::count_flops`] so the
    /// feature-gated per-stage counters stay in sync with the kernels.
    pub fn apply_flops(&self, k: usize) -> u64 {
        2 * (self.rows * self.cols) as u64 * k as u64
    }

    pub fn apply_batch_add(&self, x: &[f32], y: &mut [f32], k: usize) {
        assert_eq!(x.len(), self.cols * k, "input block shape mismatch");
        assert_eq!(y.len(), self.rows * k, "output block shape mismatch");
        match &self.data {
            WeightBuf::F32(w) => apply_batch_add_w(w.as_slice(), self.rows, self.cols, x, y, k),
            WeightBuf::F16(w) => apply_batch_add_w(w.as_slice(), self.rows, self.cols, x, y, k),
        }
    }

    /// Y = A @ X for a row-major column block (overwrites Y).
    pub fn apply_batch_into(&self, x: &[f32], y: &mut [f32], k: usize) {
        assert_eq!(y.len(), self.rows * k, "output block shape mismatch");
        if k == 1 {
            self.matvec_into(x, y);
            return;
        }
        y.fill(0.0);
        self.apply_batch_add(x, y, k);
    }

    /// Y = Aᵀ @ X for a row-major column block X [rows, k] → Y [cols, k],
    /// without materializing the transpose (overwrites Y). Blocked over
    /// A's columns so the written Y rows stay cache-resident.
    pub fn apply_batch_t_into(&self, x: &[f32], y: &mut [f32], k: usize) {
        assert_eq!(x.len(), self.rows * k, "input block shape mismatch");
        assert_eq!(y.len(), self.cols * k, "output block shape mismatch");
        if k == 1 {
            self.matvec_t_into(x, y);
            return;
        }
        y.fill(0.0);
        match &self.data {
            WeightBuf::F32(w) => apply_batch_t_add_w(w.as_slice(), self.rows, self.cols, x, y, k),
            WeightBuf::F16(w) => apply_batch_t_add_w(w.as_slice(), self.rows, self.cols, x, y, k),
        }
    }

    /// [`Matrix::apply_batch_into`] with an f16 staging buffer: an
    /// f16-resident matrix is pre-widened **wholesale** into `stage` once
    /// per call (exact), so the hot kernel always runs the pure-f32
    /// monomorphization instead of converting inside the inner loop; an
    /// f32-resident matrix skips the staging copy entirely. Bit-identical
    /// to the unstaged call for either dtype — widening is exact and the
    /// kernel's arithmetic order is unchanged. `stage` grows on demand and
    /// is reused across calls (wire it through a `BatchWorkspace`).
    pub fn apply_batch_into_staged(&self, x: &[f32], y: &mut [f32], k: usize, stage: &mut Vec<f32>) {
        match &self.data {
            WeightBuf::F32(_) => self.apply_batch_into(x, y, k),
            WeightBuf::F16(w) => {
                assert_eq!(x.len(), self.cols * k, "input block shape mismatch");
                assert_eq!(y.len(), self.rows * k, "output block shape mismatch");
                let s = crate::linalg::weightbuf::widen_f16_into(w, stage);
                if k == 1 {
                    matvec_into_w(s, self.rows, self.cols, x, y);
                } else {
                    y.fill(0.0);
                    apply_batch_add_w(s, self.rows, self.cols, x, y, k);
                }
            }
        }
    }

    /// Accumulating form of [`Matrix::apply_batch_into_staged`]
    /// (Y += A @ X).
    pub fn apply_batch_add_staged(&self, x: &[f32], y: &mut [f32], k: usize, stage: &mut Vec<f32>) {
        match &self.data {
            WeightBuf::F32(_) => self.apply_batch_add(x, y, k),
            WeightBuf::F16(w) => {
                assert_eq!(x.len(), self.cols * k, "input block shape mismatch");
                assert_eq!(y.len(), self.rows * k, "output block shape mismatch");
                let s = crate::linalg::weightbuf::widen_f16_into(w, stage);
                apply_batch_add_w(s, self.rows, self.cols, x, y, k);
            }
        }
    }

    /// Symmetric permutation A[p, p] (rows and columns).
    pub fn permute_sym(&self, perm: &[usize]) -> Matrix {
        assert!(self.is_square());
        let n = self.rows;
        assert_eq!(perm.len(), n);
        let mut out = Matrix::zeros(n, n);
        for i in 0..n {
            let src = self.row(perm[i]);
            let dst = out.row_mut(i);
            for j in 0..n {
                dst[j] = src[perm[j]];
            }
        }
        out
    }
}

// ---------------------------------------------------------------- kernels
//
// The generic kernels are monomorphized per weight dtype: `E::widen` is
// the identity for f32 (compiling to exactly the pre-dtype-generic code)
// and an in-register u16 → binary16 → f32 conversion for f16-resident
// weights. Activations (`x`), outputs (`y`/`out`), and every accumulator
// stay f32.

/// OUT[m, n] += A[m, k] @ B[n, k]ᵀ over raw row-major slices — the shared
/// rank-k update kernel behind `matmul_bt_into`/`matmul_bt_add` and every
/// batched factor gradient (k = 1 is the classic outer-product update).
/// The f32-slice form used by the training backward passes.
pub fn gemm_nt_add(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    gemm_nt_add_w(a, b, m, n, k, out)
}

/// Dtype-generic [`gemm_nt_add`]: either operand may be a widened-on-read
/// weight slice (f32 or f16-as-u16). Four B rows at a time go through the
/// dispatched `simd::gemm_nt_microkernel`; each microkernel column is
/// bit-identical to a standalone `dot_w`, so the 4-way unroll (and the
/// remainder columns, which use `dot_w` directly) produce the same bits
/// for every dtype combination and dispatch level.
pub fn gemm_nt_add_w<A: WeightElem, B: WeightElem>(
    a: &[A],
    b: &[B],
    m: usize,
    n: usize,
    k: usize,
    out: &mut [f32],
) {
    assert_eq!(a.len(), m * k, "gemm_nt_add: A shape mismatch");
    assert_eq!(b.len(), n * k, "gemm_nt_add: B shape mismatch");
    assert_eq!(out.len(), m * n, "gemm_nt_add: OUT shape mismatch");
    let kt = simd::kernels();
    let k8 = k / simd::LANES * simd::LANES;
    let mut abuf = [0.0f32; simd::DOT_CHUNK];
    let mut bbuf = [[0.0f32; simd::DOT_CHUNK]; 4];
    for ib in (0..m).step_by(MC) {
        let imax = (ib + MC).min(m);
        for jb in (0..n).step_by(NC) {
            let jmax = (jb + NC).min(n);
            for i in ib..imax {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n..(i + 1) * n];
                let mut j = jb;
                while j + 4 <= jmax {
                    let mut acc = [[0.0f32; 8]; 4];
                    if !A::NEEDS_WIDEN && !B::NEEDS_WIDEN {
                        let aw = A::as_f32_lanes(&arow[..k8], &mut []);
                        let rows = [
                            B::as_f32_lanes(&b[j * k..j * k + k8], &mut []),
                            B::as_f32_lanes(&b[(j + 1) * k..(j + 1) * k + k8], &mut []),
                            B::as_f32_lanes(&b[(j + 2) * k..(j + 2) * k + k8], &mut []),
                            B::as_f32_lanes(&b[(j + 3) * k..(j + 3) * k + k8], &mut []),
                        ];
                        (kt.gemm_nt_microkernel)(aw, rows, &mut acc);
                    } else {
                        // f16 operands stage through stack chunks; the
                        // carried accumulators keep the reduction
                        // bit-identical to the unchunked f32 path.
                        let [s0, s1, s2, s3] = &mut bbuf;
                        let mut p = 0;
                        while p < k8 {
                            let c = simd::DOT_CHUNK.min(k8 - p);
                            let aw = A::as_f32_lanes(&arow[p..p + c], &mut abuf);
                            let rows = [
                                B::as_f32_lanes(&b[j * k + p..j * k + p + c], &mut s0[..]),
                                B::as_f32_lanes(&b[(j + 1) * k + p..(j + 1) * k + p + c], &mut s1[..]),
                                B::as_f32_lanes(&b[(j + 2) * k + p..(j + 2) * k + p + c], &mut s2[..]),
                                B::as_f32_lanes(&b[(j + 3) * k + p..(j + 3) * k + p + c], &mut s3[..]),
                            ];
                            (kt.gemm_nt_microkernel)(aw, rows, &mut acc);
                            p += c;
                        }
                    }
                    for (jj, accj) in acc.iter().enumerate() {
                        let mut t = simd::hsum8_tree(accj);
                        let brow = &b[(j + jj) * k..(j + jj + 1) * k];
                        for q in k8..k {
                            t += arow[q].widen() * brow[q].widen();
                        }
                        orow[j + jj] += t;
                    }
                    j += 4;
                }
                while j < jmax {
                    orow[j] += dot_w(arow, &b[j * k..(j + 1) * k], k);
                    j += 1;
                }
            }
        }
    }
}

/// Dot product — the innermost kernel of everything dense. Rides the
/// dispatched `simd::dot8_acc` (AVX2/NEON lanes, or the lane-mirrored
/// scalar fallback): 8-lane accumulation over the lane prefix, the
/// shared `hsum8_tree` fold, then a sequential tail. The reduction shape
/// is identical at every dispatch level and for every chunk split, so
/// results are bit-stable across CPUs and staging strategies.
#[inline]
pub fn dot(a: &[f32], b: &[f32], k: usize) -> f32 {
    dot_w(a, b, k)
}

/// Dtype-generic [`dot`]: f16 operands widen through the dispatched
/// `simd::widen_f16_lanes` in `DOT_CHUNK`-sized stack stages between
/// `dot8_acc` calls. The accumulator is carried across chunks, so the
/// chunked f16 path reduces bit-identically to the single-pass f32 path
/// (pinned by the chunk-carry test in `linalg::simd`).
#[inline]
pub fn dot_w<A: WeightElem, B: WeightElem>(a: &[A], b: &[B], k: usize) -> f32 {
    let a = &a[..k];
    let b = &b[..k];
    let kt = simd::kernels();
    let k8 = k / simd::LANES * simd::LANES;
    let mut acc = [0.0f32; 8];
    if !A::NEEDS_WIDEN && !B::NEEDS_WIDEN {
        let aw = A::as_f32_lanes(&a[..k8], &mut []);
        let bw = B::as_f32_lanes(&b[..k8], &mut []);
        (kt.dot8_acc)(aw, bw, &mut acc);
    } else {
        let mut abuf = [0.0f32; simd::DOT_CHUNK];
        let mut bbuf = [0.0f32; simd::DOT_CHUNK];
        let mut p = 0;
        while p < k8 {
            let c = simd::DOT_CHUNK.min(k8 - p);
            let aw = A::as_f32_lanes(&a[p..p + c], &mut abuf);
            let bw = B::as_f32_lanes(&b[p..p + c], &mut bbuf);
            (kt.dot8_acc)(aw, bw, &mut acc);
            p += c;
        }
    }
    let mut total = simd::hsum8_tree(&acc);
    for i in k8..k {
        total += a[i].widen() * b[i].widen();
    }
    total
}

/// y = W x over a raw row-major weight slice (the k = 1 dot kernel).
fn matvec_into_w<E: WeightElem>(w: &[E], rows: usize, cols: usize, x: &[f32], y: &mut [f32]) {
    for (i, yi) in y.iter_mut().enumerate().take(rows) {
        *yi = dot_w(&w[i * cols..(i + 1) * cols], x, cols);
    }
}

/// y += W x over a raw row-major weight slice.
fn matvec_add_w<E: WeightElem>(w: &[E], rows: usize, cols: usize, x: &[f32], y: &mut [f32]) {
    for (i, yi) in y.iter_mut().enumerate().take(rows) {
        *yi += dot_w(&w[i * cols..(i + 1) * cols], x, cols);
    }
}

/// y += Wᵀ x over a raw row-major weight slice (caller zeroes y for the
/// overwriting form).
fn matvec_t_add_w<E: WeightElem>(w: &[E], rows: usize, cols: usize, x: &[f32], y: &mut [f32]) {
    let kt = simd::kernels();
    let mut wbuf = [0.0f32; NC];
    for i in 0..rows {
        let xi = x[i];
        if xi != 0.0 {
            let row = &w[i * cols..(i + 1) * cols];
            let mut p = 0;
            while p < cols {
                let c = NC.min(cols - p);
                let rw = E::as_f32_lanes(&row[p..p + c], &mut wbuf);
                (kt.axpy_k)(xi, rw, &mut y[p..p + c]);
                p += c;
            }
        }
    }
}

/// Y += W X over a raw row-major weight slice and [cols, k] column block.
/// Each weight element is widened once and reused across all k lanes.
/// Public as the slice-level axpy kernel: batched attention drives its
/// softmax · V context rows through it ([1, t] weights × [t, head_dim]
/// values), so P·V is the same thin multiply as every other kernel.
pub fn apply_batch_add_w<E: WeightElem>(
    w: &[E],
    rows: usize,
    cols: usize,
    x: &[f32],
    y: &mut [f32],
    k: usize,
) {
    if k == 1 {
        matvec_add_w(w, rows, cols, x, y);
        return;
    }
    let kt = simd::kernels();
    let mut wbuf = [0.0f32; NC];
    for jb in (0..cols).step_by(NC) {
        let jmax = (jb + NC).min(cols);
        for i in 0..rows {
            // One block of this weight row, widened wholesale (f16) or
            // viewed in place (f32) — the single widening path.
            let aw = E::as_f32_lanes(&w[i * cols + jb..i * cols + jmax], &mut wbuf);
            let yrow = &mut y[i * k..(i + 1) * k];
            let mut j = 0;
            while j + 4 <= aw.len() {
                let coefs = [aw[j], aw[j + 1], aw[j + 2], aw[j + 3]];
                (kt.axpy4_k)(&coefs, &x[(jb + j) * k..(jb + j + 4) * k], k, yrow);
                j += 4;
            }
            while j < aw.len() {
                (kt.axpy_k)(aw[j], &x[(jb + j) * k..(jb + j + 1) * k], yrow);
                j += 1;
            }
        }
    }
}

/// Y += Wᵀ X over a raw row-major weight slice and [rows, k] column block
/// (caller zeroes Y for the overwriting form). Blocked over W's columns so
/// the written Y rows stay cache-resident.
fn apply_batch_t_add_w<E: WeightElem>(
    w: &[E],
    rows: usize,
    cols: usize,
    x: &[f32],
    y: &mut [f32],
    k: usize,
) {
    let kt = simd::kernels();
    let mut wbuf = [0.0f32; NC];
    for jb in (0..cols).step_by(NC) {
        let jmax = (jb + NC).min(cols);
        for i in 0..rows {
            let aw = E::as_f32_lanes(&w[i * cols + jb..i * cols + jmax], &mut wbuf);
            let xrow = &x[i * k..(i + 1) * k];
            for (jo, &aij) in aw.iter().enumerate() {
                if aij == 0.0 {
                    continue;
                }
                let yrow = &mut y[(jb + jo) * k..(jb + jo + 1) * k];
                (kt.axpy_k)(aij, xrow, yrow);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, slices_close};

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for l in 0..a.cols {
                    s += a.at(i, l) * b.at(l, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let a = Matrix::randn(37, 53, 1);
        let b = Matrix::randn(53, 29, 2);
        let c = a.matmul(&b);
        let expect = naive_matmul(&a, &b);
        for (x, y) in c.data.iter().zip(&expect.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::randn(16, 16, 3);
        let c = a.matmul(&Matrix::identity(16));
        slices_close(&c.data, &a.data, 1e-6, 1e-6, "a*I").unwrap();
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::randn(24, 31, 4);
        let x: Vec<f32> = (0..31).map(|i| i as f32 * 0.1).collect();
        let xm = Matrix::from_vec(31, 1, x.clone());
        let via_mm = a.matmul(&xm);
        let via_mv = a.matvec(&x);
        slices_close(&via_mv, &via_mm.data, 1e-5, 1e-5, "matvec").unwrap();
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let a = Matrix::randn(20, 15, 5);
        let x: Vec<f32> = (0..20).map(|i| (i as f32).sin()).collect();
        let expect = a.transpose().matvec(&x);
        let got = a.matvec_t(&x);
        slices_close(&got, &expect, 1e-5, 1e-5, "matvec_t").unwrap();
    }

    #[test]
    fn matvec_t_into_overwrites_stale_buffer() {
        let a = Matrix::randn(12, 9, 9);
        let x: Vec<f32> = (0..12).map(|i| (i as f32).cos()).collect();
        let mut y = vec![7.0f32; 9];
        a.matvec_t_into(&x, &mut y);
        slices_close(&y, &a.matvec_t(&x), 1e-6, 1e-6, "matvec_t_into").unwrap();
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::randn(13, 47, 6);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn slice_and_set_block_roundtrip() {
        let a = Matrix::randn(10, 10, 7);
        let block = a.slice(2, 6, 3, 9);
        assert_eq!((block.rows, block.cols), (4, 6));
        assert_eq!(block.at(0, 0), a.at(2, 3));
        let mut b = Matrix::zeros(10, 10);
        b.set_block(2, 3, &block);
        assert_eq!(b.at(5, 8), a.at(5, 8));
        assert_eq!(b.at(0, 0), 0.0);
    }

    #[test]
    fn permute_sym_identity_is_noop() {
        let a = Matrix::randn(8, 8, 8);
        let id: Vec<usize> = (0..8).collect();
        assert_eq!(a.permute_sym(&id), a);
    }

    #[test]
    fn permute_sym_reverses() {
        let a = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let p = vec![2usize, 1, 0];
        let ap = a.permute_sym(&p);
        assert_eq!(ap.at(0, 0), a.at(2, 2));
        assert_eq!(ap.at(0, 2), a.at(2, 0));
        assert_eq!(ap.at(1, 1), a.at(1, 1));
    }

    #[test]
    fn matmul_associativity_property() {
        check(10, |rng| {
            let n = 4 + rng.below(12);
            let a = Matrix::randn(n, n, rng.next_u64());
            let b = Matrix::randn(n, n, rng.next_u64());
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            // (A B) x == A (B x)
            let lhs = a.matmul(&b).matvec(&x);
            let rhs = a.matvec(&b.matvec(&x));
            slices_close(&lhs, &rhs, 1e-3, 1e-3, "assoc")
        });
    }

    #[test]
    fn from_cols_col_roundtrip() {
        let xs: Vec<Vec<f32>> = vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]];
        let m = Matrix::from_cols(&xs);
        assert_eq!((m.rows, m.cols), (3, 2));
        assert_eq!(m.at(0, 1), 4.0);
        assert_eq!(m.col(0), xs[0]);
        assert_eq!(m.col(1), xs[1]);
    }

    #[test]
    fn apply_batch_matches_per_column_matvec() {
        check(10, |rng| {
            let rows = 3 + rng.below(40);
            let cols = 3 + rng.below(40);
            let k = 1 + rng.below(9);
            let a = Matrix::randn(rows, cols, rng.next_u64());
            let xs: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..cols).map(|_| rng.gaussian_f32()).collect())
                .collect();
            let x = Matrix::from_cols(&xs);
            let mut y = vec![7.0f32; rows * k]; // stale buffer must be overwritten
            a.apply_batch_into(&x.data, &mut y, k);
            for (c, xc) in xs.iter().enumerate() {
                let expect = a.matvec(xc);
                let got: Vec<f32> = (0..rows).map(|i| y[i * k + c]).collect();
                slices_close(&got, &expect, 1e-4, 1e-4, "apply_batch col")?;
            }
            Ok(())
        });
    }

    #[test]
    fn apply_batch_t_matches_per_column_matvec_t() {
        check(10, |rng| {
            let rows = 3 + rng.below(30);
            let cols = 3 + rng.below(30);
            let k = 1 + rng.below(7);
            let a = Matrix::randn(rows, cols, rng.next_u64());
            let xs: Vec<Vec<f32>> = (0..k)
                .map(|_| (0..rows).map(|_| rng.gaussian_f32()).collect())
                .collect();
            let x = Matrix::from_cols(&xs);
            let mut y = vec![3.0f32; cols * k];
            a.apply_batch_t_into(&x.data, &mut y, k);
            for (c, xc) in xs.iter().enumerate() {
                let expect = a.matvec_t(xc);
                let got: Vec<f32> = (0..cols).map(|j| y[j * k + c]).collect();
                slices_close(&got, &expect, 1e-4, 1e-4, "apply_batch_t col")?;
            }
            Ok(())
        });
    }

    #[test]
    fn gemm_nt_add_matches_matmul_and_accumulates() {
        let a = Matrix::randn(9, 5, 21);
        let b = Matrix::randn(7, 5, 22);
        let expect = a.matmul(&b.transpose());
        let mut out = vec![1.0f32; 9 * 7];
        gemm_nt_add(&a.data, &b.data, 9, 7, 5, &mut out);
        for (o, e) in out.iter().zip(&expect.data) {
            assert!((o - (e + 1.0)).abs() < 1e-4, "{o} vs {}", e + 1.0);
        }
    }

    #[test]
    fn matmul_bt_add_accumulates() {
        let a = Matrix::randn(6, 4, 23);
        let bt = Matrix::randn(5, 4, 24);
        let mut c1 = Matrix::zeros(6, 5);
        a.matmul_bt_into(&bt, &mut c1);
        let mut c2 = c1.clone();
        a.matmul_bt_add(&bt, &mut c2);
        for (x, y) in c2.data.iter().zip(&c1.data) {
            assert!((x - 2.0 * y).abs() < 1e-4);
        }
    }

    /// The f16 contract: a narrowed matrix's kernels are bit-identical to
    /// running the f32 kernels on the fp16-quantized values — same
    /// arithmetic order, weights merely widened in-register.
    #[test]
    fn f16_kernels_bit_match_quantized_f32() {
        use crate::util::fp16::quantize_f16;
        check(10, |rng| {
            let rows = 3 + rng.below(30);
            let cols = 3 + rng.below(30);
            let k = 1 + rng.below(9);
            let a = Matrix::randn(rows, cols, rng.next_u64());
            let mut q = a.clone();
            quantize_f16(q.data.as_f32_mut());
            let mut h = a.clone();
            h.narrow_to_f16();
            assert_eq!(h.dtype(), crate::linalg::Dtype::F16);
            assert_eq!(h.resident_bytes() * 2, a.resident_bytes());

            let x: Vec<f32> = (0..cols * k).map(|_| rng.gaussian_f32()).collect();
            let mut yq = vec![0.0f32; rows * k];
            let mut yh = vec![0.0f32; rows * k];
            q.apply_batch_into(&x, &mut yq, k);
            h.apply_batch_into(&x, &mut yh, k);
            if yq != yh {
                return Err("apply_batch f16 != quantized f32".into());
            }

            let xt: Vec<f32> = (0..rows * k).map(|_| rng.gaussian_f32()).collect();
            let mut tq = vec![0.0f32; cols * k];
            let mut th = vec![0.0f32; cols * k];
            q.apply_batch_t_into(&xt, &mut tq, k);
            h.apply_batch_t_into(&xt, &mut th, k);
            if tq != th {
                return Err("apply_batch_t f16 != quantized f32".into());
            }

            // widening recovers the quantized values exactly
            if h.widen() != q {
                return Err("widen() lost bits".into());
            }
            Ok(())
        });
    }

    #[test]
    fn f16_matmul_bt_matches_quantized() {
        use crate::util::fp16::quantize_f16;
        let a = Matrix::randn(9, 5, 31);
        let bt = Matrix::randn(7, 5, 32);
        let mut aq = a.clone();
        quantize_f16(aq.data.as_f32_mut());
        let mut ah = a.clone();
        ah.narrow_to_f16();
        let mut c1 = Matrix::zeros(9, 7);
        let mut c2 = Matrix::zeros(9, 7);
        aq.matmul_bt_into(&bt, &mut c1);
        ah.matmul_bt_into(&bt, &mut c2);
        assert_eq!(c1, c2);
    }

    /// The staging contract: pre-widening an f16-resident matrix into the
    /// scratch and running the f32 kernel is bit-identical to the inline
    /// widening path, for both overwrite and accumulate forms, with the
    /// stage reused (stale) across calls.
    #[test]
    fn staged_apply_bit_matches_unstaged() {
        check(10, |rng| {
            let rows = 3 + rng.below(30);
            let cols = 3 + rng.below(30);
            let k = 1 + rng.below(9);
            let mut h = Matrix::randn(rows, cols, rng.next_u64());
            h.narrow_to_f16();
            let x: Vec<f32> = (0..cols * k).map(|_| rng.gaussian_f32()).collect();
            let mut stage = vec![7.0f32; 3]; // undersized and stale
            let mut y1 = vec![0.0f32; rows * k];
            let mut y2 = vec![1.0f32; rows * k]; // stale output must be overwritten
            h.apply_batch_into(&x, &mut y1, k);
            h.apply_batch_into_staged(&x, &mut y2, k, &mut stage);
            if y1 != y2 {
                return Err("staged apply_batch_into != unstaged (bitwise)".into());
            }
            let mut a1 = y1.clone();
            let mut a2 = y1.clone();
            h.apply_batch_add(&x, &mut a1, k);
            h.apply_batch_add_staged(&x, &mut a2, k, &mut stage);
            if a1 != a2 {
                return Err("staged apply_batch_add != unstaged (bitwise)".into());
            }
            // f32-resident matrices bypass the stage entirely
            let f = Matrix::randn(rows, cols, rng.next_u64());
            let before = stage.clone();
            let mut y3 = vec![0.0f32; rows * k];
            let mut y4 = vec![0.0f32; rows * k];
            f.apply_batch_into(&x, &mut y3, k);
            f.apply_batch_into_staged(&x, &mut y4, k, &mut stage);
            if y3 != y4 || stage != before {
                return Err("f32 staged path must bypass the stage".into());
            }
            Ok(())
        });
    }

    #[test]
    fn set_block_full_width_fast_path() {
        let src = Matrix::randn(3, 5, 41);
        let mut dst = Matrix::from_fn(6, 5, |_, _| 9.0);
        dst.set_block(2, 0, &src);
        for i in 0..3 {
            assert_eq!(dst.row(2 + i), src.row(i));
        }
        assert!(dst.row(0).iter().all(|&v| v == 9.0));
        assert!(dst.row(5).iter().all(|&v| v == 9.0));
    }

    #[test]
    fn dot_handles_remainders() {
        for k in 0..9 {
            let a: Vec<f32> = (0..k).map(|i| i as f32).collect();
            let b = vec![2.0f32; k];
            let expect: f32 = a.iter().sum::<f32>() * 2.0;
            assert_eq!(dot(&a, &b, k), expect);
        }
    }
}
