//! Row-major dense f32 matrix with the operations the stack needs.
//!
//! Matmul is cache-blocked with a transposed-B microkernel; `matvec` and
//! `matvec_into` are the allocation-free hot-path variants used by the HSS
//! apply and the transformer forward pass.

use crate::util::rng::Rng;
use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

/// matmul block sizes (tuned in EXPERIMENTS.md §Perf)
const MC: usize = 64;
const NC: usize = 256;

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn<F: FnMut(usize, usize) -> f32>(rows: usize, cols: usize, mut f: F) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn identity(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    /// Standard-Gaussian random matrix (deterministic by seed).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_gaussian(&mut m.data);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // simple blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Copy a contiguous submatrix [r0..r1) x [c0..c1).
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Matrix {
        assert!(r1 <= self.rows && c1 <= self.cols && r0 <= r1 && c0 <= c1);
        let mut out = Matrix::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Write `src` into the block starting at (r0, c0).
    pub fn set_block(&mut self, r0: usize, c0: usize, src: &Matrix) {
        assert!(r0 + src.rows <= self.rows && c0 + src.cols <= self.cols);
        for i in 0..src.rows {
            self.row_mut(r0 + i)[c0..c0 + src.cols].copy_from_slice(src.row(i));
        }
    }

    // --- arithmetic ---------------------------------------------------------

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix::from_vec(self.rows, self.cols, self.data.iter().map(|a| a * s).collect())
    }

    /// C = A @ B, cache-blocked over a transposed copy of B.
    pub fn matmul(&self, b: &Matrix) -> Matrix {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let mut c = Matrix::zeros(self.rows, b.cols);
        self.matmul_into(b, &mut c);
        c
    }

    /// C = A @ B without allocating C (C must be pre-sized; it is overwritten).
    pub fn matmul_into(&self, b: &Matrix, c: &mut Matrix) {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        assert_eq!((c.rows, c.cols), (self.rows, b.cols), "output shape mismatch");
        let bt = b.transpose();
        self.matmul_bt_into(&bt, c);
    }

    /// C = A @ Bᵀ given B already transposed — the dot-product microkernel.
    pub fn matmul_bt_into(&self, bt: &Matrix, c: &mut Matrix) {
        assert_eq!(self.cols, bt.cols, "inner dim mismatch");
        assert_eq!((c.rows, c.cols), (self.rows, bt.rows));
        let k = self.cols;
        for ib in (0..self.rows).step_by(MC) {
            let imax = (ib + MC).min(self.rows);
            for jb in (0..bt.rows).step_by(NC) {
                let jmax = (jb + NC).min(bt.rows);
                for i in ib..imax {
                    let arow = self.row(i);
                    let crow = c.row_mut(i);
                    for j in jb..jmax {
                        let brow = bt.row(j);
                        crow[j] = dot(arow, brow, k);
                    }
                }
            }
        }
    }

    /// y = A @ x (allocates y).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = A @ x without allocation; y is overwritten.
    pub fn matvec_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x, self.cols);
        }
    }

    /// y += A @ x.
    pub fn matvec_add(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            y[i] += dot(self.row(i), x, self.cols);
        }
    }

    /// y = Aᵀ @ x without materializing the transpose.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0.0; self.cols];
        self.matvec_t_into(x, &mut y);
        y
    }

    /// y = Aᵀ @ x into a preallocated buffer (y is overwritten) — the
    /// allocation-free form the training backward pass runs in its hot loop.
    pub fn matvec_t_into(&self, x: &[f32], y: &mut [f32]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        y.fill(0.0);
        for i in 0..self.rows {
            let xi = x[i];
            if xi != 0.0 {
                let row = self.row(i);
                for (yj, &r) in y.iter_mut().zip(row) {
                    *yj += xi * r;
                }
            }
        }
    }

    /// Symmetric permutation A[p, p] (rows and columns).
    pub fn permute_sym(&self, perm: &[usize]) -> Matrix {
        assert!(self.is_square());
        let n = self.rows;
        assert_eq!(perm.len(), n);
        let mut out = Matrix::zeros(n, n);
        for i in 0..n {
            let src = self.row(perm[i]);
            let dst = out.row_mut(i);
            for j in 0..n {
                dst[j] = src[perm[j]];
            }
        }
        out
    }
}

/// Unrolled dot product — the innermost kernel of everything dense.
/// Eight independent accumulators over exact slices: with
/// `-C target-cpu=native` LLVM turns this into AVX2/AVX-512 FMA lanes
/// (measured in EXPERIMENTS.md §Perf).
#[inline]
pub fn dot(a: &[f32], b: &[f32], k: usize) -> f32 {
    let a = &a[..k];
    let b = &b[..k];
    let mut acc = [0.0f32; 8];
    let chunks = k / 8;
    for c in 0..chunks {
        let i = c * 8;
        let (aa, bb) = (&a[i..i + 8], &b[i..i + 8]);
        for l in 0..8 {
            acc[l] += aa[l] * bb[l];
        }
    }
    let mut total = acc.iter().sum::<f32>();
    for i in chunks * 8..k {
        total += a[i] * b[i];
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, slices_close};

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for l in 0..a.cols {
                    s += a.at(i, l) * b.at(l, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let a = Matrix::randn(37, 53, 1);
        let b = Matrix::randn(53, 29, 2);
        let c = a.matmul(&b);
        let expect = naive_matmul(&a, &b);
        for (x, y) in c.data.iter().zip(&expect.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::randn(16, 16, 3);
        let c = a.matmul(&Matrix::identity(16));
        slices_close(&c.data, &a.data, 1e-6, 1e-6, "a*I").unwrap();
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Matrix::randn(24, 31, 4);
        let x: Vec<f32> = (0..31).map(|i| i as f32 * 0.1).collect();
        let xm = Matrix::from_vec(31, 1, x.clone());
        let via_mm = a.matmul(&xm);
        let via_mv = a.matvec(&x);
        slices_close(&via_mv, &via_mm.data, 1e-5, 1e-5, "matvec").unwrap();
    }

    #[test]
    fn matvec_t_matches_transpose() {
        let a = Matrix::randn(20, 15, 5);
        let x: Vec<f32> = (0..20).map(|i| (i as f32).sin()).collect();
        let expect = a.transpose().matvec(&x);
        let got = a.matvec_t(&x);
        slices_close(&got, &expect, 1e-5, 1e-5, "matvec_t").unwrap();
    }

    #[test]
    fn matvec_t_into_overwrites_stale_buffer() {
        let a = Matrix::randn(12, 9, 9);
        let x: Vec<f32> = (0..12).map(|i| (i as f32).cos()).collect();
        let mut y = vec![7.0f32; 9];
        a.matvec_t_into(&x, &mut y);
        slices_close(&y, &a.matvec_t(&x), 1e-6, 1e-6, "matvec_t_into").unwrap();
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::randn(13, 47, 6);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn slice_and_set_block_roundtrip() {
        let a = Matrix::randn(10, 10, 7);
        let block = a.slice(2, 6, 3, 9);
        assert_eq!((block.rows, block.cols), (4, 6));
        assert_eq!(block.at(0, 0), a.at(2, 3));
        let mut b = Matrix::zeros(10, 10);
        b.set_block(2, 3, &block);
        assert_eq!(b.at(5, 8), a.at(5, 8));
        assert_eq!(b.at(0, 0), 0.0);
    }

    #[test]
    fn permute_sym_identity_is_noop() {
        let a = Matrix::randn(8, 8, 8);
        let id: Vec<usize> = (0..8).collect();
        assert_eq!(a.permute_sym(&id), a);
    }

    #[test]
    fn permute_sym_reverses() {
        let a = Matrix::from_fn(3, 3, |i, j| (i * 3 + j) as f32);
        let p = vec![2usize, 1, 0];
        let ap = a.permute_sym(&p);
        assert_eq!(ap.at(0, 0), a.at(2, 2));
        assert_eq!(ap.at(0, 2), a.at(2, 0));
        assert_eq!(ap.at(1, 1), a.at(1, 1));
    }

    #[test]
    fn matmul_associativity_property() {
        check(10, |rng| {
            let n = 4 + rng.below(12);
            let a = Matrix::randn(n, n, rng.next_u64());
            let b = Matrix::randn(n, n, rng.next_u64());
            let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
            // (A B) x == A (B x)
            let lhs = a.matmul(&b).matvec(&x);
            let rhs = a.matvec(&b.matvec(&x));
            slices_close(&lhs, &rhs, 1e-3, 1e-3, "assoc")
        });
    }

    #[test]
    fn dot_handles_remainders() {
        for k in 0..9 {
            let a: Vec<f32> = (0..k).map(|i| i as f32).collect();
            let b = vec![2.0f32; k];
            let expect: f32 = a.iter().sum::<f32>() * 2.0;
            assert_eq!(dot(&a, &b, k), expect);
        }
    }
}
