//! One-sided Jacobi SVD and truncated-SVD helpers.
//!
//! Jacobi is chosen over Golub-Kahan for robustness and simplicity at the
//! block sizes the HSS builder produces (≤ 2048); accuracy is to f32 working
//! precision. `truncated_svd` returns the paper's absorbed-factor form
//! U√Σ · √ΣVᵀ used by sSVD and the HSS off-diagonal couplings.

use crate::linalg::Matrix;

/// Full SVD result: a = u · diag(s) · vᵀ with u (m×r), v (n×r), r = min(m,n).
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub v: Matrix,
}

/// One-sided Jacobi SVD (on AᵀA implicitly, by rotating columns of A).
pub fn svd(a: &Matrix) -> Svd {
    let (m, n) = (a.rows, a.cols);
    if m < n {
        // svd(Aᵀ) and swap factors
        let t = svd(&a.transpose());
        return Svd {
            u: t.v,
            s: t.s,
            v: t.u,
        };
    }
    // work on column-major copy: w.row(j) = column j of A (length m)
    let mut w = a.transpose();
    let eps = 1e-9f64;
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Need split borrow of rows p and q
                let (alpha, beta, gamma) = {
                    let cp = w.row(p);
                    let cq = w.row(q);
                    let mut alpha = 0.0f64; // ‖cp‖²
                    let mut beta = 0.0f64; // ‖cq‖²
                    let mut gamma = 0.0f64; // cp·cq
                    for i in 0..m {
                        let x = cp[i] as f64;
                        let y = cq[i] as f64;
                        alpha += x * x;
                        beta += y * y;
                        gamma += x * y;
                    }
                    (alpha, beta, gamma)
                };
                if gamma.abs() <= eps * (alpha * beta).sqrt() || gamma == 0.0 {
                    continue;
                }
                off += gamma * gamma / (alpha * beta).max(1e-300);
                // Jacobi rotation
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                // rotate columns p and q
                let cols = w.cols;
                let (rp, rq) = {
                    let (head, tail) = w.data.split_at_mut(q * cols);
                    (
                        &mut head[p * cols..p * cols + m],
                        &mut tail[..m],
                    )
                };
                for i in 0..m {
                    let x = rp[i];
                    let y = rq[i];
                    rp[i] = (c * x as f64 - s * y as f64) as f32;
                    rq[i] = (s * x as f64 + c * y as f64) as f32;
                }
            }
        }
        if off < 1e-18 {
            break;
        }
    }
    // singular values = column norms; U = normalized columns; V accumulated
    // via V = Aᵀ U Σ⁻¹ (cheaper: recompute from original A)
    let mut s: Vec<(f32, usize)> = (0..n)
        .map(|j| {
            let norm = w.row(j).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32;
            (norm, j)
        })
        .collect();
    s.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut u = Matrix::zeros(m, n);
    let mut sv = Vec::with_capacity(n);
    for (out_j, &(sig, j)) in s.iter().enumerate() {
        sv.push(sig);
        if sig > 1e-30 {
            let col = w.row(j);
            for i in 0..m {
                u.set(i, out_j, col[i] / sig);
            }
        }
    }
    // V from A v_j = ... : V = Aᵀ U Σ⁻¹
    let at_u = a.transpose().matmul(&u); // n×n
    let mut v = Matrix::zeros(n, n);
    for j in 0..n {
        let sig = sv[j];
        if sig > 1e-30 {
            for i in 0..n {
                v.set(i, j, at_u.at(i, j) / sig);
            }
        }
    }
    Svd { u, s: sv, v }
}

/// Truncated SVD in absorbed form: a ≈ l · r with l = U_k √Σ_k (m×k) and
/// r = √Σ_k V_kᵀ (k×n). Rank is capped by `max_rank` and by the count of
/// singular values above `tol`. Always returns rank ≥ 1.
pub fn truncated_svd(a: &Matrix, max_rank: usize, tol: f32) -> (Matrix, Matrix) {
    let f = svd(a);
    split_factors(&f, max_rank, tol)
}

/// Shared truncation logic (also used by the randomized path).
pub(crate) fn split_factors(f: &Svd, max_rank: usize, tol: f32) -> (Matrix, Matrix) {
    let above = f.s.iter().take_while(|&&s| s > tol).count();
    let k = max_rank.min(f.s.len()).min(above).max(1);
    let m = f.u.rows;
    let n = f.v.rows;
    let mut l = Matrix::zeros(m, k);
    let mut r = Matrix::zeros(k, n);
    for j in 0..k {
        let sq = f.s[j].max(0.0).sqrt();
        for i in 0..m {
            l.set(i, j, f.u.at(i, j) * sq);
        }
        for i in 0..n {
            r.set(j, i, f.v.at(i, j) * sq);
        }
    }
    (l, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::{fro, rel_fro_error};
    use crate::util::proptest::check;

    #[test]
    fn reconstructs_square() {
        let a = Matrix::randn(16, 16, 1);
        let f = svd(&a);
        let mut usv = Matrix::zeros(16, 16);
        for i in 0..16 {
            for j in 0..16 {
                let mut acc = 0.0;
                for k in 0..16 {
                    acc += f.u.at(i, k) * f.s[k] * f.v.at(j, k);
                }
                usv.set(i, j, acc);
            }
        }
        assert!(rel_fro_error(&usv, &a) < 1e-4, "{}", rel_fro_error(&usv, &a));
    }

    #[test]
    fn singular_values_sorted_nonneg() {
        let a = Matrix::randn(12, 20, 2);
        let f = svd(&a);
        for w in f.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(f.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn u_v_orthonormal() {
        let a = Matrix::randn(15, 10, 3);
        let f = svd(&a);
        let utu = f.u.transpose().matmul(&f.u);
        let vtv = f.v.transpose().matmul(&f.v);
        assert!(rel_fro_error(&utu, &Matrix::identity(10)) < 1e-3);
        assert!(rel_fro_error(&vtv, &Matrix::identity(10)) < 1e-3);
    }

    #[test]
    fn known_diagonal() {
        let mut a = Matrix::zeros(3, 3);
        a.set(0, 0, 5.0);
        a.set(1, 1, 2.0);
        a.set(2, 2, 1.0);
        let f = svd(&a);
        assert!((f.s[0] - 5.0).abs() < 1e-5);
        assert!((f.s[1] - 2.0).abs() < 1e-5);
        assert!((f.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rank_one_matrix() {
        // a = u vᵀ has one nonzero singular value = ‖u‖‖v‖
        let u: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..7).map(|i| 1.0 + i as f32).collect();
        let a = Matrix::from_fn(10, 7, |i, j| u[i] * v[j]);
        let f = svd(&a);
        let nu: f32 = u.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nv: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!((f.s[0] - nu * nv).abs() / (nu * nv) < 1e-4);
        assert!(f.s[1] < 1e-3);
    }

    #[test]
    fn truncated_is_best_rank_k_ish() {
        // truncation error should match the tail singular values
        let a = Matrix::randn(20, 20, 4);
        let f = svd(&a);
        let (l, r) = truncated_svd(&a, 5, 0.0);
        assert_eq!(l.cols, 5);
        let rec = l.matmul(&r);
        let err = {
            let d = rec.sub(&a);
            fro(&d)
        };
        let tail: f64 = f.s[5..].iter().map(|&s| (s as f64) * (s as f64)).sum::<f64>().sqrt();
        assert!((err - tail).abs() / tail.max(1e-9) < 0.05, "err {err} tail {tail}");
    }

    #[test]
    fn truncated_respects_tol() {
        let mut a = Matrix::zeros(8, 8);
        a.set(0, 0, 10.0);
        a.set(1, 1, 1e-8);
        let (l, _r) = truncated_svd(&a, 8, 1e-4);
        assert_eq!(l.cols, 1);
    }

    #[test]
    fn reconstruction_property_random_shapes() {
        check(8, |rng| {
            let m = 3 + rng.below(15);
            let n = 3 + rng.below(15);
            let a = Matrix::randn(m, n, rng.next_u64());
            let k = m.min(n);
            let (l, r) = truncated_svd(&a, k, 0.0);
            let err = rel_fro_error(&l.matmul(&r), &a);
            if err < 5e-3 {
                Ok(())
            } else {
                Err(format!("full-rank truncation err {err}"))
            }
        });
    }
}
