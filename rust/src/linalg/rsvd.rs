//! Randomized SVD (Halko-Martinsson-Tropp): Gaussian sketch + QR range
//! finder + small exact SVD, with oversampling and power iterations.
//!
//! This is the paper's scalable variant for the sparse-plus-low-rank
//! baselines (sR-SVD) and the default factorizer inside the HSS builder.

use crate::linalg::qr::qr;
use crate::linalg::svd::{split_factors, svd};
use crate::linalg::Matrix;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct RsvdOptions {
    pub oversample: usize,
    pub power_iters: usize,
    pub seed: u64,
}

impl Default for RsvdOptions {
    fn default() -> Self {
        RsvdOptions {
            oversample: 8,
            power_iters: 1,
            seed: 0x5EED,
        }
    }
}

/// Randomized truncated SVD in absorbed form (l = U√Σ, r = √ΣVᵀ).
/// Rank capped by `max_rank` and the `tol` threshold; always ≥ 1.
pub fn randomized_svd(
    a: &Matrix,
    max_rank: usize,
    tol: f32,
    opts: RsvdOptions,
) -> (Matrix, Matrix) {
    let (m, n) = (a.rows, a.cols);
    let l = (max_rank + opts.oversample).min(m.min(n)).max(1);

    // sketch: Y = A Ω, Ω n×l Gaussian
    let mut rng = Rng::new(opts.seed);
    let mut omega = Matrix::zeros(n, l);
    rng.fill_gaussian(&mut omega.data);
    let mut y = a.matmul(&omega);

    // power iterations with re-orthonormalization: Y <- A (Aᵀ Q)
    for _ in 0..opts.power_iters {
        let q = qr(&y).q;
        let atq = a.transpose().matmul(&q);
        y = a.matmul(&atq);
    }
    let q = qr(&y).q; // m×l orthonormal range basis

    // B = Qᵀ A is l×n, small exact SVD
    let b = q.transpose().matmul(a);
    let fb = svd(&b);
    // lift: U = Q Ub
    let u = q.matmul(&fb.u);
    let lifted = crate::linalg::svd::Svd {
        u,
        s: fb.s,
        v: fb.v,
    };
    split_factors(&lifted, max_rank, tol)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::rel_fro_error;
    use crate::linalg::svd::truncated_svd;
    use crate::util::proptest::check;

    fn low_rank_plus_noise(m: usize, n: usize, r: usize, noise: f32, seed: u64) -> Matrix {
        let u = Matrix::randn(m, r, seed);
        let v = Matrix::randn(r, n, seed + 1);
        let mut a = u.matmul(&v);
        let e = Matrix::randn(m, n, seed + 2);
        for (x, y) in a.data.iter_mut().zip(&e.data) {
            *x += noise * y;
        }
        a
    }

    #[test]
    fn recovers_exact_low_rank() {
        let a = low_rank_plus_noise(40, 30, 5, 0.0, 1);
        let (l, r) = randomized_svd(&a, 5, 0.0, RsvdOptions::default());
        assert!(rel_fro_error(&l.matmul(&r), &a) < 1e-3);
    }

    #[test]
    fn close_to_exact_truncation() {
        let a = low_rank_plus_noise(50, 50, 8, 0.05, 2);
        let (le, re) = truncated_svd(&a, 8, 0.0);
        let (lr, rr) = randomized_svd(
            &a,
            8,
            0.0,
            RsvdOptions {
                oversample: 10,
                power_iters: 2,
                seed: 3,
            },
        );
        let exact_err = rel_fro_error(&le.matmul(&re), &a);
        let rand_err = rel_fro_error(&lr.matmul(&rr), &a);
        // HMT bound: randomized within a small factor of optimal
        assert!(rand_err <= exact_err * 1.25 + 1e-4, "{rand_err} vs {exact_err}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = low_rank_plus_noise(20, 20, 4, 0.1, 4);
        let o = RsvdOptions::default();
        let (l1, r1) = randomized_svd(&a, 4, 0.0, o);
        let (l2, r2) = randomized_svd(&a, 4, 0.0, o);
        assert_eq!(l1.data, l2.data);
        assert_eq!(r1.data, r2.data);
    }

    #[test]
    fn power_iterations_improve_noisy_case() {
        let a = low_rank_plus_noise(60, 60, 6, 0.3, 5);
        let err0 = {
            let (l, r) = randomized_svd(&a, 6, 0.0, RsvdOptions { oversample: 2, power_iters: 0, seed: 6 });
            rel_fro_error(&l.matmul(&r), &a)
        };
        let err2 = {
            let (l, r) = randomized_svd(&a, 6, 0.0, RsvdOptions { oversample: 2, power_iters: 2, seed: 6 });
            rel_fro_error(&l.matmul(&r), &a)
        };
        assert!(err2 <= err0 + 1e-4, "{err2} vs {err0}");
    }

    #[test]
    fn shape_property() {
        check(8, |rng| {
            let m = 5 + rng.below(30);
            let n = 5 + rng.below(30);
            let k = 1 + rng.below(5);
            let a = Matrix::randn(m, n, rng.next_u64());
            let (l, r) = randomized_svd(&a, k, 0.0, RsvdOptions::default());
            if l.rows == m && l.cols <= k && r.rows == l.cols && r.cols == n {
                Ok(())
            } else {
                Err(format!("bad shapes {}x{} {}x{}", l.rows, l.cols, r.rows, r.cols))
            }
        });
    }
}
