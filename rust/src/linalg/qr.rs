//! Householder QR factorization (thin Q), the workhorse behind the
//! randomized SVD's orthonormalization step.

use crate::linalg::Matrix;

/// Thin QR: A (m×n, m ≥ n) = Q (m×n, orthonormal cols) · R (n×n upper).
pub struct Qr {
    pub q: Matrix,
    pub r: Matrix,
}

/// Householder QR with column-major scratch; returns thin Q and R.
pub fn qr(a: &Matrix) -> Qr {
    let m = a.rows;
    let n = a.cols;
    assert!(m >= n, "qr expects m >= n (got {m}x{n})");
    // work on a column-major copy for contiguous column access
    let mut w = a.transpose(); // w.row(j) is column j of A, length m
    let mut vs: Vec<Vec<f32>> = Vec::with_capacity(n); // householder vectors

    for j in 0..n {
        // compute householder vector for column j below the diagonal
        let col = &w.row(j)[j..];
        let alpha = {
            let norm = col.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            if col[0] >= 0.0 {
                -norm
            } else {
                norm
            }
        } as f32;
        let mut v = col.to_vec();
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
        if vnorm2 > 1e-30 {
            // apply H = I - 2 v vᵀ / (vᵀv) to remaining columns j..n
            for jj in j..n {
                let cj = &mut w.row_mut(jj)[j..];
                let dot: f64 = v.iter().zip(cj.iter()).map(|(&a, &b)| a as f64 * b as f64).sum();
                let beta = (2.0 * dot / vnorm2) as f32;
                for (ci, &vi) in cj.iter_mut().zip(&v) {
                    *ci -= beta * vi;
                }
            }
        }
        vs.push(v);
    }

    // R = upper n×n of transformed matrix
    let mut r = Matrix::zeros(n, n);
    for j in 0..n {
        for i in 0..=j {
            r.set(i, j, w.row(j)[i]);
        }
    }

    // Q = H_0 H_1 ... H_{n-1} applied to the first n columns of I (thin Q),
    // built column-major then transposed.
    let mut qt = Matrix::zeros(n, m); // row j = column j of Q
    for j in 0..n {
        let qcol = qt.row_mut(j);
        qcol[j] = 1.0;
        // apply H_k for k = n-1 .. 0
        for k in (0..=j.min(vs.len() - 1)).rev() {
            let v = &vs[k];
            let vnorm2: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
            if vnorm2 <= 1e-30 {
                continue;
            }
            let seg = &mut qcol[k..];
            let dot: f64 = v.iter().zip(seg.iter()).map(|(&a, &b)| a as f64 * b as f64).sum();
            let beta = (2.0 * dot / vnorm2) as f32;
            for (si, &vi) in seg.iter_mut().zip(v) {
                *si -= beta * vi;
            }
        }
    }
    Qr {
        q: qt.transpose(),
        r,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::rel_fro_error;
    use crate::util::proptest::check;

    #[test]
    fn reconstructs_a() {
        let a = Matrix::randn(20, 8, 1);
        let f = qr(&a);
        let qa = f.q.matmul(&f.r);
        assert!(rel_fro_error(&qa, &a) < 1e-4, "{}", rel_fro_error(&qa, &a));
    }

    #[test]
    fn q_orthonormal() {
        let a = Matrix::randn(30, 10, 2);
        let f = qr(&a);
        let qtq = f.q.transpose().matmul(&f.q);
        let i = Matrix::identity(10);
        assert!(rel_fro_error(&qtq, &i) < 1e-4);
    }

    #[test]
    fn r_upper_triangular() {
        let a = Matrix::randn(12, 12, 3);
        let f = qr(&a);
        for i in 0..12 {
            for j in 0..i {
                assert!(f.r.at(i, j).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn square_and_tall_shapes_property() {
        check(10, |rng| {
            let n = 2 + rng.below(12);
            let m = n + rng.below(20);
            let a = Matrix::randn(m, n, rng.next_u64());
            let f = qr(&a);
            let err = rel_fro_error(&f.q.matmul(&f.r), &a);
            if err < 5e-4 {
                Ok(())
            } else {
                Err(format!("qr reconstruction err {err}"))
            }
        });
    }

    #[test]
    fn handles_rank_deficient() {
        // two identical columns
        let mut a = Matrix::randn(10, 3, 4);
        for i in 0..10 {
            let v = a.at(i, 0);
            a.set(i, 1, v);
        }
        let f = qr(&a);
        assert!(rel_fro_error(&f.q.matmul(&f.r), &a) < 1e-4);
    }
}
