//! Dense linear algebra substrate: row-major [`Matrix`], blocked matmul,
//! Householder QR, one-sided Jacobi SVD, randomized SVD, norms, and
//! [`Permutation`].
//!
//! Everything the compression pipeline needs is implemented natively (the
//! offline environment has no BLAS/LAPACK crates); the hot paths are blocked
//! and allocation-free per DESIGN.md §10, and route through the
//! runtime-dispatched SIMD kernel layer in [`simd`].

pub mod matrix;
pub mod norms;
pub mod permutation;
pub mod qr;
pub mod rsvd;
pub mod simd;
pub mod svd;
pub mod weightbuf;

pub use matrix::Matrix;
pub use permutation::Permutation;
pub use weightbuf::{Dtype, MapRange, Storage, WeightBuf, WeightElem};
pub use rsvd::{randomized_svd, RsvdOptions};
pub use svd::{truncated_svd, Svd};
