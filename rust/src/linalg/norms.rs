//! Matrix/vector norms and error metrics.

use crate::linalg::Matrix;

/// Frobenius norm.
pub fn fro(m: &Matrix) -> f64 {
    m.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Vector 2-norm.
pub fn norm2(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Relative Frobenius error ‖a − b‖_F / ‖b‖_F.
pub fn rel_fro_error(a: &Matrix, b: &Matrix) -> f64 {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let mut num = 0.0f64;
    for (x, y) in a.data.iter().zip(&b.data) {
        let d = (*x - *y) as f64;
        num += d * d;
    }
    let den = fro(b).max(1e-30);
    num.sqrt() / den
}

/// Spectral-norm estimate by power iteration (‖A‖₂).
pub fn spectral_est(m: &Matrix, iters: usize, seed: u64) -> f64 {
    let mut x: Vec<f32> = Matrix::randn(m.cols, 1, seed).col(0);
    let nx = norm2(&x).max(1e-30);
    x.iter_mut().for_each(|v| *v /= nx as f32);
    let mut sigma = 0.0;
    for _ in 0..iters {
        let y = m.matvec(&x); // A x
        let z = m.matvec_t(&y); // Aᵀ A x
        let nz = norm2(&z);
        if nz < 1e-30 {
            return 0.0;
        }
        sigma = norm2(&y);
        x = z.iter().map(|&v| (v as f64 / nz) as f32).collect();
    }
    sigma
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fro_of_identity() {
        let i = Matrix::identity(9);
        assert!((fro(&i) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn rel_error_zero_for_equal() {
        let a = Matrix::randn(6, 6, 1);
        assert!(rel_fro_error(&a, &a) < 1e-12);
    }

    #[test]
    fn rel_error_one_for_zero_vs_a() {
        let a = Matrix::randn(6, 6, 2);
        let z = Matrix::zeros(6, 6);
        assert!((rel_fro_error(&z, &a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn spectral_of_diagonal() {
        // diag(3, 1, 0.5) has spectral norm 3
        let mut d = Matrix::zeros(3, 3);
        d.set(0, 0, 3.0);
        d.set(1, 1, 1.0);
        d.set(2, 2, 0.5);
        let s = spectral_est(&d, 50, 3);
        assert!((s - 3.0).abs() < 1e-3, "{s}");
    }

    #[test]
    fn spectral_bounded_by_fro() {
        let a = Matrix::randn(20, 20, 4);
        let s = spectral_est(&a, 30, 5);
        assert!(s <= fro(&a) + 1e-3);
    }
}
