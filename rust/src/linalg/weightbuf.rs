//! Dtype-generic element storage for resident weights.
//!
//! The paper's storage accounting is fp16, and the `HSB1` store writes
//! fp16 factors — but until this layer existed the loader widened every
//! value to f32, so served models were resident at twice the bytes the
//! format pays for. [`WeightBuf`] lets every weight-holding type
//! ([`crate::linalg::Matrix`] factors, [`crate::sparse::Csr`] values, HSS
//! leaves/couplings) stay half-precision in memory; the batched kernels
//! widen lane-by-lane as the weights stream through, which the batch
//! amortizes over its k columns.
//!
//! Residency contract:
//! - **f32-resident** buffers behave exactly like `Vec<f32>` (the buffer
//!   derefs to `[f32]`), so compression, training, and every pre-existing
//!   f32 code path is unchanged.
//! - **f16-resident** buffers only flow through dtype-aware code: the
//!   widened kernels in `linalg::matrix` / `sparse::csr`, storage
//!   accounting, and the store codec. Touching one through the f32 deref
//!   panics with a pointed message — training requires an explicit
//!   `widen_to_f32` first (`finetune` trains f32 and narrows on save).
//!
//! Because f16 → f32 conversion is exact and the kernels monomorphize the
//! same arithmetic for both dtypes, an f16-resident apply is bit-identical
//! to quantizing the same factors in f32 and applying those — halving
//! memory changes no numerics beyond the fp16 rounding the store already
//! imposed.

use crate::util::fp16::{f16_to_f32, f32_to_f16};

/// Element dtype of a resident weight buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F16,
}

impl Dtype {
    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::F16 => "f16",
        }
    }

    /// Resident bytes per stored value.
    pub fn value_bytes(&self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F16 => 2,
        }
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Dtype {
    type Err = String;

    fn from_str(s: &str) -> Result<Dtype, String> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Ok(Dtype::F32),
            "f16" | "fp16" | "half" => Ok(Dtype::F16),
            o => Err(format!("unknown dtype '{o}' (f32|f16)")),
        }
    }
}

/// A weight element the generic kernels can widen to f32 in-register.
/// `widen` is the identity for f32, so the f32 monomorphization compiles
/// to exactly the pre-dtype-generic kernels.
pub trait WeightElem: Copy {
    /// Whether `as_f32_lanes` needs a scratch buffer (true for f16).
    /// Kernels branch on this const so the pure-f32 monomorphizations
    /// never touch (or zero-initialize) staging storage.
    const NEEDS_WIDEN: bool;

    fn widen(self) -> f32;

    /// View a run of elements as f32 lanes for the SIMD kernels: the f32
    /// impl returns the slice itself (zero-copy, scratch untouched); the
    /// f16 impl widens into the caller's scratch via the dispatched
    /// `simd::widen_f16_lanes` — the single f16→f32 widening primitive —
    /// and returns the widened prefix. When `NEEDS_WIDEN`,
    /// `scratch.len() >= src.len()` is required.
    fn as_f32_lanes<'a>(src: &'a [Self], scratch: &'a mut [f32]) -> &'a [f32];
}

impl WeightElem for f32 {
    const NEEDS_WIDEN: bool = false;

    #[inline(always)]
    fn widen(self) -> f32 {
        self
    }

    #[inline(always)]
    fn as_f32_lanes<'a>(src: &'a [f32], _scratch: &'a mut [f32]) -> &'a [f32] {
        src
    }
}

impl WeightElem for u16 {
    const NEEDS_WIDEN: bool = true;

    #[inline(always)]
    fn widen(self) -> f32 {
        f16_to_f32(self)
    }

    #[inline]
    fn as_f32_lanes<'a>(src: &'a [u16], scratch: &'a mut [f32]) -> &'a [f32] {
        let dst = &mut scratch[..src.len()];
        (crate::linalg::simd::kernels().widen_f16_lanes)(src, dst);
        dst
    }
}

/// Marker for element types a [`MapRange`] may reinterpret mapped bytes
/// as. Sealed to `f32` and `u16`: both accept every bit pattern, so the
/// reinterpret in [`MapRange::as_slice`] is sound for exactly these.
pub trait MapElem: Copy + PartialEq + Send + Sync + 'static + private::Sealed {}

mod private {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for u16 {}
}

impl MapElem for f32 {}
impl MapElem for u16 {}

/// A typed view into a memory-mapped store file: `len` elements of `T`
/// starting `off` bytes into the mapping. Holding the `Arc` keeps the
/// mapping alive for as long as any weight borrows it; cloning is an
/// `Arc` bump, never a copy of the weights.
#[derive(Clone)]
pub struct MapRange<T: MapElem> {
    map: std::sync::Arc<crate::util::mmap::Mmap>,
    off: usize,
    len: usize,
    _elem: std::marker::PhantomData<T>,
}

impl<T: MapElem> MapRange<T> {
    /// Build a borrowed view of `len` elements at byte offset `off`, or
    /// `None` when borrowing would be unsound or wrong: out of bounds,
    /// misaligned for `T`, or a big-endian host (the store is
    /// little-endian; a copy-decode is required there). Callers fall back
    /// to the owned decode path on `None`.
    pub fn new(
        map: std::sync::Arc<crate::util::mmap::Mmap>,
        off: usize,
        len: usize,
    ) -> Option<MapRange<T>> {
        if cfg!(target_endian = "big") {
            return None;
        }
        let bytes = len.checked_mul(std::mem::size_of::<T>())?;
        let end = off.checked_add(bytes)?;
        if end > map.len() {
            return None;
        }
        if (map.as_ptr() as usize + off) % std::mem::align_of::<T>() != 0 {
            return None;
        }
        Some(MapRange {
            map,
            off,
            len,
            _elem: std::marker::PhantomData,
        })
    }

    pub fn as_slice(&self) -> &[T] {
        // SAFETY: construction checked bounds and alignment against the
        // live mapping (held alive by `self.map`), and `T: MapElem`
        // accepts every bit pattern.
        unsafe {
            std::slice::from_raw_parts(self.map.as_ptr().add(self.off) as *const T, self.len)
        }
    }
}

/// Backing storage for one run of weight values: heap-owned (the decode
/// path that copies out of the file) or a borrowed window of an mmap'd
/// store file (the zero-copy serving path). Derefs to `[T]`, so kernels
/// and every read-only consumer are agnostic to which one they got;
/// mutation (`as_mut_slice`) is owned-only by construction — training
/// widens into owned buffers first.
#[derive(Clone)]
pub enum Storage<T: MapElem> {
    Owned(Vec<T>),
    Mapped(MapRange<T>),
}

impl<T: MapElem> Storage<T> {
    pub fn as_slice(&self) -> &[T] {
        match self {
            Storage::Owned(v) => v,
            Storage::Mapped(m) => m.as_slice(),
        }
    }

    /// Mutable access to the values; panics for mapped storage (the
    /// mapping is `PROT_READ` and shared between processes — every write
    /// path must copy to owned first, which `to_f32` widening does).
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        match self {
            Storage::Owned(v) => v,
            Storage::Mapped(_) => {
                panic!("mmap-backed weight buffer is read-only (copy to owned before mutating)")
            }
        }
    }

    pub fn is_mapped(&self) -> bool {
        matches!(self, Storage::Mapped(_))
    }

    /// The values as an owned `Vec`, copying only when mapped.
    pub fn into_owned(self) -> Vec<T> {
        match self {
            Storage::Owned(v) => v,
            Storage::Mapped(m) => m.as_slice().to_vec(),
        }
    }
}

impl<T: MapElem> From<Vec<T>> for Storage<T> {
    fn from(v: Vec<T>) -> Storage<T> {
        Storage::Owned(v)
    }
}

impl<T: MapElem> std::ops::Deref for Storage<T> {
    type Target = [T];

    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: MapElem> std::ops::DerefMut for Storage<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        self.as_mut_slice()
    }
}

impl<T: MapElem> PartialEq for Storage<T> {
    fn eq(&self, other: &Storage<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<'a, T: MapElem> IntoIterator for &'a Storage<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<T: MapElem> std::fmt::Debug for Storage<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Storage::{}[{}]",
            if self.is_mapped() { "Mapped" } else { "Owned" },
            self.as_slice().len()
        )
    }
}

/// Dtype-generic element storage: f32 values, or f16 stored as raw `u16`
/// bit patterns (the store's on-disk representation, kept resident).
/// Either dtype may be heap-owned or a zero-copy borrow of an mmap'd
/// store file — see [`Storage`]; numerics are identical (same bytes
/// through the same kernels), only who owns the bytes differs.
#[derive(Clone, PartialEq)]
pub enum WeightBuf {
    F32(Storage<f32>),
    F16(Storage<u16>),
}

impl WeightBuf {
    /// Whether the values borrow an mmap'd store file rather than owning
    /// heap memory (shared page-cache bytes across serving processes).
    pub fn is_mapped(&self) -> bool {
        match self {
            WeightBuf::F32(v) => v.is_mapped(),
            WeightBuf::F16(v) => v.is_mapped(),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            WeightBuf::F32(v) => v.len(),
            WeightBuf::F16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            WeightBuf::F32(_) => Dtype::F32,
            WeightBuf::F16(_) => Dtype::F16,
        }
    }

    /// Actual bytes this buffer keeps resident.
    pub fn resident_bytes(&self) -> usize {
        self.len() * self.dtype().value_bytes()
    }

    /// Widening single-element read (valid for either dtype).
    #[inline]
    pub fn at(&self, i: usize) -> f32 {
        match self {
            WeightBuf::F32(v) => v[i],
            WeightBuf::F16(v) => f16_to_f32(v[i]),
        }
    }

    /// The f32 payload; panics for f16-resident buffers (the f32-only
    /// paths — training, factorization — must widen first).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            WeightBuf::F32(v) => v,
            WeightBuf::F16(_) => panic!(
                "f16-resident weight buffer used on an f32-only path (widen_to_f32 first)"
            ),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match self {
            WeightBuf::F32(v) => v,
            WeightBuf::F16(_) => panic!(
                "f16-resident weight buffer used on an f32-only path (widen_to_f32 first)"
            ),
        }
    }

    /// The raw f16 bit patterns; panics for f32-resident buffers.
    pub fn as_f16(&self) -> &[u16] {
        match self {
            WeightBuf::F16(v) => v,
            WeightBuf::F32(_) => panic!("f32-resident weight buffer has no f16 payload"),
        }
    }

    /// Narrow to f16 residency (round-to-nearest-even; idempotent). A
    /// mapped f16 buffer stays mapped — narrowing is the serving path,
    /// which never mutates.
    pub fn to_f16(&self) -> WeightBuf {
        match self {
            WeightBuf::F32(v) => {
                WeightBuf::F16(v.iter().map(|&x| f32_to_f16(x)).collect::<Vec<u16>>().into())
            }
            WeightBuf::F16(v) => WeightBuf::F16(v.clone()),
        }
    }

    /// Widen to f32 residency (exact; idempotent). Bulk widening rides
    /// the same dispatched lane primitive as the kernels. Always yields
    /// an **owned** buffer — widening is the training on-ramp, and
    /// mapped storage is read-only.
    pub fn to_f32(&self) -> WeightBuf {
        match self {
            WeightBuf::F32(v) => WeightBuf::F32(v.as_slice().to_vec().into()),
            WeightBuf::F16(v) => {
                let mut out = vec![0.0f32; v.len()];
                (crate::linalg::simd::kernels().widen_f16_lanes)(v, &mut out);
                WeightBuf::F32(out.into())
            }
        }
    }
}

/// Widen raw binary16 bit patterns into a reusable f32 staging buffer
/// (exact; one pass through the dispatched `simd::widen_f16_lanes`
/// primitive — F16C on AVX2, the software codec elsewhere) and return
/// the widened prefix. `stage` grows on demand and is never shrunk, so a
/// workspace-owned buffer is allocation-free after warmup. This is the
/// f16 staging path of the batched apply engine: one wholesale widen per
/// block per call instead of per-element conversion inside the hot
/// kernel's lanes.
pub fn widen_f16_into<'a>(bits: &[u16], stage: &'a mut Vec<f32>) -> &'a [f32] {
    if stage.len() < bits.len() {
        stage.resize(bits.len(), 0.0);
    }
    (crate::linalg::simd::kernels().widen_f16_lanes)(bits, &mut stage[..bits.len()]);
    &stage[..bits.len()]
}

impl From<Vec<f32>> for WeightBuf {
    fn from(v: Vec<f32>) -> WeightBuf {
        WeightBuf::F32(v.into())
    }
}

impl From<Vec<u16>> for WeightBuf {
    fn from(v: Vec<u16>) -> WeightBuf {
        WeightBuf::F16(v.into())
    }
}

/// f32-resident buffers transparently behave as `[f32]` so the
/// compression/training substrate is unchanged; f16-resident buffers
/// panic here by design (see the module docs).
impl std::ops::Deref for WeightBuf {
    type Target = [f32];

    fn deref(&self) -> &[f32] {
        self.as_f32()
    }
}

impl std::ops::DerefMut for WeightBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        self.as_f32_mut()
    }
}

impl<'a> IntoIterator for &'a WeightBuf {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_f32().iter()
    }
}

impl std::fmt::Debug for WeightBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "WeightBuf::{}[{}]", self.dtype().name(), self.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fp16::quantize_f16;
    use crate::util::rng::Rng;

    #[test]
    fn f32_buffer_behaves_like_a_slice() {
        let mut b = WeightBuf::from(vec![1.0f32, 2.0, 3.0]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.dtype(), Dtype::F32);
        assert_eq!(b.resident_bytes(), 12);
        assert_eq!(b[1], 2.0);
        b[1] = 5.0;
        assert_eq!(b.at(1), 5.0);
        let total: f32 = (&b).into_iter().sum();
        assert_eq!(total, 9.0);
    }

    #[test]
    fn narrow_matches_quantize_and_halves_bytes() {
        let mut rng = Rng::new(3);
        let xs: Vec<f32> = (0..257).map(|_| rng.gaussian_f32()).collect();
        let b = WeightBuf::from(xs.clone());
        let h = b.to_f16();
        assert_eq!(h.dtype(), Dtype::F16);
        assert_eq!(h.resident_bytes() * 2, b.resident_bytes());
        let mut q = xs.clone();
        quantize_f16(&mut q);
        // widening back reproduces the fp16 round-trip exactly
        let w = h.to_f32();
        assert_eq!(w.as_f32(), q.as_slice());
        for (i, &want) in q.iter().enumerate() {
            assert_eq!(h.at(i), want, "at({i})");
        }
        // narrowing is idempotent
        assert_eq!(h.to_f16(), h);
    }

    #[test]
    fn dtype_parse_and_names() {
        assert_eq!("f16".parse::<Dtype>().unwrap(), Dtype::F16);
        assert_eq!("FP32".parse::<Dtype>().unwrap(), Dtype::F32);
        assert!("f64".parse::<Dtype>().is_err());
        assert_eq!(Dtype::F16.name(), "f16");
        assert_eq!(Dtype::F32.value_bytes(), 4);
    }

    #[test]
    #[should_panic(expected = "f32-only path")]
    fn f16_buffer_rejects_f32_deref() {
        let b = WeightBuf::from(vec![1.0f32, 2.0]).to_f16();
        let _ = b[0]; // deref to [f32] must panic, not silently misread
    }

    #[cfg(unix)]
    fn map_fixture(tag: &str, bytes: &[u8]) -> std::sync::Arc<crate::util::mmap::Mmap> {
        let p = std::env::temp_dir().join(format!("hisolo-wbuf-{}-{tag}", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        let m = std::sync::Arc::new(crate::util::mmap::Mmap::map(&p).unwrap());
        std::fs::remove_file(&p).unwrap(); // mapping outlives the unlink
        m
    }

    #[test]
    #[cfg(unix)]
    fn mapped_storage_reads_identically_to_owned() {
        let mut rng = Rng::new(11);
        let xs: Vec<f32> = (0..513).map(|_| rng.gaussian_f32()).collect();
        let bits: Vec<u16> = xs.iter().map(|&x| f32_to_f16(x)).collect();
        let mut bytes = Vec::new();
        for &b in &bits {
            bytes.extend_from_slice(&b.to_le_bytes());
        }
        let m = map_fixture("identical", &bytes);
        let range = MapRange::<u16>::new(m, 0, bits.len()).expect("aligned in-bounds borrow");
        let mapped = WeightBuf::F16(Storage::Mapped(range));
        let owned = WeightBuf::F16(bits.clone().into());
        assert!(mapped.is_mapped() && !owned.is_mapped());
        assert_eq!(mapped, owned); // bitwise: same u16 patterns
        assert_eq!(mapped.as_f16(), owned.as_f16());
        assert_eq!(mapped.resident_bytes(), owned.resident_bytes());
        for i in 0..bits.len() {
            assert_eq!(mapped.at(i).to_bits(), owned.at(i).to_bits(), "at({i})");
        }
        // widening materializes an owned, mutable buffer
        let widened = mapped.to_f32();
        assert!(!widened.is_mapped());
        assert_eq!(widened, owned.to_f32());
    }

    #[test]
    #[cfg(unix)]
    fn map_range_rejects_misaligned_and_out_of_bounds() {
        let m = map_fixture("bounds", &[0u8; 64]);
        // u16 needs 2-byte alignment relative to the (page-aligned) map base
        assert!(MapRange::<u16>::new(m.clone(), 1, 4).is_none());
        assert!(MapRange::<f32>::new(m.clone(), 2, 4).is_none());
        // in bounds exactly
        assert!(MapRange::<u16>::new(m.clone(), 0, 32).is_some());
        assert!(MapRange::<u16>::new(m.clone(), 0, 33).is_none());
        assert!(MapRange::<f32>::new(m.clone(), 48, 4).is_some());
        assert!(MapRange::<f32>::new(m, 52, 4).is_none());
    }

    #[test]
    #[cfg(unix)]
    #[should_panic(expected = "read-only")]
    fn mapped_storage_rejects_mutation() {
        let m = map_fixture("readonly", &[0u8; 16]);
        let mut s = Storage::<f32>::Mapped(MapRange::new(m, 0, 4).unwrap());
        s.as_mut_slice()[0] = 1.0;
    }

    #[test]
    fn widen_is_exact_for_every_f16_pattern_class() {
        // exhaustive over all finite f16 bit patterns: u16::widen equals
        // the codec's decode
        for h in 0u16..=0xffff {
            let a = WeightElem::widen(h);
            let b = crate::util::fp16::f16_to_f32(h);
            assert!(a == b || (a.is_nan() && b.is_nan()), "{h:#06x}");
        }
    }
}
