//! Corpus loading (the artifact text splits produced by
//! `python/compile/corpus.py`).

use crate::model::tokenizer::ByteTokenizer;
use anyhow::{Context, Result};
use std::path::Path;

/// A tokenized corpus split.
pub struct Corpus {
    pub tokens: Vec<u32>,
}

impl Corpus {
    pub fn load(path: &Path) -> Result<Corpus> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading corpus {}", path.display()))?;
        Ok(Corpus {
            tokens: ByteTokenizer.encode_bytes(&bytes),
        })
    }

    pub fn from_text(text: &str) -> Corpus {
        Corpus {
            tokens: ByteTokenizer.encode(text),
        }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_text_tokenizes() {
        let c = Corpus::from_text("abc");
        assert_eq!(c.tokens, vec![97, 98, 99]);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn load_missing_file_errors() {
        assert!(Corpus::load(Path::new("/nonexistent/corpus.txt")).is_err());
    }

    #[test]
    fn loads_artifact_corpus_if_present() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/corpus_test.txt");
        if !path.exists() {
            return;
        }
        let c = Corpus::load(&path).unwrap();
        assert!(c.len() > 10_000);
        assert!(c.tokens.iter().all(|&t| t < 256));
    }
}
