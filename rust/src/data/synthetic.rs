//! Synthetic matrix workloads for benches and tests: matrices with the
//! structure the paper exploits (low-rank bulk + magnitude spikes), plus
//! shuffled-banded matrices that isolate the RCM effect.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// "Trained-projection-like" matrix: smooth low-rank bulk, small noise,
/// and ~3N large-magnitude spikes — the profile §3.4 describes.
pub fn trained_like(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let u = Matrix::randn(n, 8.min(n), seed.wrapping_add(1));
    let v = Matrix::randn(8.min(n), n, seed.wrapping_add(2));
    let mut a = u.matmul(&v).scale(0.1);
    for x in a.data.iter_mut() {
        *x += 0.02 * rng.gaussian_f32();
    }
    for _ in 0..3 * n {
        let i = rng.below(n);
        let j = rng.below(n);
        a.data[i * n + j] += 2.0 * rng.gaussian_f32();
    }
    a
}

/// Banded matrix hidden behind a random symmetric permutation — the
/// motivating case where RCM recovers diagonal concentration.
pub fn shuffled_banded(n: usize, half_band: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let band = Matrix::from_fn(n, n, |i, j| {
        if i.abs_diff(j) <= half_band {
            rng.gaussian_f32()
        } else {
            0.01 * rng.gaussian_f32()
        }
    });
    let mut p: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut p);
    band.permute_sym(&p)
}

/// Deterministic synthetic token stream — stands in for a corpus split
/// when artifacts are absent, so calibration/finetune paths run
/// end-to-end in any environment. Uses the crate PRNG rather than a bare
/// linear map of the index, which degenerates to a constant stream
/// whenever the vocab shares a factor with the multiplier.
pub fn token_stream(len: usize, vocab: usize) -> Vec<u32> {
    assert!(vocab > 0);
    let mut rng = Rng::new(0xC0FFEE);
    (0..len).map(|_| rng.below(vocab) as u32).collect()
}

/// Exactly low-rank matrix plus Gaussian noise (rsvd stress case).
pub fn low_rank_noise(n: usize, rank: usize, noise: f32, seed: u64) -> Matrix {
    let u = Matrix::randn(n, rank, seed.wrapping_add(10));
    let v = Matrix::randn(rank, n, seed.wrapping_add(11));
    let mut a = u.matmul(&v);
    let e = Matrix::randn(n, n, seed.wrapping_add(12));
    for (x, y) in a.data.iter_mut().zip(&e.data) {
        *x += noise * y;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd;

    #[test]
    fn trained_like_has_spikes() {
        let a = trained_like(64, 1);
        let max = a.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let mean: f32 =
            a.data.iter().map(|v| v.abs()).sum::<f32>() / a.data.len() as f32;
        assert!(max > 8.0 * mean, "max {max} mean {mean}");
    }

    #[test]
    fn low_rank_noise_spectrum_decays() {
        let a = low_rank_noise(32, 4, 0.01, 2);
        let f = svd(&a);
        assert!(f.s[3] > 10.0 * f.s[4], "σ4 {} σ5 {}", f.s[3], f.s[4]);
    }

    #[test]
    fn token_stream_in_vocab_and_deterministic() {
        // vocabs sharing a factor with common LCG constants included —
        // the stream must never degenerate to a constant
        for vocab in [64usize, 15, 3, 5, 256] {
            let a = token_stream(1000, vocab);
            assert_eq!(a.len(), 1000);
            assert!(a.iter().all(|&t| (t as usize) < vocab));
            assert_eq!(a, token_stream(1000, vocab));
            if vocab > 1 {
                assert!(
                    a.windows(2).any(|w| w[0] != w[1]),
                    "constant stream at vocab {vocab}"
                );
            }
        }
    }

    #[test]
    fn shuffled_banded_deterministic() {
        assert_eq!(
            shuffled_banded(32, 2, 3).data,
            shuffled_banded(32, 2, 3).data
        );
    }
}
