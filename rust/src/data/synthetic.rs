//! Synthetic matrix workloads for benches and tests: matrices with the
//! structure the paper exploits (low-rank bulk + magnitude spikes), plus
//! shuffled-banded matrices that isolate the RCM effect.

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// "Trained-projection-like" matrix: smooth low-rank bulk, small noise,
/// and ~3N large-magnitude spikes — the profile §3.4 describes.
pub fn trained_like(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let u = Matrix::randn(n, 8.min(n), seed.wrapping_add(1));
    let v = Matrix::randn(8.min(n), n, seed.wrapping_add(2));
    let mut a = u.matmul(&v).scale(0.1);
    for x in a.data.iter_mut() {
        *x += 0.02 * rng.gaussian_f32();
    }
    for _ in 0..3 * n {
        let i = rng.below(n);
        let j = rng.below(n);
        a.data[i * n + j] += 2.0 * rng.gaussian_f32();
    }
    a
}

/// Banded matrix hidden behind a random symmetric permutation — the
/// motivating case where RCM recovers diagonal concentration.
pub fn shuffled_banded(n: usize, half_band: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let band = Matrix::from_fn(n, n, |i, j| {
        if i.abs_diff(j) <= half_band {
            rng.gaussian_f32()
        } else {
            0.01 * rng.gaussian_f32()
        }
    });
    let mut p: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut p);
    band.permute_sym(&p)
}

/// Exactly low-rank matrix plus Gaussian noise (rsvd stress case).
pub fn low_rank_noise(n: usize, rank: usize, noise: f32, seed: u64) -> Matrix {
    let u = Matrix::randn(n, rank, seed.wrapping_add(10));
    let v = Matrix::randn(rank, n, seed.wrapping_add(11));
    let mut a = u.matmul(&v);
    let e = Matrix::randn(n, n, seed.wrapping_add(12));
    for (x, y) in a.data.iter_mut().zip(&e.data) {
        *x += noise * y;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::svd::svd;

    #[test]
    fn trained_like_has_spikes() {
        let a = trained_like(64, 1);
        let max = a.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let mean: f32 =
            a.data.iter().map(|v| v.abs()).sum::<f32>() / a.data.len() as f32;
        assert!(max > 8.0 * mean, "max {max} mean {mean}");
    }

    #[test]
    fn low_rank_noise_spectrum_decays() {
        let a = low_rank_noise(32, 4, 0.01, 2);
        let f = svd(&a);
        assert!(f.s[3] > 10.0 * f.s[4], "σ4 {} σ5 {}", f.s[3], f.s[4]);
    }

    #[test]
    fn shuffled_banded_deterministic() {
        assert_eq!(
            shuffled_banded(32, 2, 3).data,
            shuffled_banded(32, 2, 3).data
        );
    }
}
