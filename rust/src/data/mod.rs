//! Data layer: corpus reading, evaluation windowing, and the synthetic
//! matrix workloads the benches sweep.

pub mod corpus;
pub mod dataset;
pub mod synthetic;

pub use corpus::Corpus;
pub use dataset::windows;
