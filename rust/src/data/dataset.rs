//! Evaluation windowing: deterministic, evenly spaced token windows of
//! length `seq + 1` (inputs + next-token targets).

/// Evenly spaced windows over a token stream. Returns up to `count` windows
/// of length `seq + 1`; deterministic so every method sees identical data.
pub fn windows(tokens: &[u32], seq: usize, count: usize) -> Vec<Vec<u32>> {
    let need = seq + 1;
    if tokens.len() < need || count == 0 {
        return Vec::new();
    }
    let max_start = tokens.len() - need;
    let count = count.min(max_start + 1);
    let stride = if count > 1 { max_start / (count - 1) } else { 0 };
    (0..count)
        .map(|i| {
            let s = i * stride;
            tokens[s..s + need].to_vec()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produces_requested_count() {
        let toks: Vec<u32> = (0..1000).map(|i| i % 256).collect();
        let w = windows(&toks, 32, 8);
        assert_eq!(w.len(), 8);
        assert!(w.iter().all(|x| x.len() == 33));
    }

    #[test]
    fn deterministic() {
        let toks: Vec<u32> = (0..500).map(|i| (i * 7) % 256).collect();
        assert_eq!(windows(&toks, 16, 5), windows(&toks, 16, 5));
    }

    #[test]
    fn covers_start_and_end() {
        let toks: Vec<u32> = (0..100).collect();
        let w = windows(&toks, 9, 4);
        assert_eq!(w[0][0], 0);
        assert_eq!(*w.last().unwrap().last().unwrap(), 99);
    }

    #[test]
    fn short_stream_returns_empty() {
        let toks: Vec<u32> = (0..10).collect();
        assert!(windows(&toks, 32, 4).is_empty());
    }

    #[test]
    fn caps_count_to_available() {
        let toks: Vec<u32> = (0..12).collect();
        let w = windows(&toks, 10, 100);
        assert!(w.len() <= 2);
        assert!(!w.is_empty());
    }
}
