//! Evaluation harness: perplexity over corpus windows and the
//! storage-vs-PPL sweeps that regenerate the paper's figures.

pub mod perplexity;
pub mod sweep;

pub use perplexity::{
    perplexity, perplexity_batched, perplexity_parallel, perplexity_parallel_batched, row_nll,
    PplResult,
};
pub use sweep::{eval_point, eval_point_dtyped, sweep, sweep_refined, SweepPoint};
