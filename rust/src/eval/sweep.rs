//! Storage-vs-perplexity sweeps — the engine behind Fig 2 / Fig 3 and the
//! headline table.

use crate::compress::{CompressorConfig, Method};
use crate::eval::perplexity::{perplexity_parallel, PplResult};
use crate::model::{CompressedModel, Transformer};
use std::sync::Arc;

/// One point of the storage-PPL plane (a marker in the paper's Fig 3).
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub method: Method,
    pub rank: usize,
    pub sparsity: f64,
    pub depth: usize,
    pub ppl: f64,
    pub mean_nll: f64,
    /// compressed q/k/v bytes (fp16 accounting incl. indices)
    pub qkv_bytes: usize,
    pub qkv_dense_bytes: usize,
    /// whole-model storage ratio (non-qkv stays dense)
    pub model_ratio: f64,
    pub mean_rel_error: f64,
    pub compress_secs: f64,
}

impl SweepPoint {
    pub fn qkv_ratio(&self) -> f64 {
        self.qkv_bytes as f64 / self.qkv_dense_bytes as f64
    }
}

/// Evaluate one (method, config) cell.
pub fn eval_point(
    base: &Arc<Transformer>,
    method: Method,
    cfg: CompressorConfig,
    windows: &[Vec<u32>],
    threads: usize,
) -> SweepPoint {
    let t0 = std::time::Instant::now();
    let result: (PplResult, usize, usize, f64, f64);
    if method == Method::Dense {
        let ppl = perplexity_parallel(windows, |toks| base.forward(toks), threads);
        let qkv_dense = base.cfg.qkv_params() * crate::hss::storage::VALUE_BYTES;
        result = (ppl, qkv_dense, qkv_dense, 1.0, 0.0);
    } else {
        let cm = CompressedModel::compress(base.clone(), method, cfg);
        let compress_secs = t0.elapsed().as_secs_f64();
        let ppl = perplexity_parallel(windows, |toks| cm.forward(toks), threads);
        result = (
            ppl,
            cm.qkv_bytes(),
            cm.qkv_dense_bytes(),
            cm.model_storage_ratio(),
            cm.mean_rel_error(),
        );
        return SweepPoint {
            method,
            rank: cfg.rank,
            sparsity: cfg.sparsity,
            depth: cfg.depth,
            ppl: result.0.ppl,
            mean_nll: result.0.mean_nll,
            qkv_bytes: result.1,
            qkv_dense_bytes: result.2,
            model_ratio: result.3,
            mean_rel_error: result.4,
            compress_secs,
        };
    }
    SweepPoint {
        method,
        rank: 0,
        sparsity: 0.0,
        depth: 0,
        ppl: result.0.ppl,
        mean_nll: result.0.mean_nll,
        qkv_bytes: result.1,
        qkv_dense_bytes: result.2,
        model_ratio: result.3,
        mean_rel_error: result.4,
        compress_secs: 0.0,
    }
}

/// Grid sweep: every method × config cell (dense evaluated once).
pub fn sweep(
    base: &Arc<Transformer>,
    methods: &[Method],
    configs: &[CompressorConfig],
    windows: &[Vec<u32>],
    threads: usize,
) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for &m in methods {
        if m == Method::Dense {
            out.push(eval_point(base, m, CompressorConfig::default(), windows, threads));
            continue;
        }
        for &cfg in configs {
            out.push(eval_point(base, m, cfg, windows, threads));
        }
    }
    out
}

/// CSV emitter (plot-ready, one row per point).
pub fn to_csv(points: &[SweepPoint]) -> String {
    let mut s = String::from(
        "method,rank,sparsity,depth,ppl,mean_nll,qkv_bytes,qkv_dense_bytes,qkv_ratio,model_ratio,rel_error,compress_secs\n",
    );
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{:.6},{:.6},{},{},{:.4},{:.4},{:.6},{:.3}\n",
            p.method,
            p.rank,
            p.sparsity,
            p.depth,
            p.ppl,
            p.mean_nll,
            p.qkv_bytes,
            p.qkv_dense_bytes,
            p.qkv_ratio(),
            p.model_ratio,
            p.mean_rel_error,
            p.compress_secs
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::windows as mk_windows;
    use crate::model::ModelConfig;

    fn tiny() -> (Arc<Transformer>, Vec<Vec<u32>>) {
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 1,
            d_ff: 64,
            seq_len: 16,
        };
        let m = Arc::new(Transformer::random(cfg, 1));
        let toks: Vec<u32> = (0..300).map(|i| (i * 13 + i / 7) as u32 % 64).collect();
        let w = mk_windows(&toks, 16, 3);
        (m, w)
    }

    #[test]
    fn sweep_produces_all_cells() {
        let (base, w) = tiny();
        let cfgs = [CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 1,
            min_leaf: 4,
            ..Default::default()
        }];
        let pts = sweep(
            &base,
            &[Method::Dense, Method::SSvd, Method::SHssRcm],
            &cfgs,
            &w,
            2,
        );
        assert_eq!(pts.len(), 3);
        assert!(pts.iter().all(|p| p.ppl.is_finite() && p.ppl > 0.0));
    }

    #[test]
    fn dense_point_has_unit_ratio() {
        let (base, w) = tiny();
        let p = eval_point(&base, Method::Dense, CompressorConfig::default(), &w, 1);
        assert!((p.model_ratio - 1.0).abs() < 1e-12);
        assert!((p.qkv_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn near_exact_compression_matches_dense_ppl() {
        let (base, w) = tiny();
        let dense = eval_point(&base, Method::Dense, CompressorConfig::default(), &w, 1);
        let cfg = CompressorConfig {
            rank: 16,
            sparsity: 0.2,
            depth: 1,
            hss_rsvd: false,
            min_leaf: 4,
            ..Default::default()
        };
        let comp = eval_point(&base, Method::SHssRcm, cfg, &w, 1);
        assert!(
            (comp.ppl - dense.ppl).abs() / dense.ppl < 0.02,
            "dense {} vs compressed {}",
            dense.ppl,
            comp.ppl
        );
    }

    #[test]
    fn csv_has_header_and_rows() {
        let (base, w) = tiny();
        let pts = sweep(&base, &[Method::Dense], &[], &w, 1);
        let csv = to_csv(&pts);
        assert!(csv.starts_with("method,"));
        assert_eq!(csv.lines().count(), 2);
    }
}
