//! Storage-vs-perplexity sweeps — the engine behind Fig 2 / Fig 3 and the
//! headline table.

use crate::compress::{CompressorConfig, Method};
use crate::eval::perplexity::{perplexity_parallel_batched, PplResult};
use crate::linalg::{Dtype, Matrix};
use crate::model::{CompressedModel, Transformer};
use crate::train::TrainConfig;
use std::sync::Arc;

/// Windows per batched-forward call during sweep evaluation: each chunk is
/// one `apply_batch` traversal per (layer, projection).
const EVAL_BATCH: usize = 32;

/// One point of the storage-PPL plane (a marker in the paper's Fig 3).
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub method: Method,
    pub rank: usize,
    pub sparsity: f64,
    pub depth: usize,
    pub ppl: f64,
    pub mean_nll: f64,
    /// compressed q/k/v bytes (fp16 accounting incl. indices)
    pub qkv_bytes: usize,
    pub qkv_dense_bytes: usize,
    /// whole-model storage ratio (non-qkv stays dense)
    pub model_ratio: f64,
    /// mean reconstruction error of the *one-shot* compression — stable
    /// across refined and unrefined runs so rows stay comparable
    pub mean_rel_error: f64,
    pub compress_secs: f64,
    /// perplexity after `train::calibrate` refinement (== `ppl` when no
    /// refinement ran — the refined-vs-oneshot delta is then 0)
    pub ppl_refined: f64,
    /// mean calibration steps actually run per projection (0 = one-shot)
    pub refine_steps: usize,
    /// wall time of the refine stage (0 when no refinement ran) — the
    /// cost side of the refined-vs-oneshot comparison, separate from
    /// `compress_secs` which stays one-shot-only
    pub refine_secs: f64,
    /// resident dtype the perplexities were served at ("f32" or "f16") —
    /// with `qkv_resident_bytes` this makes the memory/perplexity
    /// trade-off the paper plots measurable end-to-end
    pub dtype: String,
    /// bytes actually resident for the compressed q/k/v weights at
    /// `dtype` (f16 rows report half their f32 twin)
    pub qkv_resident_bytes: usize,
}

impl SweepPoint {
    pub fn qkv_ratio(&self) -> f64 {
        self.qkv_bytes as f64 / self.qkv_dense_bytes as f64
    }
}

/// Evaluate one (method, config) cell at f32 serving residency.
pub fn eval_point(
    base: &Arc<Transformer>,
    method: Method,
    cfg: CompressorConfig,
    windows: &[Vec<u32>],
    threads: usize,
) -> SweepPoint {
    eval_cell(base, method, cfg, None, windows, threads, Dtype::F32)
}

/// Evaluate one cell at an explicit serving dtype: `Dtype::F16` narrows
/// the compressed factors before scoring, so the row's perplexity and
/// `qkv_resident_bytes` reflect exactly what an f16-resident server runs.
pub fn eval_point_dtyped(
    base: &Arc<Transformer>,
    method: Method,
    cfg: CompressorConfig,
    windows: &[Vec<u32>],
    threads: usize,
    dtype: Dtype,
) -> SweepPoint {
    eval_cell(base, method, cfg, None, windows, threads, dtype)
}

/// Precomputed refine-stage inputs, shared across grid cells: dense
/// teachers and per-layer calibration activations.
struct RefineData {
    projections: Vec<(String, Matrix)>,
    activations: Vec<Vec<Vec<f32>>>,
}

/// Calibration activations come from every *other* eval window, so half
/// the windows both perplexities run over never feed the optimizer and
/// the refined-vs-oneshot delta reflects more than overfitting to the
/// eval set.
fn refine_data(base: &Arc<Transformer>, windows: &[Vec<u32>]) -> RefineData {
    let calib: Vec<Vec<u32>> = windows.iter().step_by(2).cloned().collect();
    RefineData {
        projections: base.qkv_projections(),
        activations: crate::train::collect_activations(base, &calib),
    }
}

/// Evaluate one cell twice — one-shot, then after `train::calibrate`
/// refinement of the same compressed model — filling the
/// `ppl_refined` / `refine_steps` comparison columns (see [`refine_data`]
/// for the calibration/eval window split).
pub fn eval_point_refined(
    base: &Arc<Transformer>,
    method: Method,
    cfg: CompressorConfig,
    train_cfg: &TrainConfig,
    windows: &[Vec<u32>],
    threads: usize,
) -> SweepPoint {
    if method == Method::Dense {
        return eval_cell(base, method, cfg, None, windows, threads, Dtype::F32);
    }
    let data = refine_data(base, windows);
    eval_cell(base, method, cfg, Some((train_cfg, &data)), windows, threads, Dtype::F32)
}

fn eval_cell(
    base: &Arc<Transformer>,
    method: Method,
    cfg: CompressorConfig,
    refine: Option<(&TrainConfig, &RefineData)>,
    windows: &[Vec<u32>],
    threads: usize,
    dtype: Dtype,
) -> SweepPoint {
    if method == Method::Dense {
        let ppl =
            perplexity_parallel_batched(windows, EVAL_BATCH, |ws| base.forward_batch(ws), threads);
        let qkv_dense = base.cfg.qkv_params() * crate::hss::storage::VALUE_BYTES;
        return SweepPoint {
            method,
            rank: 0,
            sparsity: 0.0,
            depth: 0,
            ppl: ppl.ppl,
            mean_nll: ppl.mean_nll,
            qkv_bytes: qkv_dense,
            qkv_dense_bytes: qkv_dense,
            model_ratio: 1.0,
            mean_rel_error: 0.0,
            compress_secs: 0.0,
            ppl_refined: ppl.ppl,
            refine_steps: 0,
            refine_secs: 0.0,
            // the dense baseline always serves f32 (the store keeps it
            // bit-exact); its resident bytes are the f32 projections
            dtype: Dtype::F32.name().to_string(),
            qkv_resident_bytes: base.cfg.qkv_params() * 4,
        };
    }
    let t0 = std::time::Instant::now();
    let mut cm = CompressedModel::compress(base.clone(), method, cfg);
    let compress_secs = t0.elapsed().as_secs_f64();
    if dtype == Dtype::F16 {
        // serve at f16 residency: perplexities below measure exactly what
        // an f16-resident server computes (fp16-quantized factors)
        cm.narrow_to_f16();
    }
    let qkv_resident_bytes = cm.resident_weight_bytes();
    let oneshot: PplResult =
        perplexity_parallel_batched(windows, EVAL_BATCH, |ws| cm.forward_batch(ws), threads);
    // capture one-shot accounting before calibration touches the reports
    let mean_rel_error = cm.mean_rel_error();
    let (qkv_bytes, qkv_dense_bytes) = (cm.qkv_bytes(), cm.qkv_dense_bytes());
    let model_ratio = cm.model_storage_ratio();
    let (ppl_refined, refine_steps, refine_secs) = match refine {
        Some((tc, data)) => {
            let t1 = std::time::Instant::now();
            // training is f32-only; the refined model narrows back before
            // its serving-dtype evaluation
            cm.widen_to_f32();
            let cals = crate::train::calibrate_model_with(
                &mut cm,
                &data.projections,
                &data.activations,
                tc,
            );
            if dtype == Dtype::F16 {
                cm.narrow_to_f16();
            }
            let refine_secs = t1.elapsed().as_secs_f64();
            let refined = perplexity_parallel_batched(
                windows,
                EVAL_BATCH,
                |ws| cm.forward_batch(ws),
                threads,
            );
            let steps = if cals.is_empty() {
                0
            } else {
                cals.iter().map(|c| c.steps_run).sum::<usize>() / cals.len()
            };
            (refined.ppl, steps, refine_secs)
        }
        None => (oneshot.ppl, 0, 0.0),
    };
    SweepPoint {
        method,
        rank: cfg.rank,
        sparsity: cfg.sparsity,
        depth: cfg.depth,
        ppl: oneshot.ppl,
        mean_nll: oneshot.mean_nll,
        qkv_bytes,
        qkv_dense_bytes,
        model_ratio,
        mean_rel_error,
        compress_secs,
        ppl_refined,
        refine_steps,
        refine_secs,
        dtype: dtype.name().to_string(),
        qkv_resident_bytes,
    }
}

/// Grid sweep: every method × config cell (dense evaluated once), served
/// at f32 residency.
pub fn sweep(
    base: &Arc<Transformer>,
    methods: &[Method],
    configs: &[CompressorConfig],
    windows: &[Vec<u32>],
    threads: usize,
) -> Vec<SweepPoint> {
    sweep_refined(base, methods, configs, windows, threads, None, Dtype::F32)
}

/// Grid sweep with an optional refine stage and an explicit serving
/// dtype: when `train_cfg` is given, every compressed cell is evaluated
/// one-shot *and* after calibration, filling the refined-vs-oneshot
/// comparison columns; `Dtype::F16` serves every compressed cell
/// f16-resident (the dense baseline always stays f32).
pub fn sweep_refined(
    base: &Arc<Transformer>,
    methods: &[Method],
    configs: &[CompressorConfig],
    windows: &[Vec<u32>],
    threads: usize,
    train_cfg: Option<&TrainConfig>,
    dtype: Dtype,
) -> Vec<SweepPoint> {
    // teachers + calibration activations depend only on (base, windows):
    // capture them once for the whole grid, not once per cell
    let data = train_cfg.map(|_| refine_data(base, windows));
    let mut out = Vec::new();
    for &m in methods {
        if m == Method::Dense {
            out.push(eval_point(base, m, CompressorConfig::default(), windows, threads));
            continue;
        }
        for &cfg in configs {
            let refine = match (train_cfg, &data) {
                (Some(tc), Some(d)) => Some((tc, d)),
                _ => None,
            };
            out.push(eval_cell(base, m, cfg, refine, windows, threads, dtype));
        }
    }
    out
}

const CSV_HEADER: &str = "method,rank,sparsity,depth,ppl,mean_nll,qkv_bytes,qkv_dense_bytes,qkv_ratio,model_ratio,rel_error,compress_secs,ppl_refined,refine_steps,refine_secs,dtype,qkv_resident_bytes";
/// Pre-dtype header (15 columns) — still accepted by [`from_csv`] so
/// sweeps written before the dtype column stay loadable.
const LEGACY_CSV_HEADER: &str = "method,rank,sparsity,depth,ppl,mean_nll,qkv_bytes,qkv_dense_bytes,qkv_ratio,model_ratio,rel_error,compress_secs,ppl_refined,refine_steps,refine_secs";

/// CSV emitter (plot-ready, one row per point).
pub fn to_csv(points: &[SweepPoint]) -> String {
    let mut s = String::from(CSV_HEADER);
    s.push('\n');
    for p in points {
        s.push_str(&format!(
            "{},{},{},{},{:.6},{:.6},{},{},{:.4},{:.4},{:.6},{:.3},{:.6},{},{:.3},{},{}\n",
            p.method,
            p.rank,
            p.sparsity,
            p.depth,
            p.ppl,
            p.mean_nll,
            p.qkv_bytes,
            p.qkv_dense_bytes,
            p.qkv_ratio(),
            p.model_ratio,
            p.mean_rel_error,
            p.compress_secs,
            p.ppl_refined,
            p.refine_steps,
            p.refine_secs,
            p.dtype,
            p.qkv_resident_bytes
        ));
    }
    s
}

fn parse_num<T: std::str::FromStr>(s: &str, lineno: usize) -> Result<T, String>
where
    T::Err: std::fmt::Display,
{
    s.parse::<T>()
        .map_err(|e| format!("row {lineno}: bad value '{s}': {e}"))
}

/// Parse a CSV produced by [`to_csv`] back into sweep points (the
/// derived `qkv_ratio` column is recomputed, not stored).
pub fn from_csv(s: &str) -> Result<Vec<SweepPoint>, String> {
    let mut lines = s.lines();
    let header = lines.next().ok_or("empty csv")?;
    if header != CSV_HEADER && header != LEGACY_CSV_HEADER {
        return Err(format!("unexpected csv header '{header}'"));
    }
    // rows must match the declared header: a truncated current-format row
    // must error, not silently parse as a legacy (f32) row
    let want_cols = if header == CSV_HEADER { 17 } else { 15 };
    let mut out = Vec::new();
    for (i, line) in lines.enumerate() {
        if line.is_empty() {
            continue;
        }
        let lineno = i + 2;
        let cols: Vec<&str> = line.split(',').collect();
        // 17 columns today; 15-column files (legacy header) predate the
        // dtype / qkv_resident_bytes columns and read back as
        // f32-resident with unknown (0) resident bytes
        if cols.len() != want_cols {
            return Err(format!("row {lineno}: {} columns (want {want_cols})", cols.len()));
        }
        let dtype = if want_cols == 17 {
            cols[15]
                .parse::<Dtype>()
                .map_err(|e| format!("row {lineno}: {e}"))?
        } else {
            Dtype::F32
        };
        out.push(SweepPoint {
            method: cols[0].parse::<Method>()?,
            rank: parse_num(cols[1], lineno)?,
            sparsity: parse_num(cols[2], lineno)?,
            depth: parse_num(cols[3], lineno)?,
            ppl: parse_num(cols[4], lineno)?,
            mean_nll: parse_num(cols[5], lineno)?,
            qkv_bytes: parse_num(cols[6], lineno)?,
            qkv_dense_bytes: parse_num(cols[7], lineno)?,
            // cols[8] = qkv_ratio, derived
            model_ratio: parse_num(cols[9], lineno)?,
            mean_rel_error: parse_num(cols[10], lineno)?,
            compress_secs: parse_num(cols[11], lineno)?,
            ppl_refined: parse_num(cols[12], lineno)?,
            refine_steps: parse_num(cols[13], lineno)?,
            refine_secs: parse_num(cols[14], lineno)?,
            dtype: dtype.name().to_string(),
            qkv_resident_bytes: if want_cols == 17 {
                parse_num(cols[16], lineno)?
            } else {
                0 // unknown for pre-dtype files
            },
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::windows as mk_windows;
    use crate::model::ModelConfig;

    fn tiny() -> (Arc<Transformer>, Vec<Vec<u32>>) {
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 1,
            d_ff: 64,
            seq_len: 16,
        };
        let m = Arc::new(Transformer::random(cfg, 1));
        let toks: Vec<u32> = (0..300).map(|i| (i * 13 + i / 7) as u32 % 64).collect();
        let w = mk_windows(&toks, 16, 3);
        (m, w)
    }

    #[test]
    fn sweep_produces_all_cells() {
        let (base, w) = tiny();
        let cfgs = [CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 1,
            min_leaf: 4,
            ..Default::default()
        }];
        let pts = sweep(
            &base,
            &[Method::Dense, Method::SSvd, Method::SHssRcm],
            &cfgs,
            &w,
            2,
        );
        assert_eq!(pts.len(), 3);
        assert!(pts.iter().all(|p| p.ppl.is_finite() && p.ppl > 0.0));
    }

    #[test]
    fn dense_point_has_unit_ratio() {
        let (base, w) = tiny();
        let p = eval_point(&base, Method::Dense, CompressorConfig::default(), &w, 1);
        assert!((p.model_ratio - 1.0).abs() < 1e-12);
        assert!((p.qkv_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn near_exact_compression_matches_dense_ppl() {
        let (base, w) = tiny();
        let dense = eval_point(&base, Method::Dense, CompressorConfig::default(), &w, 1);
        let cfg = CompressorConfig {
            rank: 16,
            sparsity: 0.2,
            depth: 1,
            hss_rsvd: false,
            min_leaf: 4,
            ..Default::default()
        };
        let comp = eval_point(&base, Method::SHssRcm, cfg, &w, 1);
        assert!(
            (comp.ppl - dense.ppl).abs() / dense.ppl < 0.02,
            "dense {} vs compressed {}",
            dense.ppl,
            comp.ppl
        );
    }

    #[test]
    fn csv_has_header_and_rows() {
        let (base, w) = tiny();
        let pts = sweep(&base, &[Method::Dense], &[], &w, 1);
        let csv = to_csv(&pts);
        assert!(csv.starts_with("method,"));
        assert!(csv
            .lines()
            .next()
            .unwrap()
            .ends_with("refine_steps,refine_secs,dtype,qkv_resident_bytes"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn f16_point_halves_resident_bytes_within_ppl_tolerance() {
        let (base, w) = tiny();
        let cfg = CompressorConfig {
            rank: 8,
            sparsity: 0.1,
            depth: 1,
            min_leaf: 4,
            ..Default::default()
        };
        let p32 = eval_point_dtyped(&base, Method::SHssRcm, cfg, &w, 1, Dtype::F32);
        let p16 = eval_point_dtyped(&base, Method::SHssRcm, cfg, &w, 1, Dtype::F16);
        assert_eq!(p32.dtype, "f32");
        assert_eq!(p16.dtype, "f16");
        // resident weight memory exactly halves; format accounting is
        // unchanged, so the two rows stay comparable on the storage axis
        assert_eq!(p16.qkv_resident_bytes * 2, p32.qkv_resident_bytes);
        assert_eq!(p16.qkv_bytes, p32.qkv_bytes);
        // fp16 round-trip tolerance on the quality axis
        assert!(
            (p16.ppl - p32.ppl).abs() / p32.ppl < 0.05,
            "f32 ppl {} vs f16 ppl {}",
            p32.ppl,
            p16.ppl
        );
        // the dtype column round-trips through the csv
        let csv = to_csv(&[p32, p16]);
        let parsed = from_csv(&csv).unwrap();
        assert_eq!(parsed[0].dtype, "f32");
        assert_eq!(parsed[1].dtype, "f16");
        assert_eq!(to_csv(&parsed), csv);
    }

    #[test]
    fn csv_roundtrips_through_from_csv() {
        let (base, w) = tiny();
        let cfgs = [CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 1,
            min_leaf: 4,
            ..Default::default()
        }];
        let mut pts = sweep(&base, &[Method::Dense, Method::SSvd], &cfgs, &w, 1);
        // exercise non-default refined columns too
        pts[1].ppl_refined = pts[1].ppl * 0.9;
        pts[1].refine_steps = 150;
        pts[1].refine_secs = 4.2;
        let csv = to_csv(&pts);
        let parsed = from_csv(&csv).unwrap();
        assert_eq!(parsed.len(), pts.len());
        assert_eq!(to_csv(&parsed), csv, "reserialization must be lossless");
        assert_eq!(parsed[1].refine_steps, 150);
        assert_eq!(parsed[1].method, Method::SSvd);
    }

    #[test]
    fn from_csv_accepts_legacy_15_column_files() {
        let legacy = format!(
            "{LEGACY_CSV_HEADER}\ndense,0,0,0,12.5,2.52,100,100,1.0,1.0,0.0,0.0,12.5,0,0.0\n"
        );
        let pts = from_csv(&legacy).unwrap();
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].dtype, "f32");
        assert_eq!(pts[0].qkv_resident_bytes, 0); // unknown pre-dtype
        // re-serializes in the current 17-column format
        assert!(to_csv(&pts).starts_with(CSV_HEADER));
    }

    #[test]
    fn from_csv_rejects_garbage() {
        assert!(from_csv("").is_err());
        assert!(from_csv("wrong,header\n").is_err());
        let bad_row = format!("{}\ndense,0,0,0,oops\n", to_csv(&[]).trim_end());
        assert!(from_csv(&bad_row).is_err());
    }

    #[test]
    fn refined_point_keeps_oneshot_columns_and_fills_refined() {
        let (base, w) = tiny();
        let cfg = CompressorConfig {
            rank: 4,
            sparsity: 0.05,
            depth: 1,
            min_leaf: 4,
            ..Default::default()
        };
        let oneshot = eval_point(&base, Method::SSvd, cfg, &w, 1);
        let tc = crate::train::TrainConfig {
            steps: 60,
            ..Default::default()
        };
        let refined = eval_point_refined(&base, Method::SSvd, cfg, &tc, &w, 1);
        // the one-shot columns are identical between the two runs, so
        // refined and unrefined sweep rows stay directly comparable
        assert!((refined.ppl - oneshot.ppl).abs() < 1e-9);
        assert!((refined.mean_rel_error - oneshot.mean_rel_error).abs() < 1e-12);
        assert_eq!(refined.qkv_bytes, oneshot.qkv_bytes);
        // ... and the refined columns are populated
        assert!(refined.refine_steps > 0);
        assert!(refined.refine_secs > 0.0);
        assert!(refined.ppl_refined.is_finite() && refined.ppl_refined > 0.0);
        assert_eq!(oneshot.refine_steps, 0);
        assert_eq!(oneshot.refine_secs, 0.0);
        assert!((oneshot.ppl_refined - oneshot.ppl).abs() < 1e-12);
    }
}
