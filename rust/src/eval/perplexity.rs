//! Perplexity evaluation (the paper's §5.1 metric) over deterministic
//! corpus windows, with a thread-parallel variant for sweeps.

use crate::linalg::Matrix;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Perplexity result: exp(mean NLL in nats/token).
#[derive(Clone, Copy, Debug)]
pub struct PplResult {
    pub ppl: f64,
    pub mean_nll: f64,
    pub tokens: usize,
}

/// Mean next-token NLL of one window given its logits [t, vocab];
/// targets are `window[1..=t]`.
pub fn window_nll(logits: &Matrix, window: &[u32]) -> (f64, usize) {
    // one `softmax` span per window (all t rows of output log-softmax),
    // never per row — see the span-guard rules in `crate::obs`
    let _span = crate::obs::Span::enter(crate::obs::Stage::Softmax);
    let t = logits.rows;
    assert!(window.len() >= t + 1);
    let mut total = 0.0f64;
    for i in 0..t {
        total += row_nll(logits.row(i), window[i + 1] as usize);
    }
    (total, t)
}

/// NLL of one target under one logits row — the per-row unit of
/// [`window_nll`], split out so the decode path can score tokens one at
/// a time: accumulated left-to-right in f64, a prefill + per-token
/// decode sum is **bit-identical** to the full-window total.
#[inline]
pub fn row_nll(row: &[f32], target: usize) -> f64 {
    // log-softmax, numerically stable
    let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
    let lse: f64 = row
        .iter()
        .map(|&v| ((v - maxv) as f64).exp())
        .sum::<f64>()
        .ln()
        + maxv as f64;
    lse - row[target] as f64
}

/// Perplexity over windows with any forward function (dense/compressed/HLO).
pub fn perplexity<F: Fn(&[u32]) -> Matrix>(windows: &[Vec<u32>], fwd: F) -> PplResult {
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for w in windows {
        let logits = fwd(&w[..w.len() - 1]);
        let (n, t) = window_nll(&logits, w);
        nll += n;
        count += t;
    }
    finish(nll, count)
}

/// Thread-parallel perplexity (windows are independent).
pub fn perplexity_parallel<F: Fn(&[u32]) -> Matrix + Sync>(
    windows: &[Vec<u32>],
    fwd: F,
    threads: usize,
) -> PplResult {
    if threads <= 1 || windows.len() <= 1 {
        return perplexity(windows, fwd);
    }
    let next = AtomicUsize::new(0);
    let results: Vec<(f64, usize)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..threads.min(windows.len()) {
            let next = &next;
            let fwd = &fwd;
            handles.push(scope.spawn(move || {
                let mut nll = 0.0f64;
                let mut count = 0usize;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= windows.len() {
                        break;
                    }
                    let w = &windows[i];
                    let logits = fwd(&w[..w.len() - 1]);
                    let (n, t) = window_nll(&logits, w);
                    nll += n;
                    count += t;
                }
                (nll, count)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let nll: f64 = results.iter().map(|r| r.0).sum();
    let count: usize = results.iter().map(|r| r.1).sum();
    finish(nll, count)
}

/// Perplexity with windows scored as column blocks: `fwd_batch` receives
/// up to `max_batch` windows (each already truncated to its input tokens)
/// and returns one logits matrix per window — so a compressed model walks
/// its structure once per chunk instead of once per window.
pub fn perplexity_batched<F: Fn(&[&[u32]]) -> Vec<Matrix>>(
    windows: &[Vec<u32>],
    max_batch: usize,
    fwd_batch: F,
) -> PplResult {
    let max_batch = max_batch.max(1);
    let mut nll = 0.0f64;
    let mut count = 0usize;
    for chunk in windows.chunks(max_batch) {
        let inputs: Vec<&[u32]> = chunk.iter().map(|w| &w[..w.len() - 1]).collect();
        let logits = fwd_batch(&inputs);
        assert_eq!(logits.len(), chunk.len(), "scorer returned wrong batch size");
        for (lg, w) in logits.iter().zip(chunk) {
            let (n, t) = window_nll(lg, w);
            nll += n;
            count += t;
        }
    }
    finish(nll, count)
}

/// Thread-parallel batched perplexity with **length-bucketed chunking**:
/// windows are first coalesced into the same power-of-two length buckets
/// the serving batcher uses (`coordinator::batcher::default_bucket_edges`),
/// then chunked to `max_batch` within each bucket, and threads steal whole
/// chunks. Every chunk the batched forward sees is therefore a
/// near-uniform-length block — the identical bucket → stack →
/// batched-attention path the coordinator serves — and the result is the
/// same NLL sum regardless of bucketing (windows are independent).
pub fn perplexity_parallel_batched<F: Fn(&[&[u32]]) -> Vec<Matrix> + Sync>(
    windows: &[Vec<u32>],
    max_batch: usize,
    fwd_batch: F,
    threads: usize,
) -> PplResult {
    use crate::coordinator::batcher::{bucket_index, default_bucket_edges};
    let max_batch = max_batch.max(1);
    let edges = default_bucket_edges();
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); edges.len() + 1];
    for (i, w) in windows.iter().enumerate() {
        buckets[bucket_index(w.len(), &edges)].push(i);
    }
    let chunks: Vec<Vec<usize>> = buckets
        .iter()
        .flat_map(|b| b.chunks(max_batch).map(|c| c.to_vec()))
        .collect();
    let score_chunk = |chunk: &[usize]| -> (f64, usize) {
        let inputs: Vec<&[u32]> = chunk
            .iter()
            .map(|&i| &windows[i][..windows[i].len() - 1])
            .collect();
        let logits = fwd_batch(&inputs);
        assert_eq!(logits.len(), chunk.len(), "scorer returned wrong batch size");
        let mut nll = 0.0f64;
        let mut count = 0usize;
        for (lg, &i) in logits.iter().zip(chunk) {
            let (n, t) = window_nll(lg, &windows[i]);
            nll += n;
            count += t;
        }
        (nll, count)
    };
    if threads <= 1 || chunks.len() <= 1 {
        let mut nll = 0.0f64;
        let mut count = 0usize;
        for chunk in &chunks {
            let (n, t) = score_chunk(chunk);
            nll += n;
            count += t;
        }
        return finish(nll, count);
    }
    let next = AtomicUsize::new(0);
    let results: Vec<(f64, usize)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..threads.min(chunks.len()) {
            let next = &next;
            let chunks = &chunks;
            let score_chunk = &score_chunk;
            handles.push(scope.spawn(move || {
                let mut nll = 0.0f64;
                let mut count = 0usize;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= chunks.len() {
                        break;
                    }
                    let (n, t) = score_chunk(&chunks[i]);
                    nll += n;
                    count += t;
                }
                (nll, count)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let nll: f64 = results.iter().map(|r| r.0).sum();
    let count: usize = results.iter().map(|r| r.1).sum();
    finish(nll, count)
}

fn finish(nll: f64, count: usize) -> PplResult {
    let mean = if count > 0 { nll / count as f64 } else { f64::NAN };
    PplResult {
        ppl: mean.exp(),
        mean_nll: mean,
        tokens: count,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// fwd that always predicts uniform distribution
    fn uniform_fwd(vocab: usize) -> impl Fn(&[u32]) -> Matrix {
        move |tokens: &[u32]| Matrix::zeros(tokens.len(), vocab)
    }

    /// fwd that puts all mass on the true next token (needs the window)
    fn oracle_logits(window: &[u32], vocab: usize) -> Matrix {
        let t = window.len() - 1;
        let mut m = Matrix::zeros(t, vocab);
        for i in 0..t {
            m.set(i, window[i + 1] as usize, 50.0);
        }
        m
    }

    #[test]
    fn uniform_model_ppl_equals_vocab() {
        let windows: Vec<Vec<u32>> = vec![(0..33).map(|i| i % 7).collect()];
        let r = perplexity(&windows, uniform_fwd(128));
        assert!((r.ppl - 128.0).abs() < 1e-6, "{}", r.ppl);
    }

    #[test]
    fn oracle_model_ppl_near_one() {
        let w: Vec<u32> = (0..17).map(|i| (i * 3) % 11).collect();
        let windows = vec![w.clone()];
        let r = perplexity(&windows, |toks| {
            let mut full = toks.to_vec();
            full.push(w[toks.len()]);
            oracle_logits(&full, 16)
        });
        assert!(r.ppl < 1.001, "{}", r.ppl);
    }

    #[test]
    fn parallel_matches_serial() {
        let windows: Vec<Vec<u32>> = (0..6)
            .map(|s| (0..21).map(|i| ((i + s) * 5) % 64).collect())
            .collect();
        let f = uniform_fwd(64);
        let serial = perplexity(&windows, &f);
        let par = perplexity_parallel(&windows, &f, 4);
        assert!((serial.ppl - par.ppl).abs() < 1e-9);
        assert_eq!(serial.tokens, par.tokens);
    }

    #[test]
    fn batched_matches_serial() {
        let windows: Vec<Vec<u32>> = (0..7)
            .map(|s| (0..21).map(|i| ((i + s) * 5) % 64).collect())
            .collect();
        let f = uniform_fwd(64);
        let serial = perplexity(&windows, &f);
        let fb = |inputs: &[&[u32]]| -> Vec<Matrix> { inputs.iter().map(|t| f(t)).collect() };
        for max_batch in [1, 3, 16] {
            let b = perplexity_batched(&windows, max_batch, fb);
            assert!((serial.ppl - b.ppl).abs() < 1e-9, "max_batch {max_batch}");
            assert_eq!(serial.tokens, b.tokens);
            let p = perplexity_parallel_batched(&windows, max_batch, fb, 4);
            assert!((serial.ppl - p.ppl).abs() < 1e-9, "parallel max_batch {max_batch}");
            assert_eq!(serial.tokens, p.tokens);
        }
    }

    #[test]
    fn bucketed_parallel_matches_serial_on_ragged_lengths() {
        // lengths straddling several power-of-two bucket edges: the
        // length-bucketed chunking must reorder evaluation, never results
        let windows: Vec<Vec<u32>> = (0..13)
            .map(|s| (0..(5 + s * 7) % 60 + 2).map(|i| ((i + s) * 5) % 64).collect())
            .collect();
        let f = uniform_fwd(64);
        let serial = perplexity(&windows, &f);
        let fb = |inputs: &[&[u32]]| -> Vec<Matrix> {
            // every chunk must be length-homogeneous under the default
            // power-of-two edges
            let edges = crate::coordinator::batcher::default_bucket_edges();
            let b0 = crate::coordinator::batcher::bucket_index(inputs[0].len() + 1, &edges);
            for w in inputs {
                assert_eq!(
                    crate::coordinator::batcher::bucket_index(w.len() + 1, &edges),
                    b0,
                    "chunk mixes length buckets"
                );
            }
            inputs.iter().map(|t| f(t)).collect()
        };
        for threads in [1, 4] {
            let p = perplexity_parallel_batched(&windows, 4, fb, threads);
            assert!((serial.ppl - p.ppl).abs() < 1e-9, "threads {threads}");
            assert_eq!(serial.tokens, p.tokens);
        }
    }

    #[test]
    fn token_count_accumulates() {
        let windows: Vec<Vec<u32>> = vec![vec![0; 11], vec![1; 11]];
        let r = perplexity(&windows, uniform_fwd(4));
        assert_eq!(r.tokens, 20);
    }
}
