//! The `HSB2` sharded variant container: per-layer shard files behind a
//! small manifest, built for mmap'd zero-copy serving and parallel loads.
//!
//! A sharded variant is a *directory* `<variant>.hsb2/` holding one shard
//! file per layer (the layer's `q/k/v` triple — the unit [`super::entry_name`]
//! already keys on) plus `manifest.hsb2`. Shards carry their own crc32, so
//! corruption is detected — and isolated — at the granularity of one
//! layer's factors; the manifest records every shard's length and crc plus
//! a per-entry index (name, kind, dtype, payload offset/len), so a reader
//! can reject a missing or truncated shard with an error that names it
//! before any payload is touched.
//!
//! Shard payloads use the **aligned** grammar
//! ([`format::encode_payload_aligned`]): every f32/f16 value run sits on a
//! [`format::VALUE_ALIGN`] file boundary, so the mmap'd reader hands out
//! `WeightBuf` borrows of the mapping itself — N serving processes share
//! one page-cache copy of the factors (see `store/mod.rs` for the full
//! format spec, and `benches/store_load.rs --procs` for the measurement).
//!
//! Durability contract: shards are written and synced **before** the
//! manifest, and the finished directory is swapped into place by rename —
//! a manifest that exists always references complete shards. Deletion
//! inverts the order (manifest first, [`remove_sharded_variant`]), so no
//! observer ever finds a manifest pointing at missing shards mid-delete.

use crate::compress::{CompressedMatrix, Method};
use crate::store::format::{
    self, kind_of, method_code, method_from_code, EntryMeta, KIND_DENSE, KIND_HSS, METHOD_UNKNOWN,
};
use crate::store::reader::{parse_entry_table, EntryIndex, FileBytes};
use crate::store::MmapMode;
use crate::util::binio::{
    crc32, put_f64, put_string, put_u16, put_u32, put_u64, ByteReader, DT_F16, DT_F32,
};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

/// Shard file magic.
pub const SHARD_MAGIC: &[u8; 4] = b"HSB2";
pub const SHARD_VERSION: u16 = 1;
/// Manifest magic.
pub const MANIFEST_MAGIC: &[u8; 4] = b"HSBM";
pub const MANIFEST_VERSION: u16 = 1;
/// Manifest file name inside a `<variant>.hsb2/` directory.
pub const MANIFEST_NAME: &str = "manifest.hsb2";

/// Extension (without dot) of a sharded variant directory.
pub const SHARDED_EXT: &str = "hsb2";

/// One entry handed to [`write_sharded`].
pub struct ShardEntry<'a> {
    pub name: String,
    pub method: Option<Method>,
    pub rel_error: f64,
    pub matrix: &'a CompressedMatrix,
}

/// Manifest value dtype for an entry (informational: the dtype its value
/// runs are stored at — the dense baseline is f32, every factor is f16).
fn entry_dtype(kind: u8) -> u8 {
    if kind == KIND_DENSE {
        DT_F32
    } else {
        DT_F16
    }
}

// ---------------------------------------------------------------- writing

/// Serialize one shard: header, entry table with aligned payloads, crc
/// footer. Returns the bytes plus each entry's `(payload_off, payload_len)`
/// for the manifest.
fn encode_shard(entries: &[&ShardEntry]) -> (Vec<u8>, Vec<(u64, u64)>) {
    let mut out = Vec::new();
    out.extend_from_slice(SHARD_MAGIC);
    put_u16(&mut out, SHARD_VERSION);
    put_u16(&mut out, 0); // flags, reserved
    put_u32(&mut out, entries.len() as u32);
    let mut extents = Vec::with_capacity(entries.len());
    for e in entries {
        put_string(&mut out, &e.name);
        out.push(kind_of(e.matrix));
        out.push(e.method.map_or(METHOD_UNKNOWN, method_code));
        put_f64(&mut out, e.rel_error);
        // the payload begins 8 bytes (its own length field) past here —
        // that absolute file offset is what the aligned grammar pads from
        let payload_base = out.len() + 8;
        let payload = format::encode_payload_aligned(e.matrix, payload_base);
        put_u64(&mut out, payload.len() as u64);
        out.extend_from_slice(&payload);
        extents.push((payload_base as u64, payload.len() as u64));
    }
    let crc = crc32(&out);
    put_u32(&mut out, crc);
    (out, extents)
}

/// Write a sharded `HSB2` variant directory at `final_dir`
/// (`…/<variant>.hsb2`): one shard per entry-name prefix (`layer3.wq` →
/// `layer3.shard`), shards first, manifest last, everything staged in a
/// temp directory and swapped into place by rename. Returns total bytes
/// written (shards + manifest).
pub fn write_sharded(final_dir: &Path, entries: &[ShardEntry], save_seq: u64) -> Result<u64> {
    static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    if entries.is_empty() {
        bail!("refusing to write an empty sharded variant");
    }

    // group entries into shards by name prefix, preserving first-appearance
    // order (layer{i}.w{q,k,v} → one shard per layer)
    let mut groups: Vec<(String, Vec<usize>)> = Vec::new();
    for (i, e) in entries.iter().enumerate() {
        let prefix = e.name.split('.').next().unwrap_or(&e.name).to_string();
        match groups.iter_mut().find(|(p, _)| *p == prefix) {
            Some((_, v)) => v.push(i),
            None => groups.push((prefix, vec![i])),
        }
    }

    let tmp_dir = match final_dir.file_name() {
        Some(name) => {
            let mut n = name.to_os_string();
            n.push(format!(
                ".tmp.{}.{}",
                std::process::id(),
                SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
            ));
            final_dir.with_file_name(n)
        }
        None => bail!("sharded variant path {} has no file name", final_dir.display()),
    };
    std::fs::create_dir_all(&tmp_dir)
        .with_context(|| format!("creating {}", tmp_dir.display()))?;

    // 1) shards, each written and synced before the manifest references it
    let mut total: u64 = 0;
    let mut manifest = Vec::new();
    manifest.extend_from_slice(MANIFEST_MAGIC);
    put_u16(&mut manifest, MANIFEST_VERSION);
    put_u16(&mut manifest, 0); // flags, reserved
    put_u64(&mut manifest, save_seq);
    put_u32(&mut manifest, groups.len() as u32);
    for (prefix, idxs) in &groups {
        let members: Vec<&ShardEntry> = idxs.iter().map(|&i| &entries[i]).collect();
        let (bytes, extents) = encode_shard(&members);
        let rel = format!("{prefix}.shard");
        write_synced(&tmp_dir.join(&rel), &bytes)?;
        total += bytes.len() as u64;
        // the shard's own footer crc doubles as its manifest fingerprint
        let crc = u32::from_le_bytes(bytes[bytes.len() - 4..].try_into().expect("4-byte footer"));
        put_string(&mut manifest, &rel);
        put_u64(&mut manifest, bytes.len() as u64);
        put_u32(&mut manifest, crc);
        put_u32(&mut manifest, members.len() as u32);
        for (m, (off, len)) in members.iter().zip(&extents) {
            put_string(&mut manifest, &m.name);
            let kind = kind_of(m.matrix);
            manifest.push(kind);
            manifest.push(m.method.map_or(METHOD_UNKNOWN, method_code));
            put_f64(&mut manifest, m.rel_error);
            put_u64(&mut manifest, *off);
            put_u64(&mut manifest, *len);
            manifest.push(entry_dtype(kind));
        }
    }
    let crc = crc32(&manifest);
    put_u32(&mut manifest, crc);

    // 2) manifest last: its existence is the commit point of the variant
    write_synced(&tmp_dir.join(MANIFEST_NAME), &manifest)?;
    total += manifest.len() as u64;
    sync_dir(&tmp_dir);

    // 3) swap into place; a replaced variant is renamed aside first so the
    // final rename never races a reader holding the old directory open
    if final_dir.exists() {
        let mut old_name = final_dir
            .file_name()
            .expect("checked above")
            .to_os_string();
        old_name.push(format!(
            ".old.{}.{}",
            std::process::id(),
            SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
        ));
        let old_dir = final_dir.with_file_name(old_name);
        std::fs::rename(final_dir, &old_dir)
            .with_context(|| format!("renaming previous {} aside", final_dir.display()))?;
        std::fs::rename(&tmp_dir, final_dir)
            .with_context(|| format!("renaming {} into place", tmp_dir.display()))?;
        let _ = std::fs::remove_dir_all(&old_dir);
    } else {
        std::fs::rename(&tmp_dir, final_dir)
            .with_context(|| format!("renaming {} into place", tmp_dir.display()))?;
    }
    if let Some(parent) = final_dir.parent() {
        sync_dir(parent);
    }
    Ok(total)
}

fn write_synced(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut f =
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    f.write_all(bytes)
        .with_context(|| format!("writing {}", path.display()))?;
    f.sync_all()
        .with_context(|| format!("syncing {}", path.display()))?;
    Ok(())
}

fn sync_dir(dir: &Path) {
    // best-effort: durability of the rename, not correctness, depends on it
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Delete a sharded variant with the manifest removed **first**: the
/// variant atomically stops being openable, then the shard bytes go. The
/// inverse of the write ordering, so a manifest on disk always references
/// complete shards.
pub fn remove_sharded_variant(dir: &Path) -> std::io::Result<()> {
    let manifest = dir.join(MANIFEST_NAME);
    if manifest.exists() {
        std::fs::remove_file(&manifest)?;
    }
    std::fs::remove_dir_all(dir)
}

// ---------------------------------------------------------------- reading

/// One opened, crc-verified shard file (mmap-backed when available).
pub struct ShardFile {
    bytes: FileBytes,
    entries: Vec<EntryIndex>,
}

impl ShardFile {
    /// Open and fully validate one shard: magic, version, entry table, and
    /// the crc32 footer — a bit flip in this shard fails *this* open and
    /// no other shard's.
    pub fn open_with(path: &Path, mode: MmapMode) -> Result<ShardFile> {
        let bytes = FileBytes::open(path, mode)?;
        if bytes.len() < 12 + 4 {
            bail!("shard too short ({} bytes)", bytes.len());
        }
        let body = &bytes[..bytes.len() - 4];
        let footer = &bytes[bytes.len() - 4..];
        let want = u32::from_le_bytes([footer[0], footer[1], footer[2], footer[3]]);
        let got = crc32(body);
        if want != got {
            bail!("crc mismatch: footer {want:#010x} vs computed {got:#010x} (corrupt or truncated shard)");
        }
        let mut r = ByteReader::new(body);
        r.expect_magic(SHARD_MAGIC, "HSB2 shard")?;
        let version = r.u16()?;
        if version != SHARD_VERSION {
            bail!("unsupported HSB2 shard version {version} (this build reads {SHARD_VERSION})");
        }
        let _flags = r.u16()?;
        let count = r.u32()? as usize;
        let entries = parse_entry_table(&mut r, count)?;
        if r.remaining() != 0 {
            bail!("{} trailing bytes after the last entry", r.remaining());
        }
        drop(r);
        Ok(ShardFile { bytes, entries })
    }

    pub fn is_mapped(&self) -> bool {
        self.bytes.is_mapped()
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.meta.name.as_str()).collect()
    }

    fn find(&self, name: &str) -> Option<&EntryIndex> {
        self.entries.iter().find(|e| e.meta.name == name)
    }

    /// Decode one entry at its on-disk dtype; with a mapped backing the
    /// value runs borrow the mapping (zero-copy, aligned grammar).
    pub fn load_native(&self, name: &str) -> Result<CompressedMatrix> {
        let e = self
            .find(name)
            .ok_or_else(|| anyhow::anyhow!("entry '{name}' not in shard (have: {})", self.names().join(", ")))?;
        let map = self.bytes.map().map(|m| (m.clone(), e.start));
        format::decode_payload_ext(e.meta.kind, &self.bytes[e.start..e.start + e.len], true, true, map)
            .with_context(|| format!("decoding entry '{name}' (native dtype)"))
    }

    /// Decode one entry widening f16 to f32 (the training/compat load;
    /// always an owned copy).
    pub fn load(&self, name: &str) -> Result<CompressedMatrix> {
        let e = self
            .find(name)
            .ok_or_else(|| anyhow::anyhow!("entry '{name}' not in shard (have: {})", self.names().join(", ")))?;
        format::decode_payload_ext(e.meta.kind, &self.bytes[e.start..e.start + e.len], false, true, None)
            .with_context(|| format!("decoding entry '{name}'"))
    }
}

/// One manifest entry: the `HSB1` metadata plus where the payload lives.
pub struct ManifestEntry {
    pub meta: EntryMeta,
    pub payload_off: u64,
    pub payload_len: u64,
    /// dominant value dtype of the payload (`DT_F32` for dense, `DT_F16`
    /// for factors) — informational, for tooling
    pub dtype: u8,
}

/// Manifest record of one shard file.
pub struct ShardMeta {
    pub rel_path: String,
    pub file_bytes: u64,
    pub file_crc: u32,
    pub entries: Vec<ManifestEntry>,
}

/// An opened sharded variant: parsed manifest, existence/length-validated
/// shards, and a lazy cache of opened (crc-verified, possibly mmap'd)
/// shard files. Shard opens are independent, so N layers can be decoded by
/// N threads and a corrupt shard fails only the loads that touch it.
pub struct ShardedVariant {
    dir: PathBuf,
    save_seq: u64,
    manifest_bytes: u64,
    shards: Vec<ShardMeta>,
    opened: Vec<Mutex<Option<Arc<ShardFile>>>>,
    mode: MmapMode,
}

impl ShardedVariant {
    /// Open `<variant>.hsb2/`: parse + crc-check the manifest, then verify
    /// every referenced shard exists at exactly its recorded length. A
    /// missing or truncated shard is rejected here with an error naming
    /// it; per-shard crc verification happens at first shard open.
    pub fn open(dir: &Path) -> Result<ShardedVariant> {
        ShardedVariant::open_with(dir, MmapMode::Auto)
    }

    /// [`ShardedVariant::open`] with an explicit mmap policy.
    pub fn open_with(dir: &Path, mode: MmapMode) -> Result<ShardedVariant> {
        let manifest_path = dir.join(MANIFEST_NAME);
        let bytes = std::fs::read(&manifest_path)
            .with_context(|| format!("reading manifest {}", manifest_path.display()))?;
        let shards = parse_manifest_body(&bytes)
            .with_context(|| format!("parsing manifest {}", manifest_path.display()))?;
        let (save_seq, shards) = shards;
        // every referenced shard must exist at its recorded length before
        // the variant opens — a precise early error beats a late decode one
        for s in &shards {
            let p = dir.join(&s.rel_path);
            let found = std::fs::metadata(&p)
                .map(|m| m.len())
                .map_err(|e| anyhow::anyhow!("shard '{}' missing: {e}", s.rel_path))
                .with_context(|| format!("sharded variant {}", dir.display()))?;
            if found != s.file_bytes {
                bail!(
                    "sharded variant {}: shard '{}' truncated or replaced (manifest records {} bytes, found {found})",
                    dir.display(),
                    s.rel_path,
                    s.file_bytes
                );
            }
        }
        let opened = shards.iter().map(|_| Mutex::new(None)).collect();
        Ok(ShardedVariant {
            dir: dir.to_path_buf(),
            save_seq,
            manifest_bytes: bytes.len() as u64,
            shards,
            opened,
            mode,
        })
    }

    pub fn save_seq(&self) -> u64 {
        self.save_seq
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total on-disk footprint: manifest + every shard at its manifest
    /// length.
    pub fn total_bytes(&self) -> u64 {
        self.manifest_bytes + self.shards.iter().map(|s| s.file_bytes).sum::<u64>()
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.entries.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entry names in manifest order.
    pub fn names(&self) -> Vec<&str> {
        self.shards
            .iter()
            .flat_map(|s| s.entries.iter().map(|e| e.meta.name.as_str()))
            .collect()
    }

    pub fn meta(&self, name: &str) -> Option<&EntryMeta> {
        self.find(name).map(|(s, e)| &self.shards[s].entries[e].meta)
    }

    fn find(&self, name: &str) -> Option<(usize, usize)> {
        for (si, s) in self.shards.iter().enumerate() {
            if let Some(ei) = s.entries.iter().position(|e| e.meta.name == name) {
                return Some((si, ei));
            }
        }
        None
    }

    /// Open (or fetch the cached) shard `i`, crc-verifying on first open
    /// and cross-checking its entry names against the manifest. Errors
    /// name the shard and are not cached — a transient failure retries.
    pub fn shard(&self, i: usize) -> Result<Arc<ShardFile>> {
        let mut slot = self.opened[i].lock().unwrap();
        if let Some(f) = slot.as_ref() {
            return Ok(f.clone());
        }
        let meta = &self.shards[i];
        let path = self.dir.join(&meta.rel_path);
        let f = ShardFile::open_with(&path, self.mode)
            .with_context(|| format!("opening shard '{}' of {}", meta.rel_path, self.dir.display()))?;
        let manifest_names: Vec<&str> = meta.entries.iter().map(|e| e.meta.name.as_str()).collect();
        if f.names() != manifest_names {
            bail!(
                "shard '{}' of {} does not match its manifest (shard entries [{}], manifest [{}])",
                meta.rel_path,
                self.dir.display(),
                f.names().join(", "),
                manifest_names.join(", ")
            );
        }
        let f = Arc::new(f);
        *slot = Some(f.clone());
        Ok(f)
    }

    /// Whether any opened shard is mmap-backed (opens the first shard if
    /// none is yet).
    pub fn is_mapped(&self) -> bool {
        for slot in &self.opened {
            if let Some(f) = slot.lock().unwrap().as_ref() {
                return f.is_mapped();
            }
        }
        self.shard(0).map(|f| f.is_mapped()).unwrap_or(false)
    }

    /// Decode one entry at its on-disk dtype (zero-copy when mapped).
    pub fn load_native(&self, name: &str) -> Result<CompressedMatrix> {
        let (si, _) = self
            .find(name)
            .ok_or_else(|| anyhow::anyhow!("entry '{name}' not in variant (have: {})", self.names().join(", ")))?;
        self.shard(si)?.load_native(name)
    }

    /// Decode one entry widening to f32.
    pub fn load(&self, name: &str) -> Result<CompressedMatrix> {
        let (si, _) = self
            .find(name)
            .ok_or_else(|| anyhow::anyhow!("entry '{name}' not in variant (have: {})", self.names().join(", ")))?;
        self.shard(si)?.load(name)
    }
}

/// Parse a manifest image (crc-checked): returns the save-seq and the
/// shard records.
fn parse_manifest_body(bytes: &[u8]) -> Result<(u64, Vec<ShardMeta>)> {
    if bytes.len() < 20 + 4 {
        bail!("manifest too short ({} bytes)", bytes.len());
    }
    let body = &bytes[..bytes.len() - 4];
    let footer = &bytes[bytes.len() - 4..];
    let want = u32::from_le_bytes([footer[0], footer[1], footer[2], footer[3]]);
    let got = crc32(body);
    if want != got {
        bail!("crc mismatch: footer {want:#010x} vs computed {got:#010x} (corrupt or truncated manifest)");
    }
    let mut r = ByteReader::new(body);
    r.expect_magic(MANIFEST_MAGIC, "HSB2 manifest")?;
    let version = r.u16()?;
    if version != MANIFEST_VERSION {
        bail!("unsupported manifest version {version} (this build reads {MANIFEST_VERSION})");
    }
    let _flags = r.u16()?;
    let save_seq = r.u64()?;
    let shard_count = r.u32()? as usize;
    let mut shards = Vec::with_capacity(shard_count.min(4096));
    for _ in 0..shard_count {
        let rel_path = r.string()?;
        if rel_path.contains('/') || rel_path.contains('\\') || rel_path.contains("..") {
            bail!("manifest shard path '{rel_path}' escapes the variant directory");
        }
        let file_bytes = r.u64()?;
        let file_crc = r.u32()?;
        let entry_count = r.u32()? as usize;
        let mut entries = Vec::with_capacity(entry_count.min(4096));
        for _ in 0..entry_count {
            let name = r.string()?;
            let kind = r.u8()?;
            if kind > KIND_HSS {
                bail!("manifest entry '{name}': unknown kind {kind}");
            }
            let method_byte = r.u8()?;
            let method = if method_byte == METHOD_UNKNOWN {
                None
            } else {
                Some(method_from_code(method_byte).ok_or_else(|| {
                    anyhow::anyhow!("manifest entry '{name}': bad method code {method_byte}")
                })?)
            };
            let rel_error = r.f64()?;
            let payload_off = r.u64()?;
            let payload_len = r.u64()?;
            let dtype = r.u8()?;
            if dtype != DT_F32 && dtype != DT_F16 {
                bail!("manifest entry '{name}': unknown dtype code {dtype}");
            }
            entries.push(ManifestEntry {
                meta: EntryMeta {
                    name,
                    kind,
                    method,
                    rel_error,
                },
                payload_off,
                payload_len,
                dtype,
            });
        }
        shards.push(ShardMeta {
            rel_path,
            file_bytes,
            file_crc,
            entries,
        });
    }
    if r.remaining() != 0 {
        bail!("{} trailing bytes after the last shard record", r.remaining());
    }
    Ok((save_seq, shards))
}

/// Header-only peek at a sharded variant's save-sequence number (the
/// manifest twin of [`super::reader::peek_save_seq`]): reads 16 bytes of
/// `manifest.hsb2` through the robust [`crate::util::binio::read_full`]
/// loop. `None` for anything that isn't a well-formed manifest header.
pub fn peek_sharded_save_seq(dir: &Path) -> Option<u64> {
    let head = crate::util::binio::read_file_prefix(&dir.join(MANIFEST_NAME), 16).ok()?;
    if head.len() < 16 || &head[..4] != MANIFEST_MAGIC {
        return None;
    }
    if u16::from_le_bytes([head[4], head[5]]) != MANIFEST_VERSION {
        return None;
    }
    Some(u64::from_le_bytes(head[8..16].try_into().expect("8-byte slice")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, CompressorConfig};
    use crate::data::synthetic;
    use crate::util::rng::Rng;

    fn sample_entries(n: usize, layers: usize) -> Vec<(String, CompressedMatrix)> {
        let comp = Compressor::new(CompressorConfig {
            rank: 8,
            sparsity: 0.15,
            depth: 2,
            min_leaf: 8,
            ..Default::default()
        });
        let mut out = Vec::new();
        for l in 0..layers {
            for (pi, proj) in ["wq", "wk", "wv"].iter().enumerate() {
                let w = synthetic::trained_like(n, (l * 3 + pi) as u64 + 1);
                let m = comp.compress(&w, Method::SHssRcm);
                out.push((format!("layer{l}.{proj}"), m));
            }
        }
        out
    }

    fn write_sample(dir: &Path, n: usize, layers: usize, seq: u64) -> Vec<(String, CompressedMatrix)> {
        let entries = sample_entries(n, layers);
        let refs: Vec<ShardEntry> = entries
            .iter()
            .map(|(name, m)| ShardEntry {
                name: name.clone(),
                method: Some(Method::SHssRcm),
                rel_error: 0.01,
                matrix: m,
            })
            .collect();
        write_sharded(dir, &refs, seq).unwrap();
        entries
    }

    fn tdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "hisolo-sharded-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn manifest_roundtrip_and_layout() {
        let base = tdir("roundtrip");
        let dir = base.join("v.hsb2");
        let entries = write_sample(&dir, 32, 3, 7);

        // one shard per layer + the manifest
        let mut files: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        files.sort();
        assert_eq!(
            files,
            vec!["layer0.shard", "layer1.shard", "layer2.shard", MANIFEST_NAME]
        );

        let v = ShardedVariant::open(&dir).unwrap();
        assert_eq!(v.save_seq(), 7);
        assert_eq!(v.shard_count(), 3);
        assert_eq!(v.len(), 9);
        let names: Vec<String> = entries.iter().map(|(n, _)| n.clone()).collect();
        assert_eq!(v.names(), names.iter().map(|s| s.as_str()).collect::<Vec<_>>());
        let meta = v.meta("layer1.wk").unwrap();
        assert_eq!(meta.method, Some(Method::SHssRcm));
        assert_eq!(peek_sharded_save_seq(&dir), Some(7));

        // every entry decodes and matvec-matches a direct aligned decode
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
        for (name, want) in &entries {
            let got = v.load_native(name).unwrap();
            assert_eq!(got.params(), want.params(), "{name}");
            // stored factors are fp16-quantized; compare against the
            // widened decode of the same payload, which must be bitwise
            let wide = v.load(name).unwrap();
            assert_eq!(got.matvec(&x), wide.matvec(&x), "{name}");
        }
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn aligned_value_runs_land_on_boundaries() {
        // decode with a buffered reader but assert the writer's pads put
        // every borrowable value run on a VALUE_ALIGN boundary: borrow
        // construction from an mmap must then never fall back
        let base = tdir("aligned");
        let dir = base.join("v.hsb2");
        write_sample(&dir, 32, 1, 1);
        let v = ShardedVariant::open_with(&dir, MmapMode::Auto).unwrap();
        let m = v.load_native("layer0.wq").unwrap();
        if v.is_mapped() {
            // with the aligned grammar every f16 factor borrow succeeds
            assert!(m.resident_weight_bytes() > 0);
            let mapped = count_mapped(&m);
            assert!(mapped.1 > 0, "no mapped buffers out of {}", mapped.0);
            assert_eq!(mapped.0, mapped.1, "borrow fell back to copying somewhere");
        }
        std::fs::remove_dir_all(&base).unwrap();
    }

    /// (total weight buffers, mapped weight buffers) across the matrix.
    fn count_mapped(m: &CompressedMatrix) -> (usize, usize) {
        let mut total = 0;
        let mut mapped = 0;
        let mut visit = |b: &crate::linalg::WeightBuf| {
            total += 1;
            if b.is_mapped() {
                mapped += 1;
            }
        };
        fn walk_node(n: &crate::hss::HssNode, f: &mut dyn FnMut(&crate::linalg::WeightBuf)) {
            match n {
                crate::hss::HssNode::Leaf { d } => f(&d.data),
                crate::hss::HssNode::Branch {
                    sparse,
                    u0,
                    r0,
                    u1,
                    r1,
                    c0,
                    c1,
                    ..
                } => {
                    f(&sparse.data);
                    f(&u0.data);
                    f(&r0.data);
                    f(&u1.data);
                    f(&r1.data);
                    walk_node(c0, f);
                    walk_node(c1, f);
                }
            }
        }
        match m {
            CompressedMatrix::Dense { w } => visit(&w.data),
            CompressedMatrix::LowRank { l, r, sparse } => {
                visit(&l.data);
                visit(&r.data);
                if let Some(s) = sparse {
                    visit(&s.data);
                }
            }
            CompressedMatrix::Hss { tree } => walk_node(tree, &mut visit),
        }
        (total, mapped)
    }

    #[test]
    fn bit_flip_fails_only_that_shard() {
        let base = tdir("bitflip");
        let dir = base.join("v.hsb2");
        write_sample(&dir, 32, 3, 2);
        // flip one payload byte deep inside layer1's shard
        let p = dir.join("layer1.shard");
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&p, &bytes).unwrap();

        let v = ShardedVariant::open(&dir).unwrap(); // manifest + lengths still fine
        // the intact shards load
        assert!(v.load_native("layer0.wq").is_ok());
        assert!(v.load_native("layer2.wv").is_ok());
        // the corrupt shard fails with an error naming it
        let e = v.load_native("layer1.wk").unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("layer1.shard"), "{msg}");
        assert!(msg.contains("crc"), "{msg}");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn missing_and_truncated_shards_named_at_open() {
        let base = tdir("missing");
        let dir = base.join("v.hsb2");
        write_sample(&dir, 32, 2, 3);

        // truncate layer1's shard: open must name it
        let p = dir.join("layer1.shard");
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 9]).unwrap();
        let e = ShardedVariant::open(&dir).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("layer1.shard") && msg.contains("truncated"), "{msg}");

        // remove it entirely: still named
        std::fs::remove_file(&p).unwrap();
        let e = ShardedVariant::open(&dir).unwrap_err();
        let msg = format!("{e:#}");
        assert!(msg.contains("layer1.shard") && msg.contains("missing"), "{msg}");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn manifest_crc_and_path_escapes_rejected() {
        let base = tdir("manifest-bad");
        let dir = base.join("v.hsb2");
        write_sample(&dir, 32, 1, 1);
        let mp = dir.join(MANIFEST_NAME);
        let mut bytes = std::fs::read(&mp).unwrap();
        bytes[10] ^= 0x01;
        std::fs::write(&mp, &bytes).unwrap();
        let e = ShardedVariant::open(&dir).unwrap_err();
        assert!(format!("{e:#}").contains("crc"), "{e:#}");
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn rewrite_replaces_variant_atomically() {
        let base = tdir("rewrite");
        let dir = base.join("v.hsb2");
        write_sample(&dir, 32, 2, 1);
        let v1 = ShardedVariant::open(&dir).unwrap();
        assert_eq!(v1.save_seq(), 1);
        // second write of the same variant path swaps the directory
        write_sample(&dir, 32, 3, 2);
        let v2 = ShardedVariant::open(&dir).unwrap();
        assert_eq!(v2.save_seq(), 2);
        assert_eq!(v2.shard_count(), 3);
        // no temp/old directories left behind
        let leftovers: Vec<String> = std::fs::read_dir(&base)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n != "v.hsb2")
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_dir_all(&base).unwrap();
    }
}
