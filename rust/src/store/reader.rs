//! `HSB1` reader: one read of the whole file, crc verification, then an
//! in-place section index. Individual entries decode lazily — loading one
//! matrix from a many-entry store touches only that entry's bytes — and
//! [`StoreFile::load_with_workspace`] pre-sizes the matvec scratch at load
//! time so the first request served from a cold start pays no allocation.

use crate::compress::compressed::BatchWorkspace;
use crate::compress::CompressedMatrix;
use crate::store::format::{
    decode_payload_ext, method_from_code, EntryMeta, FOOTER_BYTES, HEADER_BYTES, KIND_HSS, MAGIC,
    METHOD_UNKNOWN, MIN_VERSION, VERSION,
};
use crate::util::binio::{crc32, read_full, ByteReader};
use crate::util::mmap::{map_or_warn, Mmap};
use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::Arc;

pub(crate) struct EntryIndex {
    pub(crate) meta: EntryMeta,
    /// payload byte range within the file buffer
    pub(crate) start: usize,
    pub(crate) len: usize,
}

/// The raw bytes of an opened store artifact: a private heap copy (the
/// buffered path) or a shared read-only mapping (the zero-copy path — N
/// processes opening the same variant share one page-cache copy).
pub(crate) enum FileBytes {
    Owned(Vec<u8>),
    Mapped(Arc<Mmap>),
}

impl FileBytes {
    /// Read or map `path` according to `mode` (mmap falls back to a
    /// buffered read with a one-time warning — see
    /// [`crate::util::mmap::map_or_warn`]).
    pub(crate) fn open(path: &Path, mode: crate::store::MmapMode) -> Result<FileBytes> {
        if mode.wants_mmap() {
            if let Some(m) = map_or_warn(path) {
                return Ok(FileBytes::Mapped(m));
            }
        }
        let buf = std::fs::read(path)
            .with_context(|| format!("reading store file {}", path.display()))?;
        Ok(FileBytes::Owned(buf))
    }

    pub(crate) fn is_mapped(&self) -> bool {
        matches!(self, FileBytes::Mapped(_))
    }

    /// The backing mapping (for zero-copy payload borrows), if any.
    pub(crate) fn map(&self) -> Option<&Arc<Mmap>> {
        match self {
            FileBytes::Owned(_) => None,
            FileBytes::Mapped(m) => Some(m),
        }
    }
}

impl std::ops::Deref for FileBytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match self {
            FileBytes::Owned(v) => v,
            FileBytes::Mapped(m) => m,
        }
    }
}

/// Header-only peek at a store file's save-sequence number: reads just the
/// fixed header bytes — no payload read, no crc pass — so retention
/// ordering stays O(1) per variant even on multi-GB stores. Returns `None`
/// when the file is missing, too short, has the wrong magic, or an
/// unsupported version; version-1 files (which predate the field) read as
/// `Some(0)`. A corrupt file caught here simply sorts oldest; full
/// validation still happens on [`StoreFile::open`]. Rides the shared
/// [`read_full`] loop, so short reads and `EINTR` retry instead of
/// misreading a live file as corrupt.
pub fn peek_save_seq(path: &Path) -> Option<u64> {
    let mut f = std::fs::File::open(path).ok()?;
    // v2 header: magic(4) version(2) flags(2) save_seq(8)
    let mut head = [0u8; 16];
    let filled = read_full(&mut f, &mut head).ok()?;
    if filled < 8 || &head[..4] != MAGIC {
        return None;
    }
    match u16::from_le_bytes([head[4], head[5]]) {
        1 => Some(0),
        2 if filled == head.len() => {
            Some(u64::from_le_bytes(head[8..16].try_into().expect("8-byte slice")))
        }
        _ => None,
    }
}

/// A parsed, integrity-checked `HSB1` file. The backing bytes are either
/// a heap buffer or (by default, on unix) a shared read-only mmap; with a
/// mapped backing, [`StoreFile::load_native`] hands out weight buffers
/// that *borrow* the mapping wherever the on-disk layout permits.
pub struct StoreFile {
    buf: FileBytes,
    entries: Vec<EntryIndex>,
    save_seq: u64,
}

impl StoreFile {
    /// Read and validate `path`: magic, version, per-section lengths, and
    /// the crc32 footer (any truncation or bit corruption is rejected here,
    /// before any payload is decoded). Maps the file when mmap is
    /// available (kill-switch: `HISOLO_MMAP=off`).
    pub fn open(path: &Path) -> Result<StoreFile> {
        StoreFile::open_with(path, crate::store::MmapMode::Auto)
    }

    /// [`StoreFile::open`] pinned to the buffered (private heap copy)
    /// reader regardless of environment — the comparison arm for the
    /// zero-copy path's bitwise-identity checks.
    pub fn open_buffered(path: &Path) -> Result<StoreFile> {
        StoreFile::open_with(path, crate::store::MmapMode::Buffered)
    }

    /// Open with an explicit mmap policy.
    pub fn open_with(path: &Path, mode: crate::store::MmapMode) -> Result<StoreFile> {
        let buf = FileBytes::open(path, mode)?;
        StoreFile::from_file_bytes(buf).with_context(|| format!("parsing {}", path.display()))
    }

    /// Parse an in-memory `HSB1` image (the file-free path used by tests
    /// and by transports that already hold the bytes).
    pub fn from_bytes(buf: Vec<u8>) -> Result<StoreFile> {
        StoreFile::from_file_bytes(FileBytes::Owned(buf))
    }

    fn from_file_bytes(buf: FileBytes) -> Result<StoreFile> {
        let (entries, save_seq) = parse_hsb1(&buf)?;
        Ok(StoreFile {
            buf,
            entries,
            save_seq,
        })
    }

    /// Whether the backing bytes are a shared mmap (zero-copy serving)
    /// rather than a private heap copy.
    pub fn is_mapped(&self) -> bool {
        self.buf.is_mapped()
    }

    /// Save-sequence number stamped at write time (0 for v1 files and
    /// writers that never set one) — the exact retention ordering key.
    pub fn save_seq(&self) -> u64 {
        self.save_seq
    }

    /// Decode context for entry payloads: the backing mapping plus the
    /// absolute offset of the payload within it (None when buffered).
    fn map_ctx(&self, start: usize) -> crate::store::format::PayloadMap {
        self.buf.map().map(|m| (m.clone(), start))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Total on-disk footprint, header and footer included.
    pub fn total_bytes(&self) -> usize {
        self.buf.len()
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.meta.name.as_str()).collect()
    }

    pub fn meta(&self, name: &str) -> Option<&EntryMeta> {
        self.find(name).map(|e| &e.meta)
    }

    fn find(&self, name: &str) -> Option<&EntryIndex> {
        self.entries.iter().find(|e| e.meta.name == name)
    }

    /// Decode one entry into its runtime representation — no recompression,
    /// fp16 sections widened to f32 (the training/compatibility load; the
    /// serving path uses [`StoreFile::load_native`]).
    pub fn load(&self, name: &str) -> Result<CompressedMatrix> {
        let e = self
            .find(name)
            .ok_or_else(|| anyhow::anyhow!("entry '{name}' not in store (have: {})", self.names().join(", ")))?;
        decode_payload_ext(e.meta.kind, &self.buf[e.start..e.start + e.len], false, false, None)
            .with_context(|| format!("decoding entry '{name}'"))
    }

    /// Decode one entry keeping the **on-disk dtype**: fp16 factors come
    /// back f16-resident, widened lane-by-lane inside the batched kernels
    /// — no f32 factor buffer is ever allocated, so the loaded matrix is
    /// resident at the bytes the format pays for.
    pub fn load_native(&self, name: &str) -> Result<CompressedMatrix> {
        let e = self
            .find(name)
            .ok_or_else(|| anyhow::anyhow!("entry '{name}' not in store (have: {})", self.names().join(", ")))?;
        decode_payload_ext(
            e.meta.kind,
            &self.buf[e.start..e.start + e.len],
            true,
            false,
            self.map_ctx(e.start),
        )
        .with_context(|| format!("decoding entry '{name}' (native dtype)"))
    }

    /// Load plus a pre-sized [`BatchWorkspace`], so the caller's first
    /// `matvec_with` allocates nothing.
    pub fn load_with_workspace(&self, name: &str) -> Result<(CompressedMatrix, BatchWorkspace)> {
        let m = self.load(name)?;
        let ws = m.workspace();
        Ok((m, ws))
    }

    /// [`StoreFile::load_native`] plus a pre-sized [`BatchWorkspace`] —
    /// the cold-start serving load: f16-resident factors, no first-request
    /// allocation.
    pub fn load_native_with_workspace(
        &self,
        name: &str,
    ) -> Result<(CompressedMatrix, BatchWorkspace)> {
        let m = self.load_native(name)?;
        let ws = m.workspace();
        Ok((m, ws))
    }

    /// Decode every entry in file order (widening load).
    pub fn load_all(&self) -> Result<Vec<(String, CompressedMatrix)>> {
        self.entries
            .iter()
            .map(|e| Ok((e.meta.name.clone(), self.load(&e.meta.name)?)))
            .collect()
    }
}

/// Validate and index an `HSB1` image: crc footer, magic, version,
/// save-seq header, and the per-entry section table.
fn parse_hsb1(buf: &[u8]) -> Result<(Vec<EntryIndex>, u64)> {
    if buf.len() < HEADER_BYTES + FOOTER_BYTES {
        bail!("file too short ({} bytes) for an HSB1 store", buf.len());
    }
    let body = &buf[..buf.len() - FOOTER_BYTES];
    let footer = &buf[buf.len() - FOOTER_BYTES..];
    let want = u32::from_le_bytes([footer[0], footer[1], footer[2], footer[3]]);
    let got = crc32(body);
    if want != got {
        bail!("crc mismatch: footer {want:#010x} vs computed {got:#010x} (corrupt or truncated store)");
    }

    let mut r = ByteReader::new(body);
    r.expect_magic(MAGIC, "HSB1")?;
    let version = r.u16()?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        bail!("unsupported HSB1 version {version} (this build reads {MIN_VERSION}..={VERSION})");
    }
    let _flags = r.u16()?;
    // v1 predates the save-sequence field; old files read as seq 0
    let save_seq = if version >= 2 { r.u64()? } else { 0 };
    let count = r.u32()? as usize;
    let entries = parse_entry_table(&mut r, count)?;
    if r.remaining() != 0 {
        bail!("{} trailing bytes after the last entry", r.remaining());
    }
    Ok((entries, save_seq))
}

/// Parse `count` entry headers + payload extents from `r` — the table
/// layout `HSB1` files and `HSB2` shards share.
pub(crate) fn parse_entry_table(r: &mut ByteReader, count: usize) -> Result<Vec<EntryIndex>> {
    let mut entries = Vec::with_capacity(count.min(1024));
    for _ in 0..count {
        let name = r.string()?;
        let kind = r.u8()?;
        if kind > KIND_HSS {
            bail!("entry '{name}': unknown kind {kind}");
        }
        let method_byte = r.u8()?;
        let method = if method_byte == METHOD_UNKNOWN {
            None
        } else {
            Some(
                method_from_code(method_byte)
                    .ok_or_else(|| anyhow::anyhow!("entry '{name}': bad method code {method_byte}"))?,
            )
        };
        let rel_error = r.f64()?;
        let len = r.u64()? as usize;
        let start = r.pos();
        r.take(len)
            .with_context(|| format!("entry '{name}' payload"))?;
        entries.push(EntryIndex {
            meta: EntryMeta {
                name,
                kind,
                method,
                rel_error,
            },
            start,
            len,
        });
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, CompressorConfig, Method};
    use crate::data::synthetic;
    use crate::store::StoreWriter;
    use crate::util::proptest::slices_close;
    use crate::util::rng::Rng;

    fn sample_writer(n: usize) -> StoreWriter {
        let w = synthetic::trained_like(n, 11);
        let comp = Compressor::new(CompressorConfig {
            rank: 8,
            sparsity: 0.15,
            depth: 2,
            min_leaf: 8,
            ..Default::default()
        });
        let mut sw = StoreWriter::new();
        for (name, m) in [
            ("dense", Method::Dense),
            ("lowrank", Method::SSvd),
            ("hss", Method::SHssRcm),
        ] {
            sw.push_with_meta(name, &comp.compress(&w, m), Some(m), 0.01);
        }
        sw
    }

    #[test]
    fn container_roundtrip_in_memory() {
        let sw = sample_writer(48);
        let file = StoreFile::from_bytes(sw.to_bytes()).unwrap();
        assert_eq!(file.names(), vec!["dense", "lowrank", "hss"]);
        assert_eq!(file.len(), 3);
        let meta = file.meta("hss").unwrap();
        assert_eq!(meta.method, Some(Method::SHssRcm));
        assert!((meta.rel_error - 0.01).abs() < 1e-12);
        for name in ["dense", "lowrank", "hss"] {
            let (m, mut ws) = file.load_with_workspace(name).unwrap();
            assert_eq!(m.n(), 48);
            let mut rng = Rng::new(1);
            let x: Vec<f32> = (0..48).map(|_| rng.gaussian_f32()).collect();
            let mut y = vec![0.0; 48];
            m.matvec_with(&x, &mut y, &mut ws);
            slices_close(&y, &m.matvec(&x), 1e-6, 1e-6, name).unwrap();
        }
        assert!(file.load("nope").is_err());
    }

    /// Satellite: the f16-native load allocates no f32 factor buffers —
    /// every loaded weight buffer is u16-resident, at exactly half the
    /// widened footprint, and serves bit-identical matvecs.
    #[test]
    fn native_load_keeps_factors_f16_resident() {
        use crate::linalg::Dtype;
        let sw = sample_writer(48);
        let file = StoreFile::from_bytes(sw.to_bytes()).unwrap();
        for name in ["lowrank", "hss"] {
            let (native, mut ws) = file.load_native_with_workspace(name).unwrap();
            let wide = file.load(name).unwrap();
            assert_eq!(native.weights_dtype(), Dtype::F16, "{name}");
            assert_eq!(
                native.resident_weight_bytes() * 2,
                wide.resident_weight_bytes(),
                "{name}"
            );
            let mut rng = Rng::new(2);
            let x: Vec<f32> = (0..48).map(|_| rng.gaussian_f32()).collect();
            let mut y = vec![0.0; 48];
            native.matvec_with(&x, &mut y, &mut ws);
            assert_eq!(y, wide.matvec(&x), "{name}: native != widened numerics");
        }
        // dense stays f32 on disk and in memory
        let d = file.load_native("dense").unwrap();
        assert_eq!(d.weights_dtype(), Dtype::F32);
    }

    #[test]
    fn file_roundtrip_atomic_write() {
        let dir = std::env::temp_dir().join("hisolo_test_store_reader");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.hsb1");
        let sw = sample_writer(32);
        let written = sw.finish(&path).unwrap();
        assert_eq!(written, std::fs::metadata(&path).unwrap().len());
        let file = StoreFile::open(&path).unwrap();
        assert_eq!(file.total_bytes() as u64, written);
        assert_eq!(file.load_all().unwrap().len(), 3);
    }

    #[test]
    fn every_truncation_rejected() {
        let bytes = sample_writer(32).to_bytes();
        // chop at a spread of offsets including mid-header and mid-payload
        for cut in [0, 3, 8, 11, bytes.len() / 3, bytes.len() - 5, bytes.len() - 1] {
            assert!(
                StoreFile::from_bytes(bytes[..cut].to_vec()).is_err(),
                "truncation at {cut} accepted"
            );
        }
    }

    #[test]
    fn bit_flips_rejected_by_crc() {
        let bytes = sample_writer(32).to_bytes();
        for pos in [4usize, 12, bytes.len() / 2, bytes.len() - 6] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x01;
            let e = StoreFile::from_bytes(bad).unwrap_err();
            assert!(format!("{e}").contains("crc"), "flip at {pos}: {e}");
        }
    }

    #[test]
    fn save_seq_roundtrips_and_v1_files_read_as_seq_zero() {
        let mut sw = sample_writer(32);
        sw.set_save_seq(42);
        let v2 = sw.to_bytes();
        let file = StoreFile::from_bytes(v2.clone()).unwrap();
        assert_eq!(file.save_seq(), 42);

        // rebuild the same entries as a version-1 image (header without
        // the seq field): old files must keep parsing, as seq 0
        let v1 = crate::store::format::downgrade_image_to_v1(&v2);
        let old = StoreFile::from_bytes(v1.clone()).unwrap();
        assert_eq!(old.save_seq(), 0);
        assert_eq!(old.names(), file.names());
        for name in old.names() {
            let a = old.load(name).unwrap();
            let b = file.load(name).unwrap();
            assert_eq!(a.params(), b.params(), "{name}");
        }

        // the header-only peek agrees with the full parse for both versions
        let dir = std::env::temp_dir().join("hisolo_test_store_peek");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("v2.hsb1"), &v2).unwrap();
        std::fs::write(dir.join("v1.hsb1"), &v1).unwrap();
        std::fs::write(dir.join("junk.hsb1"), b"XXXX").unwrap();
        assert_eq!(peek_save_seq(&dir.join("v2.hsb1")), Some(42));
        assert_eq!(peek_save_seq(&dir.join("v1.hsb1")), Some(0));
        assert_eq!(peek_save_seq(&dir.join("junk.hsb1")), None);
        assert_eq!(peek_save_seq(&dir.join("absent.hsb1")), None);
    }

    #[test]
    fn wrong_magic_and_version_rejected() {
        let mut bytes = sample_writer(32).to_bytes();
        // magic flip, with crc recomputed so only the magic check can fire
        bytes[0] = b'X';
        let body_len = bytes.len() - 4;
        let crc = crate::util::binio::crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        let e = StoreFile::from_bytes(bytes.clone()).unwrap_err();
        assert!(format!("{e:#}").contains("magic"), "{e:#}");

        bytes[0] = b'H';
        bytes[4] = 99; // version
        let crc = crate::util::binio::crc32(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&crc.to_le_bytes());
        let e = StoreFile::from_bytes(bytes).unwrap_err();
        assert!(format!("{e:#}").contains("version"), "{e:#}");
    }
}
