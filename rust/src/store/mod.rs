//! `store/` — the native on-disk format for compressed artifacts (`HSB1`)
//! and the variant registry the serving coordinator cold-starts and
//! hot-swaps from.
//!
//! The paper's headline claim is the compressed footprint, but a
//! [`crate::compress::CompressedMatrix`] that only ever lives in RAM must be
//! recompressed from dense weights on every process start — minutes of SVD
//! work before the first request. `HSB1` persists every variant — CSR
//! spikes, recursive HSS trees (U/R factors at fp16, per-level
//! permutations, leaf blocks), and plain low-rank factors — behind a
//! versioned header, per-section lengths, and a crc32 integrity footer:
//!
//! - [`StoreWriter`] serializes named entries and writes atomically
//!   (temp + rename), so readers racing a writer never see a torn file;
//! - [`StoreFile`] reads the file once, verifies the crc, indexes sections
//!   in place, and decodes entries on demand —
//!   [`StoreFile::load_with_workspace`] also pre-sizes the matvec scratch
//!   so a cold-started worker's first request allocates nothing;
//! - [`ModelStore`] keys entries by `(layer, variant)` across one file per
//!   variant and rebuilds a [`crate::model::CompressedModel`] without
//!   recompression — the input to `Coordinator::swap_variant`.
//!
//! **The store's dtype is the serving dtype**: [`StoreFile::load_native`]
//! (what `CompressedModel::from_store` uses) keeps fp16 sections
//! f16-resident as raw `u16` bit patterns — no load-time widening, no f32
//! factor buffer ever allocated — and the batched kernels widen
//! lane-by-lane in-register. A served variant is therefore resident at
//! the bytes the format pays for (half of an f32-widened load), with
//! numerics bit-identical to widening at load. [`StoreFile::load`] remains
//! the widening path for training and compatibility; `finetune` trains
//! f32 and narrows back to fp16 on save.
//!
//! ## `HSB1` format spec (version 2)
//!
//! Little-endian throughout; crc32 (IEEE, via [`crate::util::binio`])
//! over every byte before the footer.
//!
//! ```text
//! header:  "HSB1" · u16 version · u16 flags · [v2+: u64 save_seq]
//!          · u32 entry_count
//! entry:   u32 name_len · name-bytes · u8 kind(0=dense,1=lowrank,2=hss)
//!          · u8 method (255 = unknown) · f64 rel_error
//!          · u64 payload_len · payload
//! footer:  u32 crc32
//! ```
//!
//! Header v2 fields: `save_seq` is a monotonically increasing sequence
//! number stamped by `ModelStore::save_model` (retention orders by it
//! exactly; v1 files parse as seq 0, tie-broken by mtime then name).
//! `flags` is reserved (written 0, ignored on read).
//!
//! Payload grammar (dtype tags: 0 = f32, 1 = f16):
//!
//! ```text
//! matrix  := u32 rows · u32 cols · u8 dtype · values
//! csr     := u32 rows · u32 cols · u32 nnz · indptr u32×(rows+1)
//!            · indices u32×nnz · u8 dtype · values
//! dense   := matrix(f32)                         (bit-exact baseline)
//! lowrank := matrix l(f16) · matrix r(f16) · u8 has_sparse · [csr]
//! node    := u8 0 · matrix d(f16)
//!          | u8 1 · u32 n · csr · u8 has_perm · [perm u32×n]
//!            · matrix u0 · r0 · u1 · r1 · node c0 · node c1
//! hss     := node
//! ```
//!
//! Every f16 payload is the exact bytes the serving path keeps resident;
//! re-saving a natively-loaded entry is a verbatim byte copy (no
//! requantization). The per-entry `payload_len` lets the reader index
//! sections without decoding them. The binary primitives (magic,
//! length-prefixed strings, dtype tags, crc32) are shared with the `HWT1`
//! weight container via [`crate::util::binio`].

pub mod format;
pub mod model_store;
pub mod reader;
pub mod writer;

pub use format::EntryMeta;
pub use model_store::{entry_name, ModelStore};
pub use reader::StoreFile;
pub use writer::StoreWriter;
