//! `store/` — the native on-disk format for compressed artifacts (`HSB1`)
//! and the variant registry the serving coordinator cold-starts and
//! hot-swaps from.
//!
//! The paper's headline claim is the compressed footprint, but a
//! [`crate::compress::CompressedMatrix`] that only ever lives in RAM must be
//! recompressed from dense weights on every process start — minutes of SVD
//! work before the first request. `HSB1` persists every variant — CSR
//! spikes, recursive HSS trees (U/R factors at fp16, per-level
//! permutations, leaf blocks), and plain low-rank factors — behind a
//! versioned header, per-section lengths, and a crc32 integrity footer:
//!
//! - [`StoreWriter`] serializes named entries and writes atomically
//!   (temp + rename), so readers racing a writer never see a torn file;
//! - [`StoreFile`] reads the file once, verifies the crc, indexes sections
//!   in place, and decodes entries on demand —
//!   [`StoreFile::load_with_workspace`] also pre-sizes the matvec scratch
//!   so a cold-started worker's first request allocates nothing;
//! - [`ModelStore`] keys entries by `(layer, variant)` across one file per
//!   variant and rebuilds a [`crate::model::CompressedModel`] without
//!   recompression — the input to `Coordinator::swap_variant`.
//!
//! **The store's dtype is the serving dtype**: [`StoreFile::load_native`]
//! (what `CompressedModel::from_store` uses) keeps fp16 sections
//! f16-resident as raw `u16` bit patterns — no load-time widening, no f32
//! factor buffer ever allocated — and the batched kernels widen
//! lane-by-lane in-register. A served variant is therefore resident at
//! the bytes the format pays for (half of an f32-widened load), with
//! numerics bit-identical to widening at load. [`StoreFile::load`] remains
//! the widening path for training and compatibility; `finetune` trains
//! f32 and narrows back to fp16 on save.
//!
//! ## `HSB1` format spec (version 2)
//!
//! Little-endian throughout; crc32 (IEEE, via [`crate::util::binio`])
//! over every byte before the footer.
//!
//! ```text
//! header:  "HSB1" · u16 version · u16 flags · [v2+: u64 save_seq]
//!          · u32 entry_count
//! entry:   u32 name_len · name-bytes · u8 kind(0=dense,1=lowrank,2=hss)
//!          · u8 method (255 = unknown) · f64 rel_error
//!          · u64 payload_len · payload
//! footer:  u32 crc32
//! ```
//!
//! Header v2 fields: `save_seq` is a monotonically increasing sequence
//! number stamped by `ModelStore::save_model` (retention orders by it
//! exactly; v1 files parse as seq 0, tie-broken by mtime then name).
//! `flags` is reserved (written 0, ignored on read).
//!
//! Payload grammar (dtype tags: 0 = f32, 1 = f16):
//!
//! ```text
//! matrix  := u32 rows · u32 cols · u8 dtype · values
//! csr     := u32 rows · u32 cols · u32 nnz · indptr u32×(rows+1)
//!            · indices u32×nnz · u8 dtype · values
//! dense   := matrix(f32)                         (bit-exact baseline)
//! lowrank := matrix l(f16) · matrix r(f16) · u8 has_sparse · [csr]
//! node    := u8 0 · matrix d(f16)
//!          | u8 1 · u32 n · csr · u8 has_perm · [perm u32×n]
//!            · matrix u0 · r0 · u1 · r1 · node c0 · node c1
//! hss     := node
//! ```
//!
//! Every f16 payload is the exact bytes the serving path keeps resident;
//! re-saving a natively-loaded entry is a verbatim byte copy (no
//! requantization). The per-entry `payload_len` lets the reader index
//! sections without decoding them. The binary primitives (magic,
//! length-prefixed strings, dtype tags, crc32) are shared with the `HWT1`
//! weight container via [`crate::util::binio`].
//!
//! ## `HSB2` sharded format spec (version 1)
//!
//! A sharded variant is a directory `<variant>.hsb2/` holding one shard
//! file per layer plus `manifest.hsb2`, written shards-first /
//! manifest-last and deleted manifest-first (so an on-disk manifest always
//! references complete shards). The point of the split is *zero-copy
//! serving*: shard readers mmap the file, and the decoder hands out
//! [`crate::linalg::WeightBuf`] values whose f16/f32 runs **borrow the
//! mapping** — N serving processes on one host share a single page-cache
//! copy of the factors, cold-start skips the read+copy entirely, and the
//! kernels see the same `&[u16]`/`&[f32]` slices they always did (0 ULP
//! vs the buffered path). `HISOLO_MMAP=off|0|buffered` forces the copying
//! reader; mmap failure falls back with a once-per-process warning.
//!
//! Shard file (`<prefix>.shard`, one per entry-name prefix, i.e. one per
//! layer for `layer{i}.w{q,k,v}` entries):
//!
//! ```text
//! header:  "HSB2" · u16 version · u16 flags · u32 entry_count
//! entry:   u32 name_len · name-bytes · u8 kind · u8 method
//!          · f64 rel_error · u64 payload_len · payload (aligned grammar)
//! footer:  u32 crc32 over everything above
//! ```
//!
//! The payload grammar is `HSB1`'s with one change: every `values` run is
//! preceded by `u8 pad_len · pad_len zero bytes` bringing the run's first
//! byte to a [`format::VALUE_ALIGN`]-byte *file* offset, so a borrow from
//! the mapping is always correctly aligned for `[u16]`/`[f32]`.
//!
//! Manifest (`manifest.hsb2`):
//!
//! ```text
//! header:  "HSBM" · u16 version · u16 flags · u64 save_seq
//!          · u32 shard_count
//! shard:   u32 path_len · rel-path-bytes · u64 file_bytes · u32 file_crc
//!          · u32 entry_count
//!          entry: u32 name_len · name-bytes · u8 kind · u8 method
//!                 · f64 rel_error · u64 payload_off · u64 payload_len
//!                 · u8 dtype
//! footer:  u32 crc32
//! ```
//!
//! `file_crc` duplicates the shard's own footer crc; `payload_off` is the
//! payload's absolute offset within its shard file. [`ShardedVariant`]
//! validates existence + exact length of every shard at open (errors name
//! the offending shard), crc-verifies each shard lazily on first touch —
//! so a bit flip in one layer's shard fails only that layer's loads — and
//! its independent per-shard opens are what `CompressedModel::from_store`
//! fans out across threads.

pub mod format;
pub mod model_store;
pub mod reader;
pub mod sharded;
pub mod writer;

pub use format::EntryMeta;
pub use model_store::{entry_name, ModelStore, VariantFile};
pub use reader::StoreFile;
pub use sharded::{write_sharded, ShardEntry, ShardedVariant};
pub use writer::StoreWriter;

/// Reader backing policy: `Auto` mmaps when the platform and
/// `HISOLO_MMAP` allow it (falling back to a buffered read otherwise),
/// `Buffered` always reads into an owned heap buffer. `Buffered` exists
/// so one process can hold both backings of the same file and compare
/// them bit-for-bit (see `benches/store_load.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MmapMode {
    Auto,
    Buffered,
}

impl MmapMode {
    pub(crate) fn wants_mmap(self) -> bool {
        matches!(self, MmapMode::Auto)
    }
}
