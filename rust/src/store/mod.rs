//! `store/` — the native on-disk format for compressed artifacts (`HSB1`)
//! and the variant registry the serving coordinator cold-starts and
//! hot-swaps from.
//!
//! The paper's headline claim is the compressed footprint, but a
//! [`crate::compress::CompressedMatrix`] that only ever lives in RAM must be
//! recompressed from dense weights on every process start — minutes of SVD
//! work before the first request. `HSB1` persists every variant — CSR
//! spikes, recursive HSS trees (U/R factors at fp16, per-level
//! permutations, leaf blocks), and plain low-rank factors — behind a
//! versioned header, per-section lengths, and a crc32 integrity footer:
//!
//! - [`StoreWriter`] serializes named entries and writes atomically
//!   (temp + rename), so readers racing a writer never see a torn file;
//! - [`StoreFile`] reads the file once, verifies the crc, indexes sections
//!   in place, and decodes entries on demand —
//!   [`StoreFile::load_with_workspace`] also pre-sizes the matvec scratch
//!   so a cold-started worker's first request allocates nothing;
//! - [`ModelStore`] keys entries by `(layer, variant)` across one file per
//!   variant and rebuilds a [`crate::model::CompressedModel`] without
//!   recompression — the input to `Coordinator::swap_variant`.
//!
//! Format details live in [`format`]; the binary primitives (magic,
//! length-prefixed strings, dtype tags, crc32) are shared with the `HWT1`
//! weight container via [`crate::util::binio`].

pub mod format;
pub mod model_store;
pub mod reader;
pub mod writer;

pub use format::EntryMeta;
pub use model_store::{entry_name, ModelStore};
pub use reader::StoreFile;
pub use writer::StoreWriter;
