//! `HSB1` writer: collects named [`CompressedMatrix`] entries and emits one
//! integrity-checked store file, atomically.

use crate::compress::{CompressedMatrix, Method};
use crate::store::format::{
    encode_payload, kind_of, method_code, EntryMeta, MAGIC, METHOD_UNKNOWN, VERSION,
};
use crate::util::binio::{crc32, put_string, put_u16, put_u32, put_u64, put_f64};
use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// Builder for an `HSB1` file. Entries are serialized on `push`, so the
/// writer never holds the matrices themselves — only their encoded bytes.
#[derive(Default)]
pub struct StoreWriter {
    entries: Vec<(EntryMeta, Vec<u8>)>,
    save_seq: u64,
}

impl StoreWriter {
    pub fn new() -> StoreWriter {
        StoreWriter::default()
    }

    /// Stamp the monotonically increasing save-sequence number written
    /// into the v2 header (0 when never set — files saved outside
    /// `ModelStore::save_model` sort as oldest).
    pub fn set_save_seq(&mut self, seq: u64) {
        self.save_seq = seq;
    }

    /// Add an entry without provenance metadata.
    pub fn push(&mut self, name: &str, m: &CompressedMatrix) {
        self.push_with_meta(name, m, None, f64::NAN);
    }

    /// Add an entry recording the method and compression-time error, so a
    /// loaded model can reconstruct its layer reports without the original
    /// dense weights.
    pub fn push_with_meta(
        &mut self,
        name: &str,
        m: &CompressedMatrix,
        method: Option<Method>,
        rel_error: f64,
    ) {
        let meta = EntryMeta {
            name: name.to_string(),
            kind: kind_of(m),
            method,
            rel_error,
        };
        self.entries.push((meta, encode_payload(m)));
    }

    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// Serialize header, entries, and crc footer into one buffer.
    pub fn to_bytes(&self) -> Vec<u8> {
        let payload_total: usize = self.entries.iter().map(|(_, p)| p.len()).sum();
        let mut out = Vec::with_capacity(payload_total + 64 * self.entries.len() + 16);
        out.extend_from_slice(MAGIC);
        put_u16(&mut out, VERSION);
        put_u16(&mut out, 0); // flags, reserved
        put_u64(&mut out, self.save_seq);
        put_u32(&mut out, self.entries.len() as u32);
        for (meta, payload) in &self.entries {
            put_string(&mut out, &meta.name);
            out.push(meta.kind);
            out.push(meta.method.map_or(METHOD_UNKNOWN, method_code));
            put_f64(&mut out, meta.rel_error);
            put_u64(&mut out, payload.len() as u64);
            out.extend_from_slice(payload);
        }
        let crc = crc32(&out);
        put_u32(&mut out, crc);
        out
    }

    /// Write the store to `path` atomically (temp file + rename), so a
    /// serving coordinator hot-swapping from this path never observes a
    /// half-written file. The temp name is unique per process and call,
    /// so concurrent saves of the same variant cannot interleave into a
    /// corrupt artifact — last rename wins, both renamed files are
    /// complete. Returns the byte count written.
    pub fn finish(&self, path: &Path) -> Result<u64> {
        static SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let bytes = self.to_bytes();
        let tmp = match path.file_name() {
            Some(name) => {
                let mut n = name.to_os_string();
                n.push(format!(
                    ".tmp.{}.{}",
                    std::process::id(),
                    SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
                ));
                path.with_file_name(n)
            }
            None => anyhow::bail!("store path {} has no file name", path.display()),
        };
        {
            // sync data before the rename becomes durable, so a crash can
            // never replace the previous good artifact with unflushed bytes
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&bytes)
                .with_context(|| format!("writing {}", tmp.display()))?;
            f.sync_all()
                .with_context(|| format!("syncing {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;
        // best-effort directory sync so the rename itself is durable
        if let Some(dir) = path.parent() {
            if let Ok(d) = std::fs::File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(bytes.len() as u64)
    }
}
