//! Variant-keyed registry over `HSB1` files and `HSB2` shard directories —
//! the coordinator's view of the store.
//!
//! A variant is either one file (`<dir>/<variant>.hsb1`) or one sharded
//! directory (`<dir>/<variant>.hsb2/`, see [`crate::store::sharded`]),
//! each holding every compressed q/k/v projection as
//! `layer{i}.{wq,wk,wv}` entries. Lookups are keyed by
//! `(layer, variant)`; [`ModelStore::open_variant`] resolves either form
//! into a [`VariantFile`] (preferring the newer save-seq when both
//! exist), and whole-model loads rebuild a [`CompressedModel`] without
//! recompression — which is what makes cold starts and live hot-swaps
//! (`Coordinator::swap_variant`) cheap.

use crate::compress::CompressedMatrix;
use crate::model::transformer::Proj;
use crate::model::{CompressedModel, Transformer};
use crate::store::format::EntryMeta;
use crate::store::sharded::{self, ShardedVariant};
use crate::store::{MmapMode, StoreFile};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// An opened variant, whichever on-disk form it takes: a monolithic
/// `HSB1` file or a sharded `HSB2` directory. One decode surface
/// (`meta`/`load`/`load_native`) over both, so
/// [`CompressedModel::from_store`] and the coordinator never branch on
/// the storage layout.
pub enum VariantFile {
    Single(StoreFile),
    Sharded(ShardedVariant),
}

impl VariantFile {
    pub fn names(&self) -> Vec<&str> {
        match self {
            VariantFile::Single(f) => f.names(),
            VariantFile::Sharded(v) => v.names(),
        }
    }

    pub fn meta(&self, name: &str) -> Option<&EntryMeta> {
        match self {
            VariantFile::Single(f) => f.meta(name),
            VariantFile::Sharded(v) => v.meta(name),
        }
    }

    /// Decode one entry widening f16 to f32 (training/compat path).
    pub fn load(&self, name: &str) -> Result<CompressedMatrix> {
        match self {
            VariantFile::Single(f) => f.load(name),
            VariantFile::Sharded(v) => v.load(name),
        }
    }

    /// Decode one entry at its on-disk dtype — zero-copy out of the
    /// mapping when the backing is mmap'd.
    pub fn load_native(&self, name: &str) -> Result<CompressedMatrix> {
        match self {
            VariantFile::Single(f) => f.load_native(name),
            VariantFile::Sharded(v) => v.load_native(name),
        }
    }

    pub fn save_seq(&self) -> u64 {
        match self {
            VariantFile::Single(f) => f.save_seq(),
            VariantFile::Sharded(v) => v.save_seq(),
        }
    }

    /// Whether payload bytes are served out of an mmap (vs owned heap
    /// copies).
    pub fn is_mapped(&self) -> bool {
        match self {
            VariantFile::Single(f) => f.is_mapped(),
            VariantFile::Sharded(v) => v.is_mapped(),
        }
    }

    /// Number of independent shard files (1 for a monolithic variant).
    pub fn shard_count(&self) -> usize {
        match self {
            VariantFile::Single(_) => 1,
            VariantFile::Sharded(v) => v.shard_count(),
        }
    }

    pub fn is_sharded(&self) -> bool {
        matches!(self, VariantFile::Sharded(_))
    }
}

/// Canonical entry name for one projection: `layer{layer}.{wq|wk|wv}`.
pub fn entry_name(layer: usize, proj: Proj) -> String {
    let p = match proj {
        Proj::Q => "wq",
        Proj::K => "wk",
        Proj::V => "wv",
    };
    format!("layer{layer}.{p}")
}

/// A directory of variant store files.
pub struct ModelStore {
    dir: PathBuf,
}

impl ModelStore {
    /// Bind to a store directory (created lazily on first save).
    pub fn open(dir: impl Into<PathBuf>) -> ModelStore {
        ModelStore { dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// File backing one variant's monolithic (`HSB1`) form.
    pub fn variant_path(&self, variant: &str) -> PathBuf {
        self.dir.join(format!("{variant}.hsb1"))
    }

    /// Directory backing one variant's sharded (`HSB2`) form.
    pub fn sharded_path(&self, variant: &str) -> PathBuf {
        self.dir.join(format!("{variant}.{}", sharded::SHARDED_EXT))
    }

    pub fn has_variant(&self, variant: &str) -> bool {
        self.variant_path(variant).exists() || self.sharded_path(variant).is_dir()
    }

    /// Variant names present on disk, either form, deduplicated (sorted).
    pub fn variants(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        if let Ok(rd) = std::fs::read_dir(&self.dir) {
            for e in rd.flatten() {
                let path = e.path();
                let ext = path.extension().and_then(|x| x.to_str());
                let single = ext == Some("hsb1") && path.is_file();
                let is_sharded = ext == Some(sharded::SHARDED_EXT) && path.is_dir();
                if single || is_sharded {
                    if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                        if !out.iter().any(|v| v == stem) {
                            out.push(stem.to_string());
                        }
                    }
                }
            }
        }
        out.sort();
        out
    }

    /// Persist a compressed model's q/k/v set as `variant`, atomically,
    /// stamping a save-sequence number one past the highest currently on
    /// disk — the exact ordering key `prune` retains by. Returns the
    /// written path.
    pub fn save_model(&self, variant: &str, model: &CompressedModel) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating store dir {}", self.dir.display()))?;
        let path = self.variant_path(variant);
        let seq = self.max_save_seq().saturating_add(1);
        crate::compress::pipeline::save_reports_seq(&model.reports, &path, seq)?;
        Ok(path)
    }

    /// [`ModelStore::save_model`] in the sharded `HSB2` form: one shard
    /// per layer under `<variant>.hsb2/`, aligned payloads for zero-copy
    /// mmap serving, shards written before the manifest. Takes the same
    /// fresh save-sequence number a monolithic save would, so the two
    /// forms order interchangeably under `prune` and `open_variant`.
    pub fn save_model_sharded(&self, variant: &str, model: &CompressedModel) -> Result<PathBuf> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating store dir {}", self.dir.display()))?;
        let dir = self.sharded_path(variant);
        let seq = self.max_save_seq().saturating_add(1);
        let entries: Vec<sharded::ShardEntry> = model
            .reports
            .iter()
            .map(|r| sharded::ShardEntry {
                name: r.name.clone(),
                method: Some(r.method),
                rel_error: r.rel_error,
                matrix: &r.compressed,
            })
            .collect();
        sharded::write_sharded(&dir, &entries, seq)?;
        Ok(dir)
    }

    /// Save-sequence of one variant (0 for pre-v2 files; None if neither
    /// form is present or its header unreadable). A header-only peek —
    /// no full read or crc pass — so `save_model`/`prune` stay O(1) per
    /// variant. When both forms exist, the newer one's seq wins.
    pub fn variant_save_seq(&self, variant: &str) -> Option<u64> {
        let single = crate::store::reader::peek_save_seq(&self.variant_path(variant));
        let shard = sharded::peek_sharded_save_seq(&self.sharded_path(variant));
        match (single, shard) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        }
    }

    /// Highest save-sequence present in the store (0 when empty).
    fn max_save_seq(&self) -> u64 {
        self.variants()
            .iter()
            .filter_map(|v| self.variant_save_seq(v))
            .max()
            .unwrap_or(0)
    }

    /// Open one variant, resolving whichever on-disk form it takes. When
    /// both a monolithic file and a sharded directory exist under the
    /// same name, the one with the newer save-seq wins (tie → sharded,
    /// the zero-copy form).
    pub fn open_variant(&self, variant: &str) -> Result<VariantFile> {
        self.open_variant_with(variant, MmapMode::Auto)
    }

    /// [`ModelStore::open_variant`] with an explicit mmap policy.
    pub fn open_variant_with(&self, variant: &str, mode: MmapMode) -> Result<VariantFile> {
        let single_path = self.variant_path(variant);
        let sharded_dir = self.sharded_path(variant);
        let single_seq = crate::store::reader::peek_save_seq(&single_path);
        let sharded_seq = sharded::peek_sharded_save_seq(&sharded_dir);
        let use_sharded = match (single_seq, sharded_seq) {
            (Some(a), Some(b)) => b >= a,
            (None, Some(_)) => true,
            (Some(_), None) => false,
            // neither header peeks clean: fall through to whichever open
            // path exists so the error names the real problem
            (None, None) => sharded_dir.is_dir() || !single_path.exists(),
        };
        if use_sharded {
            Ok(VariantFile::Sharded(
                ShardedVariant::open_with(&sharded_dir, mode)
                    .with_context(|| format!("variant '{variant}'"))?,
            ))
        } else {
            Ok(VariantFile::Single(
                StoreFile::open_with(&single_path, mode)
                    .with_context(|| format!("variant '{variant}'"))?,
            ))
        }
    }

    /// Load a single projection matrix, keyed by `(layer, variant)`.
    pub fn load_matrix(
        &self,
        variant: &str,
        layer: usize,
        proj: Proj,
    ) -> Result<CompressedMatrix> {
        self.open_variant(variant)?.load(&entry_name(layer, proj))
    }

    /// Cold-start a full [`CompressedModel`] for `base` from disk — no
    /// recompression, layers decoded in parallel, zero-copy out of the
    /// page cache when the variant is sharded + mmap'd.
    pub fn load_model(&self, variant: &str, base: Arc<Transformer>) -> Result<CompressedModel> {
        let file = self.open_variant(variant)?;
        CompressedModel::from_store(base, &file)
            .with_context(|| format!("building model from variant '{variant}'"))
    }

    /// On-disk bytes of one variant, summed over both forms (0 if
    /// absent).
    pub fn variant_bytes(&self, variant: &str) -> u64 {
        let single = std::fs::metadata(self.variant_path(variant))
            .map(|m| m.len())
            .unwrap_or(0);
        let mut shard = 0u64;
        if let Ok(rd) = std::fs::read_dir(self.sharded_path(variant)) {
            for e in rd.flatten() {
                shard += e.metadata().map(|m| m.len()).unwrap_or(0);
            }
        }
        single + shard
    }

    /// Retention: keep the newest `keep_last_n` variants and delete the
    /// rest. "Newest" is the `HSB1` save-sequence number (exact —
    /// `save_model` stamps a fresh one per save), falling back to file
    /// mtime then name for pre-v2 files that all read as seq 0. The
    /// actively-served variant is never deleted, however old — it simply
    /// doesn't count against the retention budget. Returns the deleted
    /// variant names (sorted), so callers can log what a GC pass
    /// reclaimed.
    pub fn prune(&self, keep_last_n: usize, active: Option<&str>) -> Result<Vec<String>> {
        let mut entries: Vec<(u64, std::time::SystemTime, String)> = Vec::new();
        for name in self.variants() {
            let mtime = std::fs::metadata(self.variant_path(&name))
                .or_else(|_| std::fs::metadata(self.sharded_path(&name)))
                .with_context(|| format!("stat variant '{name}'"))?
                .modified()
                .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
            // unreadable/corrupt files sort oldest (seq 0) so GC can
            // reclaim them before healthy variants
            let seq = self.variant_save_seq(&name).unwrap_or(0);
            entries.push((seq, mtime, name));
        }
        // newest first; seq is exact, mtime/name only break pre-v2 ties
        entries.sort_by(|a, b| b.cmp(a));
        let mut deleted = Vec::new();
        let mut kept = 0usize;
        for (_, _, name) in entries {
            if active == Some(name.as_str()) {
                continue; // refuse to touch the serving variant
            }
            if kept < keep_last_n {
                kept += 1;
                continue;
            }
            // a name covers both forms; the sharded one goes manifest-
            // first, so a reader racing the delete sees a cleanly absent
            // variant rather than a manifest with missing shards
            let single = self.variant_path(&name);
            if single.exists() {
                std::fs::remove_file(&single)
                    .with_context(|| format!("deleting variant '{name}'"))?;
            }
            let dir = self.sharded_path(&name);
            if dir.is_dir() {
                sharded::remove_sharded_variant(&dir)
                    .with_context(|| format!("deleting sharded variant '{name}'"))?;
            }
            deleted.push(name);
        }
        deleted.sort();
        Ok(deleted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{CompressorConfig, Method};
    use crate::model::ModelConfig;

    fn tiny_base(seed: u64) -> Arc<Transformer> {
        Arc::new(Transformer::random(
            ModelConfig {
                vocab: 64,
                d_model: 32,
                n_heads: 4,
                n_layers: 2,
                d_ff: 64,
                seq_len: 16,
            },
            seed,
        ))
    }

    fn temp_store(tag: &str) -> ModelStore {
        let dir = std::env::temp_dir().join(format!("hisolo_test_model_store_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        ModelStore::open(dir)
    }

    #[test]
    fn save_then_load_matches_forward() {
        let base = tiny_base(3);
        let cm = CompressedModel::compress(
            base.clone(),
            Method::SHssRcm,
            CompressorConfig {
                rank: 8,
                sparsity: 0.15,
                depth: 1,
                min_leaf: 4,
                ..Default::default()
            },
        );
        let store = temp_store("roundtrip");
        let path = store.save_model("hss", &cm).unwrap();
        assert!(path.exists());
        assert!(store.has_variant("hss"));
        assert_eq!(store.variants(), vec!["hss".to_string()]);
        assert!(store.variant_bytes("hss") > 0);

        let loaded = store.load_model("hss", base.clone()).unwrap();
        assert_eq!(loaded.method, Method::SHssRcm);
        assert_eq!(loaded.qkv.len(), 2);
        assert_eq!(loaded.reports.len(), 6);
        // storage accounting must survive the trip exactly
        for (a, b) in cm.reports.iter().zip(&loaded.reports) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.params, b.params);
            assert_eq!(a.bytes, b.bytes);
            assert_eq!(
                a.compressed.storage_ratio(),
                b.compressed.storage_ratio(),
                "{}",
                a.name
            );
        }
        // forward pass agrees within fp16 storage tolerance
        let tokens: Vec<u32> = (0..16).map(|i| (i * 5) % 64).collect();
        let y0 = cm.forward(&tokens);
        let y1 = loaded.forward(&tokens);
        let mut max_diff = 0.0f32;
        for (a, b) in y0.data.iter().zip(&y1.data) {
            max_diff = max_diff.max((a - b).abs());
        }
        assert!(max_diff < 5e-2, "max logit diff {max_diff}");
    }

    #[test]
    fn keyed_matrix_lookup() {
        let base = tiny_base(4);
        let cm = CompressedModel::compress(
            base.clone(),
            Method::SSvd,
            CompressorConfig {
                rank: 4,
                sparsity: 0.1,
                ..Default::default()
            },
        );
        let store = temp_store("keyed");
        store.save_model("ssvd", &cm).unwrap();
        let m = store.load_matrix("ssvd", 1, Proj::K).unwrap();
        assert_eq!(m.n(), 32);
        assert!(store.load_matrix("ssvd", 7, Proj::K).is_err());
        assert!(store.load_matrix("absent", 0, Proj::Q).is_err());
    }

    #[test]
    fn prune_keeps_newest_and_never_deletes_active() {
        let base = tiny_base(6);
        let store = temp_store("prune");
        let cm = CompressedModel::compress(
            base.clone(),
            Method::SSvd,
            CompressorConfig {
                rank: 4,
                sparsity: 0.1,
                ..Default::default()
            },
        );
        // no sleeps needed: the save-sequence number orders same-tick
        // saves exactly, even on coarse-mtime filesystems
        for name in ["v0", "v1", "v2", "v3"] {
            store.save_model(name, &cm).unwrap();
        }
        for (i, name) in ["v0", "v1", "v2", "v3"].iter().enumerate() {
            assert_eq!(store.variant_save_seq(name), Some(i as u64 + 1), "{name}");
        }
        // keep the 2 newest; v0 is actively served and must survive
        let deleted = store.prune(2, Some("v0")).unwrap();
        assert_eq!(deleted, vec!["v1".to_string()]);
        assert_eq!(
            store.variants(),
            vec!["v0".to_string(), "v2".to_string(), "v3".to_string()]
        );
        // the survivors still load
        assert!(store.load_model("v0", base.clone()).is_ok());
        assert!(store.load_model("v3", base.clone()).is_ok());

        // prune to zero: only the active variant remains
        let deleted = store.prune(0, Some("v0")).unwrap();
        assert_eq!(deleted, vec!["v2".to_string(), "v3".to_string()]);
        assert_eq!(store.variants(), vec!["v0".to_string()]);

        // without an active variant, prune(0) empties the store
        assert_eq!(store.prune(0, None).unwrap(), vec!["v0".to_string()]);
        assert!(store.variants().is_empty());
    }

    #[test]
    fn resaving_a_variant_moves_it_to_newest() {
        let base = tiny_base(7);
        let store = temp_store("reseq");
        let cm = CompressedModel::compress(
            base.clone(),
            Method::SSvd,
            CompressorConfig {
                rank: 4,
                sparsity: 0.1,
                ..Default::default()
            },
        );
        for name in ["a", "b", "c"] {
            store.save_model(name, &cm).unwrap();
        }
        // re-save "a": it takes seq 4 and becomes the newest — mtime
        // granularity can no longer misorder it
        store.save_model("a", &cm).unwrap();
        assert_eq!(store.variant_save_seq("a"), Some(4));
        let deleted = store.prune(1, None).unwrap();
        assert_eq!(deleted, vec!["b".to_string(), "c".to_string()]);
        assert_eq!(store.variants(), vec!["a".to_string()]);
    }

    /// Satellite: a directory mixing v1 (no save-seq) and v2 files with
    /// **equal mtimes** — the worst case for coarse-granularity
    /// filesystems. `save_model`'s header-only seq peek must not reset
    /// the sequence, and `prune`'s (seq, mtime, name) ordering must break
    /// the all-zero-seq equal-mtime tie deterministically by name.
    #[test]
    fn prune_tie_break_and_seq_peek_on_mixed_v1_v2_equal_mtimes() {
        use std::time::{Duration, SystemTime};
        let base = tiny_base(8);
        let store = temp_store("v1v2mix");
        let cm = CompressedModel::compress(
            base.clone(),
            Method::SSvd,
            CompressorConfig {
                rank: 4,
                sparsity: 0.1,
                ..Default::default()
            },
        );
        store.save_model("v2-old", &cm).unwrap(); // seq 1
        store.save_model("v2-new", &cm).unwrap(); // seq 2

        let fixed = SystemTime::UNIX_EPOCH + Duration::from_secs(1_700_000_000);
        let write_v1_pair = |names: [&str; 2]| {
            let v2_bytes = std::fs::read(store.variant_path("v2-old")).unwrap();
            let v1 = crate::store::format::downgrade_image_to_v1(&v2_bytes);
            for name in names {
                let p = store.variant_path(name);
                std::fs::write(&p, &v1).unwrap();
                // pin both mtimes to the same instant: seq AND mtime tie
                std::fs::OpenOptions::new()
                    .write(true)
                    .open(&p)
                    .unwrap()
                    .set_modified(fixed)
                    .unwrap();
            }
        };
        write_v1_pair(["v1-a", "v1-b"]);
        assert_eq!(store.variant_save_seq("v1-a"), Some(0));
        assert_eq!(store.variant_save_seq("v1-b"), Some(0));

        // the header-only peek sees through the mix: the next save stamps
        // max(v2 seqs) + 1 — v1 files never reset the counter
        store.save_model("v2-newest", &cm).unwrap();
        assert_eq!(store.variant_save_seq("v2-newest"), Some(3));

        // seq is exact: every v2 file outranks every v1 file regardless of
        // mtime, so prune(3) reclaims exactly the two v1 files
        let deleted = store.prune(3, None).unwrap();
        assert_eq!(deleted, vec!["v1-a".to_string(), "v1-b".to_string()]);

        // with seq (0) and mtime tied exactly, the name breaks the tie:
        // "v1-b" sorts newer than "v1-a", so keeping 4 deletes only v1-a —
        // deterministically, however coarse the filesystem clock
        write_v1_pair(["v1-a", "v1-b"]);
        let deleted = store.prune(4, None).unwrap();
        assert_eq!(deleted, vec!["v1-a".to_string()]);
        assert!(store.has_variant("v1-b"));
        // the surviving v1 file still parses and loads
        assert!(store.open_variant("v1-b").is_ok());
    }

    #[test]
    fn multiple_variants_coexist() {
        let base = tiny_base(5);
        let store = temp_store("multi");
        for (name, m) in [("dense", Method::Dense), ("hss", Method::SHss)] {
            let cm = CompressedModel::compress(
                base.clone(),
                m,
                CompressorConfig {
                    rank: 8,
                    sparsity: 0.1,
                    depth: 1,
                    min_leaf: 4,
                    ..Default::default()
                },
            );
            store.save_model(name, &cm).unwrap();
        }
        assert_eq!(
            store.variants(),
            vec!["dense".to_string(), "hss".to_string()]
        );
        // the compressed variant is the smaller artifact on disk
        assert!(store.variant_bytes("hss") < store.variant_bytes("dense"));
    }
}
