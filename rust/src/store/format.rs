//! The `HSB1` on-disk grammar: section encoders/decoders shared by the
//! [`crate::store::StoreWriter`] / [`crate::store::StoreFile`] pair.
//!
//! Layout (little endian throughout):
//!
//! ```text
//! "HSB1" · u16 version · u16 flags · [v2+: u64 save_seq] · u32 entry_count
//! per entry:
//!   u32 name-len · name · u8 kind · u8 method · f64 rel_error
//!   u64 payload-len · payload
//! footer: u32 crc32 over every preceding byte
//! ```
//!
//! `save_seq` (version 2) is a monotonically increasing save-sequence
//! number stamped by `ModelStore::save_model`, so retention can order
//! variants exactly instead of by coarse-granularity file mtime. Version 1
//! files (no seq field) still parse and read back as seq = 0.
//!
//! Payload grammar per kind:
//!
//! ```text
//! matrix  := u32 rows · u32 cols · u8 dtype(0=f32,1=f16) · data
//! csr     := u32 rows · u32 cols · u32 nnz · indptr u32×(rows+1)
//!            · indices u32×nnz · u8 dtype · values
//! dense   := matrix(f32)
//! lowrank := matrix l(f16) · matrix r(f16) · u8 has_sparse · [csr]
//! node    := u8 0 · matrix d(f16)
//!          | u8 1 · u32 n · csr · u8 has_perm · [perm u32×n]
//!            · matrix u0 · matrix r0 · matrix u1 · matrix r1
//!            · node c0 · node c1
//! hss     := node
//! ```
//!
//! Values are fp16 (the paper's storage precision) except the dense
//! baseline, which stays f32 so `Dense` round-trips bit-exactly. The
//! per-entry `payload-len` lets the reader index every section without
//! decoding it — loading one matrix out of a many-entry file touches only
//! that entry's bytes.

use crate::compress::{CompressedMatrix, Method};
use crate::hss::HssNode;
use crate::linalg::{Matrix, Permutation};
use crate::sparse::Csr;
use crate::util::binio::{put_u32, ByteReader, DT_F16, DT_F32};
use crate::util::fp16;
use anyhow::{bail, Result};

pub const MAGIC: &[u8; 4] = b"HSB1";
/// Current write version (v2 added the `save_seq` header field).
pub const VERSION: u16 = 2;
/// Oldest version the reader still accepts (v1 files read as seq = 0).
pub const MIN_VERSION: u16 = 1;

/// Minimum fixed bytes before the first entry (the v1 header:
/// magic + version + flags + count; v2 headers carry 8 more for the
/// save-sequence number).
pub const HEADER_BYTES: usize = 4 + 2 + 2 + 4;
/// Trailing crc32.
pub const FOOTER_BYTES: usize = 4;

pub const KIND_DENSE: u8 = 0;
pub const KIND_LOWRANK: u8 = 1;
pub const KIND_HSS: u8 = 2;

const NODE_LEAF: u8 = 0;
const NODE_BRANCH: u8 = 1;

/// `method` byte for entries saved without provenance.
pub const METHOD_UNKNOWN: u8 = 255;

/// Deepest HSS tree the decoder will follow (a legitimate tree halves `n`
/// each level, so this is far beyond any real depth — it only bounds
/// recursion on corrupt input).
const MAX_NODE_DEPTH: usize = 64;

/// Per-entry metadata carried next to the payload.
#[derive(Clone, Debug)]
pub struct EntryMeta {
    pub name: String,
    /// `KIND_DENSE` / `KIND_LOWRANK` / `KIND_HSS`
    pub kind: u8,
    /// compression method that produced the matrix, when known
    pub method: Option<Method>,
    /// reconstruction error recorded at compression time (NaN if unknown)
    pub rel_error: f64,
}

impl EntryMeta {
    /// The method, falling back to a kind-appropriate default for entries
    /// saved without provenance.
    pub fn method_or_default(&self) -> Method {
        self.method.unwrap_or(match self.kind {
            KIND_DENSE => Method::Dense,
            KIND_LOWRANK => Method::Svd,
            _ => Method::SHss,
        })
    }
}

pub fn kind_of(m: &CompressedMatrix) -> u8 {
    match m {
        CompressedMatrix::Dense { .. } => KIND_DENSE,
        CompressedMatrix::LowRank { .. } => KIND_LOWRANK,
        CompressedMatrix::Hss { .. } => KIND_HSS,
    }
}

/// Stable one-byte on-disk code for a [`Method`]. Pinned explicitly (like
/// the `KIND_*` constants) so reordering `Method::ALL` can never silently
/// remap the provenance of existing store files.
pub fn method_code(m: Method) -> u8 {
    match m {
        Method::Dense => 0,
        Method::Svd => 1,
        Method::Rsvd => 2,
        Method::SSvd => 3,
        Method::SRsvd => 4,
        Method::SHss => 5,
        Method::SHssRcm => 6,
    }
}

pub fn method_from_code(c: u8) -> Option<Method> {
    Some(match c {
        0 => Method::Dense,
        1 => Method::Svd,
        2 => Method::Rsvd,
        3 => Method::SSvd,
        4 => Method::SRsvd,
        5 => Method::SHss,
        6 => Method::SHssRcm,
        _ => return None,
    })
}

// --------------------------------------------------------------- encoding

/// Value runs in the aligned (`HSB2` shard) grammar land on this boundary
/// of the file, so an mmap'd shard can hand `[f32]`/`[u16]` views straight
/// into the mapping (8 covers both element alignments with headroom for
/// wider loads).
pub const VALUE_ALIGN: usize = 8;

/// Encoder context: `base` is the absolute file offset `out[0]` lands at,
/// which is what lets the aligned grammar compute each value run's pad
/// against the *file*, not the payload.
struct Enc<'a> {
    out: &'a mut Vec<u8>,
    base: usize,
    aligned: bool,
}

impl Enc<'_> {
    /// In the aligned grammar, emit the pad-count byte plus that many
    /// zeros so the next byte sits on a `VALUE_ALIGN` file boundary; the
    /// unaligned (`HSB1`) grammar emits nothing.
    fn pad_values(&mut self) {
        if !self.aligned {
            return;
        }
        let pos = self.base + self.out.len() + 1; // first byte after the pad count
        let pad = (VALUE_ALIGN - pos % VALUE_ALIGN) % VALUE_ALIGN;
        self.out.push(pad as u8);
        let new_len = self.out.len() + pad;
        self.out.resize(new_len, 0);
    }

    fn put_matrix(&mut self, m: &Matrix, dtype: u8) {
        use crate::linalg::WeightBuf;
        put_u32(self.out, m.rows as u32);
        put_u32(self.out, m.cols as u32);
        self.out.push(dtype);
        self.pad_values();
        match (dtype, &m.data) {
            (DT_F32, WeightBuf::F32(v)) => {
                for x in v.as_slice() {
                    self.out.extend_from_slice(&x.to_le_bytes());
                }
            }
            (DT_F32, WeightBuf::F16(bits)) => {
                for &h in bits.as_slice() {
                    self.out.extend_from_slice(&fp16::f16_to_f32(h).to_le_bytes());
                }
            }
            (_, WeightBuf::F32(v)) => self.out.extend_from_slice(&fp16::encode_f16_le(v)),
            (_, WeightBuf::F16(bits)) => {
                self.out.extend_from_slice(&fp16::encode_f16_bits_le(bits))
            }
        }
    }

    fn put_csr(&mut self, s: &Csr) {
        use crate::linalg::WeightBuf;
        put_u32(self.out, s.rows as u32);
        put_u32(self.out, s.cols as u32);
        put_u32(self.out, s.nnz() as u32);
        for &p in &s.indptr {
            put_u32(self.out, p);
        }
        for &j in &s.indices {
            put_u32(self.out, j);
        }
        self.out.push(DT_F16);
        self.pad_values();
        match &s.data {
            WeightBuf::F32(v) => self.out.extend_from_slice(&fp16::encode_f16_le(v)),
            WeightBuf::F16(bits) => self.out.extend_from_slice(&fp16::encode_f16_bits_le(bits)),
        }
    }

    fn put_node(&mut self, node: &HssNode) {
        match node {
            HssNode::Leaf { d } => {
                self.out.push(NODE_LEAF);
                self.put_matrix(d, DT_F16);
            }
            HssNode::Branch {
                n,
                sparse,
                perm,
                u0,
                r0,
                u1,
                r1,
                c0,
                c1,
            } => {
                self.out.push(NODE_BRANCH);
                put_u32(self.out, *n as u32);
                self.put_csr(sparse);
                if perm.is_identity() {
                    self.out.push(0);
                } else {
                    self.out.push(1);
                    for &i in perm.indices() {
                        put_u32(self.out, i as u32);
                    }
                }
                self.put_matrix(u0, DT_F16);
                self.put_matrix(r0, DT_F16);
                self.put_matrix(u1, DT_F16);
                self.put_matrix(r1, DT_F16);
                self.put_node(c0);
                self.put_node(c1);
            }
        }
    }

    fn put_payload(&mut self, m: &CompressedMatrix) {
        match m {
            CompressedMatrix::Dense { w } => self.put_matrix(w, DT_F32),
            CompressedMatrix::LowRank { l, r, sparse } => {
                self.put_matrix(l, DT_F16);
                self.put_matrix(r, DT_F16);
                match sparse {
                    Some(s) => {
                        self.out.push(1);
                        self.put_csr(s);
                    }
                    None => self.out.push(0),
                }
            }
            CompressedMatrix::Hss { tree } => self.put_node(tree),
        }
    }
}

/// Append a matrix section; `dtype` is `DT_F32` or `DT_F16`. The source
/// matrix may be resident at either dtype: f16-resident bits are written
/// verbatim for a `DT_F16` section (a lossless byte copy — re-saving a
/// natively-loaded variant never requantizes), and widened exactly for
/// `DT_F32`.
pub fn put_matrix(out: &mut Vec<u8>, m: &Matrix, dtype: u8) {
    Enc {
        out,
        base: 0,
        aligned: false,
    }
    .put_matrix(m, dtype);
}

/// Append a CSR section (values fp16; f16-resident values are written
/// verbatim, f32-resident ones are quantized).
pub fn put_csr(out: &mut Vec<u8>, s: &Csr) {
    Enc {
        out,
        base: 0,
        aligned: false,
    }
    .put_csr(s);
}

/// Serialize one [`CompressedMatrix`] payload (everything after the entry
/// header) in the unaligned `HSB1` grammar.
pub fn encode_payload(m: &CompressedMatrix) -> Vec<u8> {
    let mut out = Vec::with_capacity(m.bytes() + 64);
    Enc {
        out: &mut out,
        base: 0,
        aligned: false,
    }
    .put_payload(m);
    out
}

/// Serialize one payload in the aligned `HSB2` grammar: `file_base` is the
/// absolute file offset the payload's first byte will be written at, and
/// every value run is preceded by a pad byte + zeros bringing it to a
/// [`VALUE_ALIGN`] boundary of the file — the property that makes the
/// mmap'd reader's zero-copy borrows land aligned.
pub fn encode_payload_aligned(m: &CompressedMatrix, file_base: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(m.bytes() + 64);
    Enc {
        out: &mut out,
        base: file_base,
        aligned: true,
    }
    .put_payload(m);
    out
}

// --------------------------------------------------------------- decoding

/// The mapping a payload is being decoded out of: the mmap plus the
/// absolute byte offset of the payload's first byte within it. Present
/// only on the zero-copy path; `None` decodes by copying (the buffered
/// reader, or `HISOLO_MMAP=off`).
pub type PayloadMap = Option<(std::sync::Arc<crate::util::mmap::Mmap>, usize)>;

/// Decoder context: the payload cursor plus everything the zero-copy path
/// needs — whether the aligned (`HSB2`) grammar's pad bytes are present,
/// and the mapping backing the payload (if any) so value runs can be
/// handed out as [`crate::linalg::Storage::Mapped`] borrows instead of
/// copied. Borrowing is strictly opportunistic: any failed precondition
/// (no map, misalignment in an unaligned `HSB1` file, big-endian host)
/// falls back to the owned copy, decoding the same bytes to the same
/// values.
struct Dec<'a> {
    r: ByteReader<'a>,
    native: bool,
    aligned: bool,
    map: PayloadMap,
}

impl<'a> Dec<'a> {
    /// Consume the aligned grammar's pad-count byte + zeros (no-op for the
    /// unaligned grammar).
    fn skip_pad(&mut self) -> Result<()> {
        if self.aligned {
            let pad = self.r.u8()? as usize;
            if pad >= VALUE_ALIGN {
                bail!("value-run pad {pad} out of range");
            }
            self.r.take(pad)?;
        }
        Ok(())
    }

    /// Try to borrow `count` elements of `T` starting at the cursor from
    /// the backing mapping.
    fn try_borrow<T: crate::linalg::weightbuf::MapElem>(
        &self,
        count: usize,
    ) -> Option<crate::linalg::MapRange<T>> {
        let (map, base) = self.map.as_ref()?;
        crate::linalg::MapRange::new(map.clone(), base + self.r.pos(), count)
    }

    /// An f32 value run: a zero-copy borrow on the native mapped path,
    /// an owned decode otherwise.
    fn values_f32(&mut self, count: usize) -> Result<crate::linalg::Storage<f32>> {
        self.skip_pad()?;
        let nbytes = count
            .checked_mul(4)
            .ok_or_else(|| anyhow::anyhow!("value run too large"))?;
        let borrowed = if self.native { self.try_borrow::<f32>(count) } else { None };
        let bytes = self.r.take(nbytes)?;
        if let Some(mr) = borrowed {
            return Ok(crate::linalg::Storage::Mapped(mr));
        }
        Ok(crate::linalg::Storage::Owned(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        ))
    }

    /// An f16 value run kept at its on-disk dtype (native load).
    fn values_f16_native(&mut self, count: usize) -> Result<crate::linalg::Storage<u16>> {
        self.skip_pad()?;
        let nbytes = count
            .checked_mul(2)
            .ok_or_else(|| anyhow::anyhow!("value run too large"))?;
        let borrowed = self.try_borrow::<u16>(count);
        let bytes = self.r.take(nbytes)?;
        if let Some(mr) = borrowed {
            return Ok(crate::linalg::Storage::Mapped(mr));
        }
        Ok(crate::linalg::Storage::Owned(fp16::decode_f16_bits_le(bytes)))
    }

    /// An f16 value run widened to f32 (the back-compatible load; always
    /// owned — the widened values don't exist in the file).
    fn values_f16_widened(&mut self, count: usize) -> Result<Vec<f32>> {
        self.skip_pad()?;
        let nbytes = count
            .checked_mul(2)
            .ok_or_else(|| anyhow::anyhow!("value run too large"))?;
        Ok(fp16::decode_f16_le(self.r.take(nbytes)?))
    }

    fn get_matrix(&mut self) -> Result<Matrix> {
        use crate::linalg::WeightBuf;
        let rows = self.r.u32()? as usize;
        let cols = self.r.u32()? as usize;
        let dtype = self.r.u8()?;
        let count = rows
            .checked_mul(cols)
            .ok_or_else(|| anyhow::anyhow!("matrix {rows}x{cols} overflows"))?;
        let data = match dtype {
            DT_F32 => WeightBuf::F32(self.values_f32(count)?),
            DT_F16 if self.native => WeightBuf::F16(self.values_f16_native(count)?),
            DT_F16 => WeightBuf::F32(self.values_f16_widened(count)?.into()),
            d => bail!("matrix: unknown dtype code {d}"),
        };
        Ok(Matrix { rows, cols, data })
    }

    fn get_csr(&mut self) -> Result<Csr> {
        use crate::linalg::WeightBuf;
        let rows = self.r.u32()? as usize;
        let cols = self.r.u32()? as usize;
        let nnz = self.r.u32()? as usize;
        let indptr_len = rows
            .checked_add(1)
            .ok_or_else(|| anyhow::anyhow!("csr rows overflow"))?;
        let indptr: Vec<u32> = self
            .r
            .take(indptr_len.checked_mul(4).ok_or_else(|| anyhow::anyhow!("csr too large"))?)?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let indices: Vec<u32> = self
            .r
            .take(nnz.checked_mul(4).ok_or_else(|| anyhow::anyhow!("csr too large"))?)?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let dtype = self.r.u8()?;
        let data = match dtype {
            DT_F16 if self.native => WeightBuf::F16(self.values_f16_native(nnz)?),
            DT_F16 => WeightBuf::F32(self.values_f16_widened(nnz)?.into()),
            DT_F32 => WeightBuf::F32(self.values_f32(nnz)?),
            d => bail!("csr: unknown dtype code {d}"),
        };
        let csr = Csr {
            rows,
            cols,
            indptr,
            indices,
            data,
        };
        csr.validate().map_err(anyhow::Error::msg)?;
        Ok(csr)
    }

    fn get_perm(&mut self, n: usize) -> Result<Permutation> {
        let raw = self
            .r
            .take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("perm too large"))?)?;
        let mut p = Vec::with_capacity(n);
        let mut seen = vec![false; n];
        for c in raw.chunks_exact(4) {
            let i = u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as usize;
            if i >= n || seen[i] {
                bail!("permutation entry {i} invalid for n={n}");
            }
            seen[i] = true;
            p.push(i);
        }
        Ok(Permutation::from_vec(p))
    }

    fn get_node(&mut self, depth: usize) -> Result<HssNode> {
        if depth > MAX_NODE_DEPTH {
            bail!("hss tree deeper than {MAX_NODE_DEPTH} (corrupt file)");
        }
        match self.r.u8()? {
            NODE_LEAF => Ok(HssNode::Leaf {
                d: self.get_matrix()?,
            }),
            NODE_BRANCH => {
                let n = self.r.u32()? as usize;
                let sparse = self.get_csr()?;
                let perm = match self.r.u8()? {
                    0 => Permutation::identity(n),
                    1 => self.get_perm(n)?,
                    p => bail!("unknown permutation tag {p}"),
                };
                let u0 = self.get_matrix()?;
                let r0 = self.get_matrix()?;
                let u1 = self.get_matrix()?;
                let r1 = self.get_matrix()?;
                let c0 = Box::new(self.get_node(depth + 1)?);
                let c1 = Box::new(self.get_node(depth + 1)?);
                Ok(HssNode::Branch {
                    n,
                    sparse,
                    perm,
                    u0,
                    r0,
                    u1,
                    r1,
                    c0,
                    c1,
                })
            }
            t => bail!("unknown hss node tag {t}"),
        }
    }

    fn decode(&mut self, kind: u8) -> Result<CompressedMatrix> {
        let m = match kind {
            KIND_DENSE => {
                let w = self.get_matrix()?;
                if w.rows != w.cols {
                    bail!("dense entry not square: {}x{}", w.rows, w.cols);
                }
                CompressedMatrix::Dense { w }
            }
            KIND_LOWRANK => {
                let l = self.get_matrix()?;
                let rm = self.get_matrix()?;
                if l.cols != rm.rows {
                    bail!("lowrank: l is {}x{} but r is {}x{}", l.rows, l.cols, rm.rows, rm.cols);
                }
                // the runtime represents square matrices (n() reads l.rows and
                // matvec feeds length-n inputs to r): enforce it here so a
                // crc-valid but malformed entry can't panic a worker thread
                if l.rows != rm.cols {
                    bail!(
                        "lowrank entry not square: l·r is {}x{}",
                        l.rows,
                        rm.cols
                    );
                }
                let sparse = match self.r.u8()? {
                    0 => None,
                    1 => {
                        let s = self.get_csr()?;
                        if s.rows != l.rows || s.cols != rm.cols {
                            bail!(
                                "lowrank: spike matrix {}x{} vs factors {}x{}",
                                s.rows,
                                s.cols,
                                l.rows,
                                rm.cols
                            );
                        }
                        Some(s)
                    }
                    t => bail!("unknown sparse tag {t}"),
                };
                CompressedMatrix::LowRank { l, r: rm, sparse }
            }
            KIND_HSS => {
                let tree = self.get_node(0)?;
                tree.validate().map_err(anyhow::Error::msg)?;
                CompressedMatrix::Hss { tree }
            }
            k => bail!("unknown entry kind {k}"),
        };
        if self.r.remaining() != 0 {
            bail!("{} trailing bytes after payload", self.r.remaining());
        }
        Ok(m)
    }
}

/// Parse a matrix section, widening fp16 payloads to f32 (the
/// back-compatible load; [`get_matrix_native`] keeps the on-disk dtype).
pub fn get_matrix(r: &mut ByteReader) -> Result<Matrix> {
    get_matrix_standalone(r, false)
}

/// Parse a matrix section keeping the on-disk dtype: a `DT_F16` payload
/// becomes an f16-resident matrix — no f32 buffer is ever allocated.
pub fn get_matrix_native(r: &mut ByteReader) -> Result<Matrix> {
    get_matrix_standalone(r, true)
}

fn get_matrix_standalone(r: &mut ByteReader, native: bool) -> Result<Matrix> {
    // reconstruct a Dec over the reader's remaining bytes, then advance
    // the caller's cursor by what was consumed
    let rest = r.take(r.remaining())?;
    let mut d = Dec {
        r: ByteReader::new(rest),
        native,
        aligned: false,
        map: None,
    };
    let m = d.get_matrix();
    // rewind the over-take: hand back the unconsumed suffix
    *r = ByteReader::new(&rest[d.r.pos()..]);
    m
}

/// Parse and structurally validate a CSR section (widening load; see
/// [`get_matrix`] vs [`get_matrix_native`]).
pub fn get_csr(r: &mut ByteReader) -> Result<Csr> {
    let rest = r.take(r.remaining())?;
    let mut d = Dec {
        r: ByteReader::new(rest),
        native: false,
        aligned: false,
        map: None,
    };
    let c = d.get_csr();
    *r = ByteReader::new(&rest[d.r.pos()..]);
    c
}

/// Deserialize one payload back into a [`CompressedMatrix`], widening
/// fp16 sections to f32 (the back-compatible load).
pub fn decode_payload(kind: u8, payload: &[u8]) -> Result<CompressedMatrix> {
    decode_payload_ext(kind, payload, false, false, None)
}

/// Deserialize one payload keeping every section's on-disk dtype: fp16
/// factors come back f16-resident, so the decoded matrix occupies the
/// bytes the format pays for — the serving load path.
pub fn decode_payload_native(kind: u8, payload: &[u8]) -> Result<CompressedMatrix> {
    decode_payload_ext(kind, payload, true, false, None)
}

/// The full-control decode: `native` keeps on-disk dtypes, `aligned`
/// selects the `HSB2` pad-byte grammar, and `map` (mmap + absolute offset
/// of `payload[0]`) enables zero-copy value-run borrows. `payload` must be
/// the same bytes the mapping holds at that offset.
pub fn decode_payload_ext(
    kind: u8,
    payload: &[u8],
    native: bool,
    aligned: bool,
    map: PayloadMap,
) -> Result<CompressedMatrix> {
    Dec {
        r: ByteReader::new(payload),
        native,
        aligned,
        map,
    }
    .decode(kind)
}

/// Test-only: rewrite a v2 `HSB1` image as version 1 (drop the save-seq
/// header field, recompute the crc) — lets tests exercise pre-v2 files
/// without keeping binary fixtures around.
#[cfg(test)]
pub(crate) fn downgrade_image_to_v1(v2: &[u8]) -> Vec<u8> {
    let mut v1 = Vec::with_capacity(v2.len().saturating_sub(8));
    v1.extend_from_slice(&v2[..4]); // magic
    v1.extend_from_slice(&1u16.to_le_bytes()); // version 1
    v1.extend_from_slice(&v2[6..8]); // flags
    v1.extend_from_slice(&v2[16..v2.len() - 4]); // count + entries (skip the u64 seq)
    let crc = crate::util::binio::crc32(&v1);
    v1.extend_from_slice(&crc.to_le_bytes());
    v1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, CompressorConfig};
    use crate::data::synthetic;
    use crate::util::proptest::slices_close;
    use crate::util::rng::Rng;

    fn compressed(n: usize, m: Method, seed: u64) -> CompressedMatrix {
        let w = synthetic::trained_like(n, seed);
        Compressor::new(CompressorConfig {
            rank: 8,
            sparsity: 0.15,
            depth: 2,
            min_leaf: 8,
            ..Default::default()
        })
        .compress(&w, m)
    }

    #[test]
    fn payload_roundtrip_preserves_structure_and_matvec() {
        for m in [Method::Dense, Method::SSvd, Method::SHssRcm] {
            let c = compressed(48, m, 3);
            let payload = encode_payload(&c);
            let back = decode_payload(kind_of(&c), &payload).unwrap();
            assert_eq!(back.n(), c.n(), "{m:?}");
            assert_eq!(back.params(), c.params(), "{m:?}");
            assert_eq!(back.bytes(), c.bytes(), "{m:?}");
            let mut rng = Rng::new(9);
            let x: Vec<f32> = (0..48).map(|_| rng.gaussian_f32()).collect();
            // fp16 quantization of the stored factors bounds the drift
            slices_close(&back.matvec(&x), &c.matvec(&x), 2e-2, 2e-2, m.name()).unwrap();
        }
    }

    /// The f16-native load: factors stay at the on-disk dtype (half the
    /// resident bytes), numerics are bit-identical to the widening load,
    /// and re-encoding is a lossless byte copy (no requantization drift).
    #[test]
    fn native_decode_keeps_dtype_and_matches_widened_load() {
        use crate::linalg::Dtype;
        for m in [Method::SSvd, Method::SHssRcm] {
            let c = compressed(48, m, 8);
            let payload = encode_payload(&c);
            let wide = decode_payload(kind_of(&c), &payload).unwrap();
            let native = decode_payload_native(kind_of(&c), &payload).unwrap();
            assert_eq!(native.weights_dtype(), Dtype::F16, "{m:?}");
            assert_eq!(wide.weights_dtype(), Dtype::F32, "{m:?}");
            assert_eq!(
                native.resident_weight_bytes() * 2,
                wide.resident_weight_bytes(),
                "{m:?}"
            );
            // format accounting is residency-independent
            assert_eq!(native.params(), wide.params(), "{m:?}");
            assert_eq!(native.bytes(), wide.bytes(), "{m:?}");
            // widened and native loads compute bit-identical matvecs
            let mut rng = Rng::new(21);
            let x: Vec<f32> = (0..48).map(|_| rng.gaussian_f32()).collect();
            assert_eq!(native.matvec(&x), wide.matvec(&x), "{m:?}");
            // re-saving a natively-loaded entry copies the f16 bits verbatim
            assert_eq!(encode_payload(&native), payload, "{m:?}");
        }
        // the dense baseline stays f32 either way (bit-exact round-trips)
        let d = compressed(32, Method::Dense, 9);
        let payload = encode_payload(&d);
        let native = decode_payload_native(KIND_DENSE, &payload).unwrap();
        assert_eq!(native.weights_dtype(), Dtype::F32);
        assert_eq!(encode_payload(&native), payload);
    }

    #[test]
    fn dense_payload_bit_exact() {
        let c = compressed(32, Method::Dense, 4);
        let back = decode_payload(KIND_DENSE, &encode_payload(&c)).unwrap();
        let (CompressedMatrix::Dense { w: a }, CompressedMatrix::Dense { w: b }) = (&c, &back)
        else {
            panic!("wrong variants");
        };
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn truncated_payload_rejected() {
        let c = compressed(32, Method::SHssRcm, 5);
        let payload = encode_payload(&c);
        for cut in [1, payload.len() / 2, payload.len() - 1] {
            assert!(
                decode_payload(KIND_HSS, &payload[..cut]).is_err(),
                "cut at {cut} accepted"
            );
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let c = compressed(32, Method::SSvd, 6);
        let mut payload = encode_payload(&c);
        payload.push(0);
        assert!(decode_payload(KIND_LOWRANK, &payload).is_err());
    }

    #[test]
    fn corrupt_csr_indices_rejected_not_panicking() {
        let c = compressed(32, Method::SSvd, 7);
        let CompressedMatrix::LowRank { l, r, sparse: Some(s) } = &c else {
            panic!("ssvd should carry a spike matrix");
        };
        let mut bad = s.clone();
        if !bad.indices.is_empty() {
            bad.indices[0] = 10_000; // far out of range
        }
        let corrupt = CompressedMatrix::LowRank {
            l: l.clone(),
            r: r.clone(),
            sparse: Some(bad),
        };
        let payload = encode_payload(&corrupt);
        let e = decode_payload(KIND_LOWRANK, &payload).unwrap_err();
        assert!(format!("{e:#}").contains("csr"), "{e:#}");
    }

    #[test]
    fn non_square_entries_rejected() {
        // a crc-valid but non-square entry must fail decode, not panic the
        // worker later in matvec
        let lr = CompressedMatrix::LowRank {
            l: crate::linalg::Matrix::zeros(4, 2),
            r: crate::linalg::Matrix::zeros(2, 3),
            sparse: None,
        };
        let e = decode_payload(KIND_LOWRANK, &encode_payload(&lr)).unwrap_err();
        assert!(format!("{e:#}").contains("square"), "{e:#}");

        let d = CompressedMatrix::Dense {
            w: crate::linalg::Matrix::zeros(4, 3),
        };
        let e = decode_payload(KIND_DENSE, &encode_payload(&d)).unwrap_err();
        assert!(format!("{e:#}").contains("square"), "{e:#}");
    }

    #[test]
    fn method_codes_roundtrip() {
        for m in Method::ALL {
            assert_eq!(method_from_code(method_code(m)), Some(m));
        }
        assert_eq!(method_from_code(METHOD_UNKNOWN), None);
    }
}
