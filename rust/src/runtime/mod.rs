//! PJRT runtime: loads the AOT HLO-text artifacts (L1 Pallas kernels inside
//! L2 JAX graphs) and executes them from the Rust request path.
//!
//! Python runs only at `make artifacts` time; this module plus the weight
//! files is everything serving needs.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactDir, ExeSpec, InputSpec};
pub use client::{LoadedModel, Runtime};
