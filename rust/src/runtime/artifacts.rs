//! Artifact manifest parsing (`artifacts/manifest.json` from aot.py):
//! which executables exist, their operand order/shapes, batch sizes.

use crate::model::ModelConfig;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InputSpec {
    pub name: String,
    pub dtype: String, // "f32" | "i32" | "f16"
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ExeSpec {
    pub name: String,
    pub file: PathBuf,
    pub batch: usize,
    pub inputs: Vec<InputSpec>,
    pub output_shape: Vec<usize>,
}

pub struct ArtifactDir {
    pub dir: PathBuf,
    pub model_config: ModelConfig,
    pub executables: Vec<ExeSpec>,
    pub hss_config: Option<Json>,
}

impl ArtifactDir {
    pub fn load(dir: &Path) -> Result<ArtifactDir> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let model_config = ModelConfig::from_manifest(&j)?;

        let exes = j
            .get("executables")
            .ok_or_else(|| anyhow!("manifest missing executables"))?;
        let Json::Obj(map) = exes else {
            bail!("executables is not an object");
        };
        let mut executables = Vec::new();
        for (name, e) in map {
            let file = e
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| anyhow!("{name}: missing file"))?;
            let batch = e
                .get("batch")
                .and_then(|b| b.as_usize())
                .ok_or_else(|| anyhow!("{name}: missing batch"))?;
            let inputs = e
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(parse_input)
                .collect::<Result<Vec<_>>>()?;
            let output_shape = e
                .get("output")
                .and_then(|o| o.get("shape"))
                .and_then(|s| s.as_arr())
                .ok_or_else(|| anyhow!("{name}: missing output shape"))?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            executables.push(ExeSpec {
                name: name.clone(),
                file: dir.join(file),
                batch,
                inputs,
                output_shape,
            });
        }
        executables.sort_by(|a, b| a.name.cmp(&b.name));
        Ok(ArtifactDir {
            dir: dir.to_path_buf(),
            model_config,
            executables,
            hss_config: j.get("hss_config").cloned(),
        })
    }

    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.executables
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| {
                anyhow!(
                    "executable '{name}' not in manifest (have: {})",
                    self.executables
                        .iter()
                        .map(|e| e.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// Default artifact location: `$HISOLO_ARTIFACTS` or `./artifacts`.
    pub fn default_path() -> PathBuf {
        std::env::var("HISOLO_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

fn parse_input(j: &Json) -> Result<InputSpec> {
    Ok(InputSpec {
        name: j
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| anyhow!("input missing name"))?
            .to_string(),
        dtype: j
            .get("dtype")
            .and_then(|d| d.as_str())
            .ok_or_else(|| anyhow!("input missing dtype"))?
            .to_string(),
        shape: j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| anyhow!("input missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("hisolo_test_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "model_config": {"vocab":256,"d_model":64,"n_heads":4,"n_layers":2,"d_ff":128,"seq_len":32},
              "executables": {
                "model_dense_b1": {"file":"model_dense_b1.hlo.txt","batch":1,
                  "inputs":[{"name":"tokens","dtype":"i32","shape":[1,32]}],
                  "output":{"dtype":"f32","shape":[1,32,256]}}
              }
            }"#,
        )
        .unwrap();
        let a = ArtifactDir::load(&dir).unwrap();
        assert_eq!(a.model_config.d_model, 64);
        let e = a.exe("model_dense_b1").unwrap();
        assert_eq!(e.batch, 1);
        assert_eq!(e.inputs[0].shape, vec![1, 32]);
        assert_eq!(e.output_shape, vec![1, 32, 256]);
        assert!(a.exe("nope").is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let a = ArtifactDir::load(&dir).unwrap();
        assert_eq!(a.executables.len(), 4);
        let e = a.exe("model_hss_b8").unwrap();
        assert_eq!(e.batch, 8);
        assert_eq!(e.inputs[0].name, "tokens");
        assert!(e.inputs.len() > 50); // params + hss operands
    }
}
