//! PJRT client wrapper: HLO text → compiled executable → scoring calls.
//!
//! Weights are uploaded to device buffers once at load; each request only
//! transfers its token batch (see DESIGN.md §6). HLO **text** is the
//! interchange format because xla_extension 0.5.1 rejects jax≥0.5's
//! serialized protos (64-bit instruction ids).
//!
//! The `xla` bindings are not available in the offline build environment, so
//! the real client is gated behind the `pjrt` cargo feature. Without it
//! (the default), [`Runtime`] / [`LoadedModel`] keep the same API but
//! [`Runtime::cpu`] returns an error — the native forward path and the
//! store-backed serving path ([`crate::store`]) are unaffected.

#[cfg(feature = "pjrt")]
pub use pjrt_enabled::{LoadedModel, Runtime};
#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::{LoadedModel, Runtime};

#[cfg(feature = "pjrt")]
mod pjrt_enabled {
    use crate::linalg::Matrix;
    use crate::model::weights::{Dtype, WeightFile};
    use crate::runtime::artifacts::{ArtifactDir, ExeSpec};
    use anyhow::{anyhow, bail, Context, Result};

    /// Shared PJRT client (CPU plugin).
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().map_err(wrap)?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile one manifest executable and bind its weight operands from
        /// the given weight files (searched in order).
        pub fn load_model(
            &self,
            artifacts: &ArtifactDir,
            exe_name: &str,
            weight_files: &[&WeightFile],
        ) -> Result<LoadedModel> {
            let spec = artifacts.exe(exe_name)?.clone();
            let path = spec
                .file
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(wrap)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(wrap)?;

            // upload every non-token operand once
            let mut weight_buffers = Vec::with_capacity(spec.inputs.len().saturating_sub(1));
            for input in spec.inputs.iter().skip(1) {
                let buf = self.upload_named(input, weight_files)?;
                weight_buffers.push(buf);
            }
            Ok(LoadedModel {
                exe,
                spec,
                weight_buffers,
                client: self.client.clone(),
            })
        }

        fn upload_named(
            &self,
            input: &crate::runtime::artifacts::InputSpec,
            weight_files: &[&WeightFile],
        ) -> Result<xla::PjRtBuffer> {
            let tensor = weight_files
                .iter()
                .find_map(|wf| wf.get(&input.name).ok())
                .ok_or_else(|| anyhow!("operand '{}' not found in weight files", input.name))?;
            let expect: usize = if input.shape.is_empty() {
                1
            } else {
                input.shape.iter().product()
            };
            match (input.dtype.as_str(), tensor.dtype) {
                ("f32", Dtype::F32) | ("f32", Dtype::F16) => {
                    if tensor.f32_data.len() != expect {
                        bail!(
                            "operand '{}': manifest wants {expect} f32s, file has {}",
                            input.name,
                            tensor.f32_data.len()
                        );
                    }
                    self.client
                        .buffer_from_host_buffer::<f32>(&tensor.f32_data, &input.shape, None)
                        .map_err(wrap)
                }
                ("i32", Dtype::I32) => {
                    if tensor.i32_data.len() != expect {
                        bail!(
                            "operand '{}': manifest wants {expect} i32s, file has {}",
                            input.name,
                            tensor.i32_data.len()
                        );
                    }
                    self.client
                        .buffer_from_host_buffer::<i32>(&tensor.i32_data, &input.shape, None)
                        .map_err(wrap)
                }
                (want, have) => bail!(
                    "operand '{}': dtype mismatch manifest={want} file={have:?}",
                    input.name
                ),
            }
        }
    }

    /// A compiled executable with device-resident weights.
    pub struct LoadedModel {
        exe: xla::PjRtLoadedExecutable,
        pub spec: ExeSpec,
        weight_buffers: Vec<xla::PjRtBuffer>,
        client: xla::PjRtClient,
    }

    impl LoadedModel {
        pub fn batch(&self) -> usize {
            self.spec.batch
        }

        pub fn seq_len(&self) -> usize {
            self.spec.inputs[0].shape[1]
        }

        /// Score a batch of token windows: returns per-sequence logits
        /// [t, vocab]. Fewer than `batch` windows are padded with repeats of
        /// the last window (results for padding are discarded).
        pub fn score(&self, windows: &[Vec<u32>]) -> Result<Vec<Matrix>> {
            let b = self.spec.batch;
            let t = self.seq_len();
            if windows.is_empty() || windows.len() > b {
                bail!("score wants 1..={b} windows, got {}", windows.len());
            }
            for w in windows {
                if w.len() != t {
                    bail!("window length {} != seq_len {t}", w.len());
                }
            }
            // pack tokens [b, t], padding with the last window
            let mut tokens = Vec::with_capacity(b * t);
            for i in 0..b {
                let w = windows.get(i).unwrap_or_else(|| windows.last().unwrap());
                tokens.extend(w.iter().map(|&x| x as i32));
            }
            let tok_buf = self
                .client
                .buffer_from_host_buffer::<i32>(&tokens, &[b, t], None)
                .map_err(wrap)?;

            let mut args: Vec<&xla::PjRtBuffer> =
                Vec::with_capacity(1 + self.weight_buffers.len());
            args.push(&tok_buf);
            args.extend(self.weight_buffers.iter());

            let outputs = self.exe.execute_b(&args).map_err(wrap)?;
            let lit = outputs[0][0].to_literal_sync().map_err(wrap)?;
            // aot.py lowers with return_tuple=True → 1-tuple of [b, t, vocab]
            let out = lit.to_tuple1().map_err(wrap)?;
            let flat: Vec<f32> = out.to_vec::<f32>().map_err(wrap)?;
            let vocab = self.spec.output_shape[2];
            if flat.len() != b * t * vocab {
                bail!("unexpected output size {} != {}", flat.len(), b * t * vocab);
            }
            Ok(windows
                .iter()
                .enumerate()
                .map(|(i, _)| {
                    Matrix::from_vec(t, vocab, flat[i * t * vocab..(i + 1) * t * vocab].to_vec())
                })
                .collect())
        }
    }

    /// xla::Error -> anyhow (the crate's error is not Sync-compatible with ?).
    fn wrap(e: xla::Error) -> anyhow::Error {
        anyhow!("xla: {e}")
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub {
    use crate::linalg::Matrix;
    use crate::model::weights::WeightFile;
    use crate::runtime::artifacts::{ArtifactDir, ExeSpec};
    use anyhow::{bail, Result};

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: hisolo was built without the `pjrt` feature \
         (the xla_extension bindings are not present in this environment); \
         use the native serving path (`serve --native`) or the store-backed \
         path (`serve --native --from-store`)";

    /// API-compatible stand-in for the PJRT client when `pjrt` is disabled.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            bail!("{UNAVAILABLE}");
        }

        pub fn platform(&self) -> String {
            "pjrt-stub".to_string()
        }

        pub fn load_model(
            &self,
            _artifacts: &ArtifactDir,
            _exe_name: &str,
            _weight_files: &[&WeightFile],
        ) -> Result<LoadedModel> {
            bail!("{UNAVAILABLE}");
        }
    }

    /// Stub executable handle; never constructible through [`Runtime`].
    pub struct LoadedModel {
        pub spec: ExeSpec,
    }

    impl LoadedModel {
        pub fn batch(&self) -> usize {
            self.spec.batch
        }

        pub fn seq_len(&self) -> usize {
            self.spec.inputs[0].shape[1]
        }

        pub fn score(&self, _windows: &[Vec<u32>]) -> Result<Vec<Matrix>> {
            bail!("{UNAVAILABLE}");
        }
    }
}

#[cfg(test)]
mod tests {
    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_errors_cleanly() {
        let e = super::Runtime::cpu().unwrap_err();
        assert!(format!("{e}").contains("pjrt"), "{e}");
    }
}
