//! `hisolo` — CLI for the Hierarchical Sparse Plus Low-Rank compression
//! stack: compress matrices, evaluate compressed models, serve scoring
//! requests through the coordinator, and run storage-vs-PPL sweeps.

use anyhow::{bail, Context, Result};
use hisolo::compress::{CompressorConfig, Method};
use hisolo::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, Variant};
use hisolo::data::corpus::Corpus;
use hisolo::data::dataset::windows;
use hisolo::data::synthetic;
use hisolo::eval::sweep::{eval_point, sweep_refined, to_csv};
use hisolo::model::{CompressedModel, ModelConfig, Transformer, WeightFile};
use hisolo::store::ModelStore;
use hisolo::runtime::{ArtifactDir, Runtime};
use hisolo::train::{calibrate_model, OptimizerKind, TrainConfig};
use hisolo::util::cli::Args;
use hisolo::util::timer::Table;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

const USAGE: &str = "\
hisolo — Hierarchical Sparse Plus Low-Rank compression of LLMs

USAGE: hisolo <command> [options]

COMMANDS:
  info                          show artifact manifest summary
  compress                      compress one matrix, report error/storage
      --n 256 --method shss-rcm --rank 32 --sparsity 0.3 --depth 3
      [--weights artifacts/model.hwt --tensor layer0.wq]
  eval                          perplexity of a compressed model (native path)
      --method shss-rcm --rank 32 --sparsity 0.3 --depth 3 --windows 24
      [--artifacts artifacts] [--threads N]
  sweep                         full storage-vs-PPL grid (Fig 3 engine)
      [--ranks 8,16,32,64] [--sparsities 0.1,0.2,0.3] [--out sweep.csv]
      [--refine-steps N]  (also calibrate each cell; fills the
      ppl_refined / refine_steps comparison columns)
      [--dtype f32|f16]  (serving residency of the compressed cells:
      f16 halves resident weight bytes; the dtype and
      qkv_resident_bytes CSV columns record the trade-off)
  finetune                      fine-tune compressed factors against the
                                dense teacher (layer-wise calibration) and
                                persist the refined model as a store variant
      --method shss-rcm --steps 200 --lr 0.01 --batch 16
      [--optimizer adam|sgd] [--windows 8] [--threads N]  (N parallel
      per-projection calibrations; 0 = all cores)
      [--rank 32 --sparsity 0.3
      --depth 3] [--store store] [--variant <method>-ft]
      [--synthetic [--tiny]]  (random base model; --tiny shrinks it for
      smoke tests)
  save                          compress the model's q/k/v and persist the
                                HSB1 artifact store (no recompression at load)
      --method shss-rcm --rank 32 --sparsity 0.3 --depth 3
      [--store store] [--variant <name>] (default: the method name)
      [--sharded]  (write the HSB2 sharded form: one shard per layer,
      value runs aligned for zero-copy mmap serving — N processes share
      one page-cache copy; disable mapping with HISOLO_MMAP=off)
      [--synthetic [--tiny]]  (random base model when artifacts are
      absent; --tiny matches serve's smoke config)
  serve                         serve scoring requests via PJRT executables
      [--variant both|dense|hss] [--requests 64] [--max-batch 8]
      [--max-wait-ms 5] [--native]  (--native uses the Rust fwd, no PJRT)
      [--from-store store [--store-variant shss-rcm]]  (with --native:
      cold-start the hss lane from the store instead of recompressing;
      auto-detects monolithic HSB1 vs sharded HSB2 variants — sharded +
      mmap serves factors zero-copy straight out of the page cache)
      [--synthetic [--tiny]]  (with --native: random base model over a
      synthetic token stream — no artifacts needed; smoke runs)
      [--metrics-json path]  (write a Metrics::to_json() snapshot — the
      reporter refreshes it periodically, plus one final write)
      [--metrics-interval-secs 5]  (reporter period: queue-depth gauges
      sampled + one-line summary logged; silence with HISOLO_LOG=off)
      [--json traj.jsonl]  (append the serve trajectory record: latency
      p50/p99/p999, queue/service split, per-stage span breakdown)
      [--trace-out trace.json]  (per-request flight recorder: write a
      Chrome trace-event / Perfetto JSON timeline with trace IDs,
      per-batch stage spans, and tail-sampled slow requests)
      [--slo-p99-us N]  (SLO burn-rate accounting against a p99 latency
      target: prints a slo_burn_check line, fills the metrics `slo`
      object, and the reporter tracks a rolling-window burn rate)
      [--kv-pages N]  (with --native: attach a paged KV cache of N
      fixed-size pages to each lane's scorer, enabling prefill/decode
      session requests; memory ceiling = N x 2 x layers x 16 x d_model
      x 2 bytes, allocated up front)
      [--decode]  (with --native: after the rescore workload, run
      multi-turn session traffic — prefill shared prompts, then decode
      one token per step over the paged KV cache — and print a
      decode_check line asserting decode NLLs are bit-identical to
      full-window prefill and the prefix cache is hitting; implies
      --kv-pages 512 unless given)
  trace <file>                  analyze a --trace-out export offline:
                                per-trace critical paths for the slowest
                                requests and a per-bucket stage breakdown
      [--top 5]  (how many slow traces to expand)

Artifacts default to ./artifacts (override with --artifacts or
HISOLO_ARTIFACTS). Build them with `make artifacts`.";

fn main() {
    let args = Args::parse(&["native", "no-rcm", "help", "synthetic", "tiny", "decode", "sharded"]);
    if args.flag("help") || args.subcommand().is_none() {
        println!("{USAGE}");
        return;
    }
    let result = match args.subcommand().unwrap() {
        "info" => cmd_info(&args),
        "compress" => cmd_compress(&args),
        "eval" => cmd_eval(&args),
        "sweep" => cmd_sweep(&args),
        "save" => cmd_save(&args),
        "finetune" => cmd_finetune(&args),
        "serve" => cmd_serve(&args),
        "trace" => cmd_trace(&args),
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_path(args: &Args) -> PathBuf {
    args.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(ArtifactDir::default_path)
}

fn cfg_from_args(args: &Args) -> CompressorConfig {
    CompressorConfig {
        rank: args.get_usize("rank", 32),
        sparsity: args.get_f64("sparsity", 0.3),
        depth: args.get_usize("depth", 3),
        tol: args.get_f64("tol", 1e-6) as f32,
        min_leaf: args.get_usize("min-leaf", 16),
        ..Default::default()
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = artifacts_path(args);
    let a = ArtifactDir::load(&dir)?;
    println!("artifacts: {}", dir.display());
    println!("model: {:?}", a.model_config);
    println!(
        "qkv params (compression target): {}",
        a.model_config.qkv_params()
    );
    if let Some(h) = &a.hss_config {
        println!("hss config: {h}");
    }
    let mut t = Table::new(&["executable", "batch", "inputs", "output"]);
    for e in &a.executables {
        t.row(&[
            e.name.clone(),
            e.batch.to_string(),
            e.inputs.len().to_string(),
            format!("{:?}", e.output_shape),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<()> {
    let method: Method = args
        .get_str("method", "shss-rcm")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let cfg = cfg_from_args(args);
    let w = if let Some(wpath) = args.get("weights") {
        let tensor = args
            .get("tensor")
            .context("--tensor required with --weights")?;
        WeightFile::load(&PathBuf::from(wpath))?
            .matrix(tensor)?
            .transpose()
    } else {
        synthetic::trained_like(args.get_usize("n", 256), args.get_usize("seed", 42) as u64)
    };
    println!(
        "compressing {}x{} with {} (rank={} sp={} depth={})",
        w.rows, w.cols, method, cfg.rank, cfg.sparsity, cfg.depth
    );
    let t0 = Instant::now();
    let c = hisolo::compress::Compressor::new(cfg).compress(&w, method);
    let dt = t0.elapsed();
    println!("compress time: {:.3}s", dt.as_secs_f64());
    println!("rel fro error: {:.6}", c.rel_error(&w));
    println!(
        "storage: {} params, {} bytes ({:.3}x of dense fp16)",
        c.params(),
        c.bytes(),
        c.storage_ratio()
    );
    // matvec sanity + latency
    let x = vec![1.0f32; w.cols];
    let stats = hisolo::util::timer::quick_bench(|| {
        std::hint::black_box(c.matvec(&x));
    });
    println!("matvec: {}", hisolo::util::timer::fmt_ns(stats.mean_ns));
    Ok(())
}

fn load_model(args: &Args) -> Result<(Arc<Transformer>, ArtifactDir)> {
    let dir = artifacts_path(args);
    let a = ArtifactDir::load(&dir)?;
    let weights = WeightFile::load(&dir.join("model.hwt"))?;
    let model = Transformer::from_weights(&weights, a.model_config)?;
    Ok((Arc::new(model), a))
}

fn eval_windows(a: &ArtifactDir, count: usize) -> Result<Vec<Vec<u32>>> {
    let corpus = Corpus::load(&a.dir.join("corpus_test.txt"))?;
    let ws = windows(&corpus.tokens, a.model_config.seq_len, count);
    if ws.is_empty() {
        bail!("corpus too short for seq_len {}", a.model_config.seq_len);
    }
    Ok(ws)
}

fn cmd_eval(args: &Args) -> Result<()> {
    let method: Method = args
        .get_str("method", "shss-rcm")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let cfg = cfg_from_args(args);
    let threads = args.get_usize("threads", default_threads());
    let (model, a) = load_model(args)?;
    let ws = eval_windows(&a, args.get_usize("windows", 24))?;
    println!(
        "evaluating {} (rank={} sp={} depth={}) on {} windows, {} threads",
        method,
        cfg.rank,
        cfg.sparsity,
        cfg.depth,
        ws.len(),
        threads
    );
    let dense = eval_point(&model, Method::Dense, cfg, &ws, threads);
    let p = if method == Method::Dense {
        dense.clone()
    } else {
        eval_point(&model, method, cfg, &ws, threads)
    };
    let mut t = Table::new(&[
        "method",
        "ppl",
        "d_ppl vs dense",
        "qkv ratio",
        "model ratio",
        "rel err",
        "compress s",
    ]);
    for x in [&dense, &p] {
        t.row(&[
            x.method.paper_label().to_string(),
            format!("{:.4}", x.ppl),
            format!("{:+.4}", x.ppl - dense.ppl),
            format!("{:.3}", x.qkv_ratio()),
            format!("{:.3}", x.model_ratio),
            format!("{:.4}", x.mean_rel_error),
            format!("{:.2}", x.compress_secs),
        ]);
    }
    t.print();
    Ok(())
}

/// Base transformer for `save`: the trained artifact model when present,
/// else (with --synthetic) a random model so the store path works in
/// environments that never ran `make artifacts`.
fn base_model(args: &Args) -> Result<Arc<Transformer>> {
    let dir = artifacts_path(args);
    if dir.join("manifest.json").exists() {
        let (model, _a) = load_model(args)?;
        Ok(model)
    } else if args.flag("synthetic") {
        let seed = args.get_usize("seed", 7) as u64;
        // --tiny matches serve's smoke config exactly, so a tiny saved
        // store variant cold-starts under `serve --synthetic --tiny`
        let mcfg = if args.flag("tiny") {
            ModelConfig {
                vocab: 64,
                d_model: 64,
                n_heads: 4,
                n_layers: 2,
                d_ff: 128,
                seq_len: 32,
            }
        } else {
            ModelConfig::default()
        };
        Ok(Arc::new(Transformer::random(mcfg, seed)))
    } else {
        bail!(
            "artifacts not found at {} — run `make artifacts`, or pass \
             --synthetic to use a random base model",
            dir.display()
        );
    }
}

fn cmd_save(args: &Args) -> Result<()> {
    let method: Method = args
        .get_str("method", "shss-rcm")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let cfg = cfg_from_args(args);
    let store_dir = args.get_str("store", "store");
    let variant = args.get_str("variant", method.name());
    let model = base_model(args)?;
    println!(
        "compressing q/k/v of {} layers with {} (rank={} sp={} depth={})",
        model.cfg.n_layers, method, cfg.rank, cfg.sparsity, cfg.depth
    );
    let t0 = Instant::now();
    let cm = CompressedModel::compress(model, method, cfg);
    let compress_secs = t0.elapsed().as_secs_f64();
    let store = ModelStore::open(&store_dir);
    let path = if args.flag("sharded") {
        // HSB2: one shard per layer, aligned payloads — the zero-copy
        // mmap serving form
        store.save_model_sharded(&variant, &cm)?
    } else {
        store.save_model(&variant, &cm)?
    };
    println!("compress time: {compress_secs:.2}s");
    println!("mean rel error: {:.4}", cm.mean_rel_error());
    println!(
        "qkv storage: {} bytes compressed vs {} dense fp16 ({:.3}x)",
        cm.qkv_raw_bytes(),
        cm.qkv_dense_bytes(),
        cm.qkv_raw_bytes() as f64 / cm.qkv_dense_bytes() as f64
    );
    println!(
        "wrote variant '{variant}' -> {} ({} bytes on disk)",
        path.display(),
        store.variant_bytes(&variant)
    );
    println!("serve it with: hisolo serve --native --from-store {store_dir} --store-variant {variant}");
    Ok(())
}

fn train_cfg_from_args(args: &Args, steps: usize) -> Result<TrainConfig> {
    let d = TrainConfig::default();
    let optimizer: OptimizerKind = args
        .get_str("optimizer", d.optimizer.name())
        .parse()
        .map_err(anyhow::Error::msg)?;
    Ok(TrainConfig {
        steps,
        batch: args.get_usize("batch", d.batch),
        lr: args.get_f64("lr", d.lr as f64) as f32,
        optimizer,
        eval_every: args.get_usize("eval-every", d.eval_every),
        patience: args.get_usize("patience", d.patience),
        seed: args.get_usize("train-seed", d.seed as usize) as u64,
        // fan the independent per-projection calibrations across threads
        threads: args.get_usize("threads", d.threads),
        ..d
    })
}

/// `finetune` — the paper's end-to-end training claim as a deployment
/// step: compress, calibrate every q/k/v projection against its dense
/// teacher on corpus activations, and persist the refined model as a new
/// store variant ready for `Coordinator::swap_variant`.
fn cmd_finetune(args: &Args) -> Result<()> {
    let method: Method = args
        .get_str("method", "shss-rcm")
        .parse()
        .map_err(anyhow::Error::msg)?;
    let cfg = cfg_from_args(args);
    let store_dir = args.get_str("store", "store");
    let variant = args.get_str("variant", &format!("{}-ft", method.name()));
    let n_windows = args.get_usize("windows", 8);

    // base model + calibration tokens: trained artifacts when present,
    // otherwise (--synthetic) a random model over a synthetic stream;
    // --tiny shrinks the synthetic model for CI smoke runs.
    let dir = artifacts_path(args);
    // an explicit --synthetic always wins over an artifacts directory that
    // happens to exist — smoke runs must never touch the production model
    let (model, tokens): (Arc<Transformer>, Vec<u32>) = if args.flag("synthetic") {
        let mcfg = if args.flag("tiny") {
            ModelConfig {
                vocab: 64,
                d_model: 64,
                n_heads: 4,
                n_layers: 2,
                d_ff: 128,
                seq_len: 32,
            }
        } else {
            ModelConfig::default()
        };
        let seed = args.get_usize("seed", 7) as u64;
        let model = Arc::new(Transformer::random(mcfg, seed));
        (model, synthetic::token_stream(20_000, mcfg.vocab))
    } else if dir.join("manifest.json").exists() {
        let (model, a) = load_model(args)?;
        let corpus = match Corpus::load(&a.dir.join("corpus_train.txt")) {
            Ok(c) => c,
            Err(_) => {
                // calibrating on the eval split overstates refined-vs-
                // oneshot numbers downstream — make the fallback loud
                eprintln!(
                    "WARN: corpus_train.txt missing — calibrating on corpus_test.txt, \
                     which eval/sweep also measure perplexity on"
                );
                Corpus::load(&a.dir.join("corpus_test.txt"))?
            }
        };
        (model, corpus.tokens)
    } else {
        bail!(
            "artifacts not found at {} — run `make artifacts`, or pass \
             --synthetic to use a random base model",
            dir.display()
        );
    };
    let ws = windows(&tokens, model.cfg.seq_len, n_windows);
    if ws.is_empty() {
        bail!("corpus too short for seq_len {}", model.cfg.seq_len);
    }

    println!(
        "compressing q/k/v of {} layers with {} (rank={} sp={} depth={})",
        model.cfg.n_layers, method, cfg.rank, cfg.sparsity, cfg.depth
    );
    let t0 = Instant::now();
    let mut cm = CompressedModel::compress(model, method, cfg);
    println!("compress time: {:.2}s", t0.elapsed().as_secs_f64());
    println!("one-shot mean rel error: {:.4}", cm.mean_rel_error());

    let train_cfg = train_cfg_from_args(args, args.get_usize("steps", 200))?;
    println!(
        "calibrating on {} windows ({} steps max, lr {}, batch {}, {})",
        ws.len(),
        train_cfg.steps,
        train_cfg.lr,
        train_cfg.batch,
        train_cfg.optimizer.name()
    );
    let t0 = Instant::now();
    let reports = calibrate_model(&mut cm, &ws, &train_cfg);
    let train_secs = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&[
        "projection",
        "steps",
        "rel err before",
        "rel err after",
        "loss before",
        "loss after",
    ]);
    for r in &reports {
        t.row(&[
            r.name.clone(),
            r.steps_run.to_string(),
            format!("{:.4}", r.rel_err_before),
            format!("{:.4}", r.rel_err_after),
            format!("{:.5}", r.loss_before),
            format!("{:.5}", r.loss_after),
        ]);
    }
    t.print();
    let total_steps: usize = reports.iter().map(|r| r.steps_run).sum();
    println!(
        "refined mean rel error: {:.4} ({total_steps} total steps in {train_secs:.2}s, {:.0} steps/s)",
        cm.mean_rel_error(),
        total_steps as f64 / train_secs.max(1e-9)
    );

    let store = ModelStore::open(&store_dir);
    let path = store.save_model(&variant, &cm)?;
    println!(
        "wrote refined variant '{variant}' -> {} ({} bytes on disk)",
        path.display(),
        store.variant_bytes(&variant)
    );
    println!("serve it with: hisolo serve --native --from-store {store_dir} --store-variant {variant}");
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let (model, a) = load_model(args)?;
    let ws = eval_windows(&a, args.get_usize("windows", 16))?;
    let threads = args.get_usize("threads", default_threads());
    let ranks: Vec<usize> = parse_list(&args.get_str("ranks", "8,16,32,64"))?;
    let sparsities: Vec<f64> = parse_list(&args.get_str("sparsities", "0.1,0.2,0.3"))?;
    let depth = args.get_usize("depth", 3);
    let mut configs = Vec::new();
    for &r in &ranks {
        for &sp in &sparsities {
            configs.push(CompressorConfig {
                rank: r,
                sparsity: sp,
                depth,
                ..Default::default()
            });
        }
    }
    let refine_steps = args.get_usize("refine-steps", 0);
    let train_cfg = if refine_steps > 0 {
        Some(train_cfg_from_args(args, refine_steps)?)
    } else {
        None
    };
    // serving residency for the compressed cells: f16 rows measure the
    // memory/perplexity trade-off at the store's native dtype
    let dtype: hisolo::linalg::Dtype = args
        .get_str("dtype", "f32")
        .parse()
        .map_err(anyhow::Error::msg)?;
    println!(
        "sweep: {} methods x {} configs on {} windows at {dtype} residency{}",
        Method::FIG3.len(),
        configs.len(),
        ws.len(),
        if refine_steps > 0 {
            format!(" (+ refine stage, {refine_steps} steps)")
        } else {
            String::new()
        }
    );
    let points = sweep_refined(
        &model,
        &Method::FIG3,
        &configs,
        &ws,
        threads,
        train_cfg.as_ref(),
        dtype,
    );
    let csv = to_csv(&points);
    if let Some(out) = args.get("out") {
        std::fs::write(out, &csv)?;
        println!("wrote {out}");
    } else {
        print!("{csv}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = artifacts_path(args);
    let n_requests = args.get_usize("requests", 64);
    let variant_sel = args.get_str("variant", "both");
    let native = args.flag("native");
    let synthetic_mode = args.flag("synthetic");
    let from_store = args.get_path("from-store");
    if from_store.is_some() && !native {
        bail!("--from-store requires --native (the PJRT path loads AOT graphs, not HSB1 stores)");
    }
    if synthetic_mode && !native {
        bail!("--synthetic requires --native (PJRT graphs are compiled against trained artifacts)");
    }
    let decode_mode = args.flag("decode");
    if decode_mode && !native {
        bail!("--decode requires --native (paged-KV sessions live in the native scorers)");
    }
    let kv_pages = args.get_usize("kv-pages", if decode_mode { 512 } else { 0 });
    if kv_pages > 0 && !native {
        bail!("--kv-pages requires --native");
    }

    // per-request flight recorder: enabled only when a trace is requested,
    // so default serving pays one thread-local check per span
    let trace_out = args.get_path("trace-out");
    if trace_out.is_some() {
        hisolo::obs::recorder::recorder().set_enabled(true);
        if !hisolo::obs::registry().enabled() {
            eprintln!(
                "WARN: HISOLO_TRACE=off — the trace will contain request lifecycles \
                 but no kernel stage spans"
            );
        }
    }

    // model + scoring stream: trained artifacts by default, or
    // (--synthetic [--tiny]) a random base model over a synthetic token
    // stream so smoke runs need no artifacts on disk. The native base
    // model is built once here and shared across lanes.
    let (base_model, seq_len, tokens): (Option<Arc<Transformer>>, usize, Vec<u32>) =
        if synthetic_mode {
            let mcfg = if args.flag("tiny") {
                ModelConfig {
                    vocab: 64,
                    d_model: 64,
                    n_heads: 4,
                    n_layers: 2,
                    d_ff: 128,
                    seq_len: 32,
                }
            } else {
                ModelConfig::default()
            };
            let seed = args.get_usize("seed", 7) as u64;
            let model = Arc::new(Transformer::random(mcfg, seed));
            (Some(model), mcfg.seq_len, synthetic::token_stream(20_000, mcfg.vocab))
        } else {
            let a = ArtifactDir::load(&dir)?;
            let corpus = Corpus::load(&dir.join("corpus_test.txt"))?;
            let model = if native {
                let weights = WeightFile::load(&dir.join("model.hwt"))?;
                Some(Arc::new(Transformer::from_weights(&weights, a.model_config)?))
            } else {
                None
            };
            (model, a.model_config.seq_len, corpus.tokens)
        };
    let coordinator_cfg = CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: args.get_usize("max-batch", 8),
            max_wait: Duration::from_millis(args.get_usize("max-wait-ms", 5) as u64),
            capacity: args.get_usize("capacity", 1024),
            // window-length coalescing: "pow2" (default), "none", or a
            // comma-separated list of ascending bucket edges
            bucket_edges: match args.get_str("bucket-edges", "pow2").as_str() {
                "pow2" => hisolo::coordinator::batcher::default_bucket_edges(),
                "none" => Vec::new(),
                spec => {
                    let edges = spec
                        .split(',')
                        .map(|e| e.trim().parse::<usize>())
                        .collect::<std::result::Result<Vec<_>, _>>()
                        .map_err(|e| anyhow::anyhow!("bad --bucket-edges '{spec}': {e}"))?;
                    // bucket_index picks the first edge >= len, so the
                    // homogeneity guarantee needs strictly ascending,
                    // nonzero edges
                    if edges[0] == 0 || edges.windows(2).any(|w| w[0] >= w[1]) {
                        bail!("--bucket-edges '{spec}' must be strictly ascending and nonzero");
                    }
                    edges
                }
            },
        },
    };
    let mut coord = Coordinator::new(coordinator_cfg);
    // arm SLO accounting before any request completes, so every latency
    // counts against the error budget
    let slo_target = args.get_usize("slo-p99-us", 0) as u64;
    if slo_target > 0 {
        coord.metrics.set_slo_target_us(slo_target);
    }
    let variants: Vec<Variant> = match variant_sel.as_str() {
        "both" => vec![Variant::Dense, Variant::Hss],
        v => vec![v.parse().map_err(anyhow::Error::msg)?],
    };

    for &v in &variants {
        if native {
            let model = base_model.clone().expect("native path built the base model");
            match v {
                Variant::Dense => {
                    let mut scorer =
                        hisolo::coordinator::worker::NativeDenseScorer::new(model, 8);
                    if kv_pages > 0 {
                        scorer = scorer.with_kv_pages(kv_pages);
                    }
                    coord.add_worker(v, scorer)
                }
                Variant::Hss => {
                    let cm = if let Some(store_dir) = &from_store {
                        // cold start from the HSB1 store: parse only — fp16
                        // factors stay f16-resident (the batched kernels
                        // widen lane-by-lane), no SVD/RCM recompression
                        let store = ModelStore::open(store_dir);
                        let vname = args.get_str("store-variant", "shss-rcm");
                        let t0 = Instant::now();
                        // auto-detects the on-disk form: a sharded HSB2
                        // directory wins over a same-name HSB1 file when
                        // newer, and layers decode in parallel either way
                        let file = store.open_variant(&vname)?;
                        let loaded = Arc::new(CompressedModel::from_store(model, &file)?);
                        println!(
                            "cold-started '{vname}' from {} in {:.1} ms ({}-resident, {} weight bytes, \
                             {} shard(s), {} backing)",
                            store_dir.display(),
                            t0.elapsed().as_secs_f64() * 1e3,
                            loaded.weights_dtype(),
                            loaded.resident_weight_bytes(),
                            file.shard_count(),
                            if file.is_mapped() { "mmap" } else { "buffered" }
                        );
                        loaded
                    } else {
                        let cfg = cfg_from_args(args);
                        Arc::new(CompressedModel::compress(model, Method::SHssRcm, cfg))
                    };
                    let mut scorer =
                        hisolo::coordinator::worker::NativeCompressedScorer::new(cm, 8);
                    if kv_pages > 0 {
                        scorer = scorer.with_kv_pages(kv_pages);
                    }
                    coord.add_worker(v, scorer)
                }
            }
        } else {
            // PJRT scorers are built on the worker thread (client is !Send)
            let dir = dir.clone();
            let exe = match v {
                Variant::Dense => "model_dense_b8",
                Variant::Hss => "model_hss_b8",
            };
            coord.add_worker_factory(v, move || {
                let a = ArtifactDir::load(&dir)?;
                let weights = WeightFile::load(&dir.join("model.hwt"))?;
                let rt = Runtime::cpu()?;
                if exe.contains("hss") {
                    let ops = WeightFile::load(&dir.join("hss_operands.hwt"))?;
                    rt.load_model(&a, exe, &[&weights, &ops])
                } else {
                    rt.load_model(&a, exe, &[&weights])
                }
            });
        }
    }

    let ws = windows(&tokens, seq_len, n_requests);
    if ws.is_empty() {
        bail!("token stream too short for seq_len {seq_len}");
    }
    println!(
        "serving {} requests per variant ({} mode)",
        ws.len(),
        if native { "native" } else { "pjrt" }
    );

    // periodic metrics reporter: samples queue-depth gauges, logs the
    // one-line summary, and (with --metrics-json) overwrites the snapshot
    // file with Metrics::to_json() each interval
    let metrics_json = args.get_path("metrics-json");
    let interval_secs = args.get_usize("metrics-interval-secs", 5);
    if metrics_json.is_some() || args.get("metrics-interval-secs").is_some() {
        coord.start_reporter(
            Duration::from_secs(interval_secs.max(1) as u64),
            metrics_json.clone(),
        );
    }

    let mut t = Table::new(&[
        "variant",
        "requests",
        "ppl",
        "throughput req/s",
        "p50 ms",
        "p95 ms",
        "mean batch",
    ]);
    let mut total_completed = 0usize;
    for &v in &variants {
        let t0 = Instant::now();
        let resps = coord.submit_all(v, &ws)?;
        let wall = t0.elapsed().as_secs_f64();
        total_completed += resps.len();
        let errors = resps.iter().filter(|r| r.error.is_some()).count();
        if errors > 0 {
            bail!(
                "{errors} errors; first: {:?}",
                resps.iter().find_map(|r| r.error.clone())
            );
        }
        let nll: f64 = resps.iter().map(|r| r.nll).sum();
        let toks: usize = resps.iter().map(|r| r.tokens).sum();
        let mut lat: Vec<u64> = resps.iter().map(|r| r.latency_us).collect();
        lat.sort_unstable();
        let mean_batch =
            resps.iter().map(|r| r.batch_size).sum::<usize>() as f64 / resps.len() as f64;
        t.row(&[
            v.name().to_string(),
            resps.len().to_string(),
            format!("{:.4}", (nll / toks as f64).exp()),
            format!("{:.1}", resps.len() as f64 / wall),
            format!("{:.1}", lat[lat.len() / 2] as f64 / 1e3),
            format!("{:.1}", lat[lat.len() * 95 / 100] as f64 / 1e3),
            format!("{mean_batch:.2}"),
        ]);
    }
    t.print();
    // --decode: multi-turn session traffic over the paged KV cache,
    // raced against the O(t²) full-window rescore pattern, with a
    // bitwise NLL identity check (decode vs full-window prefill)
    if decode_mode {
        for &v in &variants {
            run_decode_sessions(&coord, v, &ws, seq_len)?;
        }
    }
    coord.sample_queue_depths();
    println!("\nstage breakdown (where each served token's microseconds went):");
    hisolo::obs::registry().table().print();
    println!("metrics: {}", coord.metrics.summary());

    // SLO accounting must close: the worker computes e2e latency as
    // queue_us + service_us per request, so the means decompose exactly
    // (the tolerance only absorbs float summation order). CI greps PASS.
    let q_mean = coord.metrics.mean_queue_wait_us();
    let svc_mean = coord.metrics.mean_service_us();
    let e2e_mean = coord.metrics.mean_latency_us();
    let ratio = if e2e_mean > 0.0 {
        (q_mean + svc_mean) / e2e_mean
    } else {
        0.0
    };
    let decomposed = e2e_mean > 0.0 && (0.95..=1.05).contains(&ratio);
    println!(
        "latency_decomposition: queue_wait_mean={q_mean:.0}us + service_mean={svc_mean:.0}us \
         vs e2e_mean={e2e_mean:.0}us (ratio {ratio:.3}) {}",
        if decomposed { "PASS" } else { "FAIL" }
    );

    // SLO burn rate: violation rate over the 1% p99 error budget. Burn
    // above 1.0 means the budget is being consumed faster than it accrues
    // — an operational signal, not a smoke failure, so no bail here.
    if slo_target > 0 {
        let (total, bad) = coord.metrics.slo_counts();
        let burn = coord.metrics.slo_burn_rate();
        println!(
            "slo_burn_check: target_p99={slo_target}us total={total} violations={bad} \
             burn_rate={burn:.3} budget_remaining={:.3} {}",
            coord.metrics.slo_budget_remaining(),
            if burn <= 1.0 { "PASS" } else { "FAIL" }
        );
    }

    // final snapshot (the reporter may not have fired since the last
    // completions) + one-line JSON trajectory record for the benches file
    if let Some(path) = &metrics_json {
        std::fs::write(path, format!("{}\n", coord.metrics.to_json()))
            .with_context(|| format!("write metrics snapshot {}", path.display()))?;
        println!("wrote metrics snapshot to {}", path.display());
    }
    if let Some(path) = args.get_path("json") {
        use hisolo::util::json::{num, obj, s};
        use std::io::Write;
        let m = &coord.metrics;
        let record = obj(vec![
            ("bench", s("serve")),
            ("requests", num(total_completed as f64)),
            ("latency_p50_us", num(m.latency_percentile_us(0.50) as f64)),
            ("latency_p99_us", num(m.latency_percentile_us(0.99) as f64)),
            ("latency_p999_us", num(m.latency_percentile_us(0.999) as f64)),
            ("queue_wait_p50_us", num(m.queue_wait_percentile_us(0.50) as f64)),
            ("service_p50_us", num(m.service_percentile_us(0.50) as f64)),
            ("mean_batch", num(m.mean_batch_size())),
            ("stages", hisolo::obs::registry().to_json()),
        ]);
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("open json trajectory file {}", path.display()))?;
        writeln!(f, "{record}")?;
        println!("appended serve trajectory line to {}", path.display());
    }
    if let Some(path) = &trace_out {
        let export = hisolo::obs::recorder::recorder().export();
        std::fs::write(path, format!("{}\n", export.json))
            .with_context(|| format!("write trace {}", path.display()))?;
        println!(
            "wrote trace: {} requests ({} tail-sampled), {} stage spans, {} dropped -> {} \
             (load in Perfetto / chrome://tracing, or run `hisolo trace {}`)",
            export.requests,
            export.tail_sampled,
            export.span_events,
            export.dropped_spans,
            path.display(),
            path.display()
        );
    }
    coord.shutdown();
    if !decomposed {
        bail!("latency decomposition check failed (ratio {ratio:.3})");
    }
    Ok(())
}

/// `serve --decode` workload: open paired sessions whose prompts share a
/// prefix (so the paged cache publishes and re-hits prompt pages), decode
/// the rest of each window one token per step, race the same token
/// stream through the O(t²) full-window rescore pattern, and assert the
/// decode NLL totals are bit-identical to a fresh full-window prefill.
/// Single-token decode steps keep the f64 NLL accumulation order equal
/// to the full prefill's row sum — that's what makes bitwise equality
/// (not mere closeness) the right assertion.
fn run_decode_sessions(
    coord: &Coordinator,
    v: Variant,
    ws: &[Vec<u32>],
    seq_len: usize,
) -> Result<()> {
    use hisolo::model::kvcache::DEFAULT_BLOCK_SIZE;
    let recv = |rx: std::sync::mpsc::Receiver<hisolo::coordinator::ScoreResponse>,
                what: &str|
     -> Result<hisolo::coordinator::ScoreResponse> {
        let r = rx
            .recv()
            .map_err(|e| anyhow::anyhow!("{what}: worker gone: {e}"))?;
        match r.error {
            Some(e) => bail!("{what} failed: {e}"),
            None => Ok(r),
        }
    };
    let n_sessions = ws.len().clamp(2, 8) & !1; // even, pairs share a window
    // block-aligned prompt ≥ one full block: the pair's second prefill
    // must find published prompt pages to hit
    let prompt_len = (seq_len / 2 / DEFAULT_BLOCK_SIZE * DEFAULT_BLOCK_SIZE)
        .max(DEFAULT_BLOCK_SIZE)
        .min(seq_len - 1)
        .max(2);
    let window_of = |s: usize| &ws[(s / 2) % ws.len()];

    let mut totals = vec![0.0f64; n_sessions];
    let mut toks = vec![0usize; n_sessions];
    let t0 = Instant::now();
    // two prefill waves: evens publish the prompt pages, odds (same
    // prompts) re-open them as prefix-cache hits
    for wave in 0..2 {
        let mut rxs = Vec::new();
        for s in (0..n_sessions).filter(|s| s % 2 == wave) {
            let rx = coord.submit_prefill(v, s as u64, window_of(s)[..prompt_len].to_vec())?;
            rxs.push((s, rx));
        }
        for (s, rx) in rxs {
            let r = recv(rx, "prefill")?;
            totals[s] += r.nll;
            toks[s] += r.tokens;
        }
    }
    // decode one token per session per step; the steps coalesce into
    // decode-class buckets and run as one batched O(t) kernel call
    let mut decoded = 0usize;
    for i in prompt_len..seq_len {
        let mut rxs = Vec::new();
        for s in 0..n_sessions {
            let rx = coord.submit_decode(v, s as u64, vec![window_of(s)[i]])?;
            rxs.push((s, rx));
        }
        for (s, rx) in rxs {
            let r = recv(rx, "decode")?;
            totals[s] += r.nll;
            toks[s] += r.tokens;
            decoded += 1;
        }
    }
    let decode_secs = t0.elapsed().as_secs_f64();

    // rescore arm: the pre-decode O(t²) pattern — rescore the whole
    // growing window once per new token
    let t1 = Instant::now();
    for i in prompt_len..seq_len {
        let windows: Vec<Vec<u32>> = (0..n_sessions)
            .map(|s| window_of(s)[..=i].to_vec())
            .collect();
        let resps = coord.submit_all(v, &windows)?;
        if let Some(e) = resps.iter().find_map(|r| r.error.clone()) {
            bail!("rescore failed: {e}");
        }
    }
    let rescore_secs = t1.elapsed().as_secs_f64();

    // reference: full-window prefill in fresh sessions must reproduce
    // the prefill+decode NLL totals bit-for-bit
    let mut bitwise_ok = true;
    for s in 0..n_sessions {
        let rx = coord.submit_prefill(v, 1_000 + s as u64, window_of(s)[..seq_len].to_vec())?;
        let r = recv(rx, "reference prefill")?;
        if r.nll.to_bits() != totals[s].to_bits() || r.tokens != toks[s] {
            bitwise_ok = false;
            eprintln!(
                "session {s}: decode total nll {} ({} toks) != full prefill {} ({} toks)",
                totals[s], toks[s], r.nll, r.tokens
            );
        }
    }
    let hit_rate = coord.metrics.kv_hit_rate();
    let pass = bitwise_ok && hit_rate > 0.0;
    println!(
        "decode_check: variant={} sessions={n_sessions} prompt={prompt_len} decoded={decoded} \
         decode_tps={:.0} rescore_tps={:.0} speedup={:.2}x bitwise={} kv_hit_rate={hit_rate:.3} {}",
        v.name(),
        decoded as f64 / decode_secs.max(1e-12),
        decoded as f64 / rescore_secs.max(1e-12),
        rescore_secs.max(1e-12) / decode_secs.max(1e-12),
        if bitwise_ok { "ok" } else { "MISMATCH" },
        if pass { "PASS" } else { "FAIL" }
    );
    if !pass {
        bail!(
            "decode_check failed for {} (bitwise={bitwise_ok} hit_rate={hit_rate})",
            v.name()
        );
    }
    Ok(())
}

/// Stable display name for a variant index recorded in a trace export
/// (the export stores `Variant::index()` so `obs` stays decoupled from
/// the coordinator types).
fn variant_label(idx: usize) -> String {
    [Variant::Dense, Variant::Hss]
        .iter()
        .find(|v| v.index() == idx)
        .map(|v| v.name().to_string())
        .unwrap_or_else(|| format!("variant{idx}"))
}

/// `trace` — offline analysis of a Chrome trace-event file written by
/// `serve --trace-out`: joins request events to the stage spans of the
/// batch that served them (via `args.batch`), prints the critical path of
/// the slowest traces, and aggregates a per-bucket stage breakdown keyed
/// by next-power-of-two window length (the serve-time bucket edges are
/// not recorded in the export).
fn cmd_trace(args: &Args) -> Result<()> {
    use std::collections::{BTreeMap, BTreeSet};

    let path = args
        .positional()
        .get(1)
        .context("usage: hisolo trace <trace.json> [--top 5]")?;
    let text = std::fs::read_to_string(path).with_context(|| format!("read {path}"))?;
    let j = hisolo::util::json::Json::parse(text.trim())
        .map_err(|e| anyhow::anyhow!("parse {path}: {e}"))?;
    let events = j
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .context("no traceEvents array — not a trace-event export?")?;

    struct Req {
        trace: u64,
        batch: u64,
        dur: f64,
        queue_us: f64,
        service_us: f64,
        len: u64,
        variant: String,
        tail: bool,
        error: bool,
    }
    let mut reqs: Vec<Req> = Vec::new();
    // batch -> stage name -> (span count, total µs)
    let mut spans: BTreeMap<u64, BTreeMap<String, (u64, f64)>> = BTreeMap::new();
    for ev in events {
        let name = ev.get("name").and_then(|n| n.as_str()).unwrap_or("");
        let cat = ev.get("cat").and_then(|c| c.as_str()).unwrap_or("");
        let top = |k: &str| ev.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        let argf = |k: &str| {
            ev.get("args")
                .and_then(|a| a.get(k))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        if name == "request" {
            let argb = |k: &str| {
                ev.get("args")
                    .and_then(|a| a.get(k))
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false)
            };
            reqs.push(Req {
                trace: argf("trace") as u64,
                batch: argf("batch") as u64,
                dur: top("dur"),
                queue_us: argf("queue_us"),
                service_us: argf("service_us"),
                len: argf("len") as u64,
                variant: variant_label(argf("variant") as usize),
                tail: argb("tail_sampled"),
                error: argb("error"),
            });
        } else if cat == "stage" {
            let slot = spans
                .entry(argf("batch") as u64)
                .or_default()
                .entry(name.to_string())
                .or_insert((0, 0.0));
            slot.0 += 1;
            slot.1 += top("dur");
        }
    }
    if reqs.is_empty() {
        bail!("no request events in {path}");
    }

    reqs.sort_by(|a, b| b.dur.partial_cmp(&a.dur).unwrap_or(std::cmp::Ordering::Equal));
    let top_n = args.get_usize("top", 5).min(reqs.len());
    println!(
        "{}: {} requests ({} tail-sampled), {} batches with stage spans",
        path,
        reqs.len(),
        reqs.iter().filter(|r| r.tail).count(),
        spans.len()
    );
    println!("\nslowest {top_n} traces (critical path: queue wait, then the serving batch's stages by time — stages nest, so shares can overlap):");
    for r in &reqs[..top_n] {
        let mut path_parts = vec![format!("queue_wait {:.0}us", r.queue_us)];
        match spans.get(&r.batch) {
            Some(stages) => {
                let mut by_time: Vec<(&String, &(u64, f64))> = stages.iter().collect();
                by_time.sort_by(|a, b| {
                    b.1 .1.partial_cmp(&a.1 .1).unwrap_or(std::cmp::Ordering::Equal)
                });
                for (stage, (count, total)) in by_time.iter().take(4) {
                    path_parts.push(format!("{stage} {total:.0}us x{count}"));
                }
            }
            None => path_parts.push(format!(
                "service {:.0}us (batch spans wrapped out of the ring)",
                r.service_us
            )),
        }
        println!(
            "  trace {} [{} len={} batch={}{}{}] {:.0}us: {}",
            r.trace,
            r.variant,
            r.len,
            r.batch,
            if r.tail { " tail-sampled" } else { "" },
            if r.error { " ERROR" } else { "" },
            r.dur,
            path_parts.join(" -> ")
        );
    }

    // per-bucket breakdown: a batch is length-homogeneous, so its spans
    // count once per bucket (via the set), never once per member request
    let mut buckets: BTreeMap<u64, (u64, f64, BTreeSet<u64>)> = BTreeMap::new();
    for r in &reqs {
        let edge = r.len.max(1).next_power_of_two();
        let b = buckets.entry(edge).or_default();
        b.0 += 1;
        b.1 += r.dur;
        b.2.insert(r.batch);
    }
    println!("\nper-bucket stage breakdown (bucketed by next-pow2 window length):");
    let mut t = Table::new(&["bucket<=", "requests", "mean e2e", "dominant stages (total us)"]);
    for (edge, (n, total_dur, batches)) in &buckets {
        let mut stage_tot: BTreeMap<&str, f64> = BTreeMap::new();
        for b in batches {
            if let Some(stages) = spans.get(b) {
                for (stage, (_c, tot)) in stages {
                    *stage_tot.entry(stage.as_str()).or_default() += *tot;
                }
            }
        }
        let mut ranked: Vec<(&str, f64)> = stage_tot.into_iter().collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        let desc = ranked
            .iter()
            .take(3)
            .map(|(stage, us)| format!("{stage}={us:.0}"))
            .collect::<Vec<_>>()
            .join(" ");
        t.row(&[
            edge.to_string(),
            n.to_string(),
            format!("{:.0}us", total_dur / *n as f64),
            if desc.is_empty() { "(no spans)".to_string() } else { desc },
        ]);
    }
    t.print();
    Ok(())
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

fn parse_list<T: std::str::FromStr>(s: &str) -> Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    s.split(',')
        .filter(|p| !p.is_empty())
        .map(|p| {
            p.trim()
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("bad list element '{p}': {e}"))
        })
        .collect()
}
