//! Stage-level span tracing: where every microsecond of a served token goes.
//!
//! The paper's hardware-friendliness claim is a *cost-structure* claim —
//! HSS matvec "reduces to one sparse and a sequence of thin-matrix
//! multiplications" — so end-to-end latency alone cannot validate it. This
//! module decomposes a served request into a fixed taxonomy of stages
//! ([`Stage`]), each backed by a lock-free log-bucketed histogram (the same
//! bucket scheme as the serving `Metrics`, see [`histogram`]), recorded by
//! RAII [`Span`] guards cheap enough (~2 `Instant::now` calls, two relaxed
//! atomic adds) to wrap every `apply_batch` / `attention_batch` /
//! `spmm_add` call on the hot path.
//!
//! # Stage taxonomy
//!
//! | stage | covers |
//! |---|---|
//! | `queue_wait` | submit → dequeue (recorded by the worker, not a guard) |
//! | `bucket_form` | length-coalescing a polled batch into buckets |
//! | `spmm` | CSR sparse multiply (`Csr::spmm_add` / `spmm_add_staged`) |
//! | `hss_walk` | one blocked HSS tree traversal (`HssNode::apply_batch_with`) |
//! | `lowrank` | the two thin factor multiplies of a low-rank apply |
//! | `attention` | one `attention_batch` call over the stacked block |
//! | `mlp` | one transformer FFN block (ln2 → gelu matmuls → residual) |
//! | `softmax` | output log-softmax + NLL (`window_nll`) |
//! | `reply_route` | routing one scored response back to its submitter |
//! | `swap_install` | building + installing a hot-swapped scorer |
//! | `kv_prefill` | cache-writing K/V quantize+store during a prefill layer |
//! | `kv_decode` | one decode layer: K/V append + paged attention |
//! | `page_gather` | widening a sequence's f16 pages into the gather staging |
//!
//! Stages are **not disjoint**: `spmm` spans fired inside an HSS traversal
//! nest within the enclosing `hss_walk` span, so stage totals answer "how
//! much time was spent inside X", not "stage times sum to wall clock". The
//! request-lifecycle split that *does* sum exactly — queue_wait + service =
//! end-to-end — lives in `coordinator::Metrics`.
//!
//! # Span-guard rules for hot loops
//!
//! Instrument at **call-site granularity** (one span per `apply_batch`, per
//! `attention_batch`, per `window_nll`), never inside per-row / per-element
//! inner loops: a guard costs ~40–80ns, which is noise around a batched
//! kernel call but would dominate a row of streaming attention softmax.
//! The batched-apply bench measures this and asserts span overhead ≤ 2% of
//! a k=32 compressed apply (`span_overhead_check` in CI).
//!
//! Tracing is on by default; set `HISOLO_TRACE=off` (or call
//! `registry().set_enabled(false)`) to reduce every guard to a single
//! relaxed load with no clock reads. Flop/byte counters per stage are
//! compiled out unless the zero-dependency `obs-flops` cargo feature is
//! enabled; with it, kernels call [`count_flops`] and the counts attribute
//! to the innermost active span on the calling thread.
//!
//! On top of the aggregate registry, the [`recorder`] submodule adds a
//! *per-request* flight recorder: trace IDs minted at submission, span
//! timelines captured per scored batch into bounded lock-light rings,
//! tail sampling of the slowest traces, and Chrome/Perfetto trace-event
//! export (`hisolo serve --trace-out` / `hisolo trace`). See its module
//! docs for the memory bound and export schema.

pub mod histogram;
pub mod recorder;

pub use recorder::{FlightRecorder, RequestEvent, SpanEvent, TraceId};

use crate::util::json::{num, obj, Json};
use crate::util::timer::{fmt_ns, Table};
use histogram::LogHistogram;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Static stage IDs — the fixed taxonomy every span records under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    QueueWait,
    BucketForm,
    Spmm,
    HssWalk,
    LowRank,
    Attention,
    Mlp,
    Softmax,
    ReplyRoute,
    SwapInstall,
    /// cache-writing K/V quantize+store during a prefill layer
    KvPrefill,
    /// one decode layer: K/V append + paged attention
    KvDecode,
    /// widening a sequence's f16 pages into the gather staging
    PageGather,
}

impl Stage {
    pub const COUNT: usize = 13;

    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::QueueWait,
        Stage::BucketForm,
        Stage::Spmm,
        Stage::HssWalk,
        Stage::LowRank,
        Stage::Attention,
        Stage::Mlp,
        Stage::Softmax,
        Stage::ReplyRoute,
        Stage::SwapInstall,
        Stage::KvPrefill,
        Stage::KvDecode,
        Stage::PageGather,
    ];

    /// Stable snake_case name — the JSON export key and CI grep target.
    pub fn name(self) -> &'static str {
        match self {
            Stage::QueueWait => "queue_wait",
            Stage::BucketForm => "bucket_form",
            Stage::Spmm => "spmm",
            Stage::HssWalk => "hss_walk",
            Stage::LowRank => "lowrank",
            Stage::Attention => "attention",
            Stage::Mlp => "mlp",
            Stage::Softmax => "softmax",
            Stage::ReplyRoute => "reply_route",
            Stage::SwapInstall => "swap_install",
            Stage::KvPrefill => "kv_prefill",
            Stage::KvDecode => "kv_decode",
            Stage::PageGather => "page_gather",
        }
    }

    /// Dense index into per-stage arrays (`0..Stage::COUNT`).
    pub fn index(self) -> usize {
        self as usize
    }
}

/// Per-stage accumulators: exact count + total (ns) for precise means and
/// throughput math, a log-bucketed µs histogram for percentiles, and
/// (feature-gated) flop/byte counters so tokens/s and bytes/token are
/// derivable per stage.
pub struct StageStats {
    count: AtomicU64,
    total_ns: AtomicU64,
    hist: LogHistogram,
    #[cfg(feature = "obs-flops")]
    flops: AtomicU64,
    #[cfg(feature = "obs-flops")]
    bytes: AtomicU64,
}

impl StageStats {
    fn new() -> StageStats {
        StageStats {
            count: AtomicU64::new(0),
            total_ns: AtomicU64::new(0),
            hist: LogHistogram::new(),
            #[cfg(feature = "obs-flops")]
            flops: AtomicU64::new(0),
            #[cfg(feature = "obs-flops")]
            bytes: AtomicU64::new(0),
        }
    }

    fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
        self.total_ns.store(0, Ordering::Relaxed);
        self.hist.reset();
        #[cfg(feature = "obs-flops")]
        {
            self.flops.store(0, Ordering::Relaxed);
            self.bytes.store(0, Ordering::Relaxed);
        }
    }
}

/// The span registry: one [`StageStats`] per stage, all lock-free. Usually
/// accessed through the process-wide instance ([`registry`]); tests build
/// their own for exact-total assertions.
pub struct StageRegistry {
    stages: [StageStats; Stage::COUNT],
    enabled: AtomicBool,
    /// Bytes of activation-memory round-trips the fused serving epilogues
    /// avoided (residual-add + layernorm folded into one pass instead of
    /// three). Always on — one relaxed add per fused call — because the
    /// fusion win is a headline serving metric, unlike the per-kernel
    /// flop/byte attribution that stays behind `obs-flops`.
    fusion_saved: AtomicU64,
}

impl StageRegistry {
    pub fn new() -> StageRegistry {
        StageRegistry {
            stages: std::array::from_fn(|_| StageStats::new()),
            enabled: AtomicBool::new(true),
            fusion_saved: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Enable/disable span recording. Disabled guards skip the clock reads
    /// entirely, so a disabled registry costs one relaxed load per span.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_ns(&self, stage: Stage, ns: u64) {
        let s = &self.stages[stage.index()];
        s.count.fetch_add(1, Ordering::Relaxed);
        s.total_ns.fetch_add(ns, Ordering::Relaxed);
        s.hist.record_us(ns / 1_000);
    }

    #[inline]
    pub fn record(&self, stage: Stage, d: Duration) {
        self.record_ns(stage, d.as_nanos() as u64);
    }

    pub fn count(&self, stage: Stage) -> u64 {
        self.stages[stage.index()].count.load(Ordering::Relaxed)
    }

    pub fn total_ns(&self, stage: Stage) -> u64 {
        self.stages[stage.index()].total_ns.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self, stage: Stage) -> f64 {
        let c = self.count(stage);
        if c == 0 {
            0.0
        } else {
            self.total_ns(stage) as f64 / c as f64
        }
    }

    /// Approximate stage-duration percentile in µs (upper bucket bound).
    pub fn percentile_us(&self, stage: Stage, p: f64) -> u64 {
        self.stages[stage.index()].hist.percentile_us(p)
    }

    /// Spans recorded across all stages — the bench uses deltas of this to
    /// count spans fired by one instrumented call.
    pub fn total_count(&self) -> u64 {
        Stage::ALL.iter().map(|&s| self.count(s)).sum()
    }

    /// Flops attributed to `stage` via [`count_flops`] (0 unless the
    /// `obs-flops` feature is enabled).
    pub fn flops(&self, stage: Stage) -> u64 {
        #[cfg(feature = "obs-flops")]
        {
            self.stages[stage.index()].flops.load(Ordering::Relaxed)
        }
        #[cfg(not(feature = "obs-flops"))]
        {
            let _ = stage;
            0
        }
    }

    /// Bytes attributed to `stage` via [`count_flops`] (0 unless the
    /// `obs-flops` feature is enabled).
    pub fn bytes(&self, stage: Stage) -> u64 {
        #[cfg(feature = "obs-flops")]
        {
            self.stages[stage.index()].bytes.load(Ordering::Relaxed)
        }
        #[cfg(not(feature = "obs-flops"))]
        {
            let _ = stage;
            0
        }
    }

    #[cfg(feature = "obs-flops")]
    fn add_counters(&self, stage: Stage, flops: u64, bytes: u64) {
        let s = &self.stages[stage.index()];
        s.flops.fetch_add(flops, Ordering::Relaxed);
        s.bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Credit `bytes` of avoided activation-memory traffic to the fused
    /// epilogues. The fused call sites (see
    /// `transformer::fused_add_layernorm`) report the row round-trips the
    /// fusion skipped relative to the unfused three-pass sequence.
    pub fn add_fusion_saved_bytes(&self, bytes: u64) {
        self.fusion_saved.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Bytes of memory traffic avoided by fusion since the last reset.
    pub fn fusion_saved_bytes(&self) -> u64 {
        self.fusion_saved.load(Ordering::Relaxed)
    }

    /// Zero every stage (bench/test isolation; gauges elsewhere untouched).
    pub fn reset(&self) {
        for s in &self.stages {
            s.reset();
        }
        self.fusion_saved.store(0, Ordering::Relaxed);
    }

    /// Structured snapshot: `{stage_name: {count, total_us, mean_us,
    /// p50_us, p95_us, p99_us, p999_us}}` (+ `flops`/`bytes` under the
    /// `obs-flops` feature), plus the always-on top-level
    /// `bytes_saved_fusion` gauge. Key set is stable — BTreeMap order,
    /// fixed stage names.
    pub fn to_json(&self) -> Json {
        let mut stages = Vec::new();
        for &st in Stage::ALL.iter() {
            // mut is only exercised by the feature-gated pushes below
            #[cfg_attr(not(feature = "obs-flops"), allow(unused_mut))]
            let mut fields = vec![
                ("count", num(self.count(st) as f64)),
                ("total_us", num(self.total_ns(st) as f64 / 1e3)),
                ("mean_us", num(self.mean_ns(st) / 1e3)),
                ("p50_us", num(self.percentile_us(st, 0.50) as f64)),
                ("p95_us", num(self.percentile_us(st, 0.95) as f64)),
                ("p99_us", num(self.percentile_us(st, 0.99) as f64)),
                ("p999_us", num(self.percentile_us(st, 0.999) as f64)),
            ];
            #[cfg(feature = "obs-flops")]
            {
                fields.push(("flops", num(self.flops(st) as f64)));
                fields.push(("bytes", num(self.bytes(st) as f64)));
            }
            stages.push((st.name(), obj(fields)));
        }
        stages.push(("bytes_saved_fusion", num(self.fusion_saved_bytes() as f64)));
        obj(stages)
    }

    /// The per-stage latency-breakdown table printed in shutdown summaries.
    /// `share %` is each stage's total over the sum of all stage totals —
    /// a within-table share, not a wall-clock fraction (stages nest).
    pub fn table(&self) -> Table {
        let grand: u64 = Stage::ALL.iter().map(|&s| self.total_ns(s)).sum();
        let mut t = Table::new(&[
            "stage", "count", "total", "mean", "p50", "p99", "p999", "share %",
        ]);
        for &st in Stage::ALL.iter() {
            let total = self.total_ns(st);
            let share = if grand == 0 {
                0.0
            } else {
                100.0 * total as f64 / grand as f64
            };
            t.row(&[
                st.name().to_string(),
                self.count(st).to_string(),
                fmt_ns(total as f64),
                fmt_ns(self.mean_ns(st)),
                format!("{}us", self.percentile_us(st, 0.50)),
                format!("{}us", self.percentile_us(st, 0.99)),
                format!("{}us", self.percentile_us(st, 0.999)),
                format!("{share:.1}"),
            ]);
        }
        t
    }
}

impl Default for StageRegistry {
    fn default() -> Self {
        StageRegistry::new()
    }
}

static GLOBAL: OnceLock<StageRegistry> = OnceLock::new();

/// The process-wide span registry. First access honors `HISOLO_TRACE=off`
/// (or `0`) to start disabled; everything else starts enabled.
pub fn registry() -> &'static StageRegistry {
    GLOBAL.get_or_init(|| {
        let r = StageRegistry::new();
        if matches!(
            std::env::var("HISOLO_TRACE").as_deref(),
            Ok("off") | Ok("0")
        ) {
            r.set_enabled(false);
        }
        r
    })
}

#[cfg(feature = "obs-flops")]
thread_local! {
    /// Innermost-active-span stack: `count_flops` attributes to the top.
    static STAGE_STACK: std::cell::RefCell<Vec<Stage>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// RAII span guard: stamps `Instant::now()` on enter, records the elapsed
/// time into the global registry on drop. When tracing is disabled the
/// guard is inert (no clock reads). Bind it (`let _span = ...`) — `let _`
/// drops immediately and records a ~0ns span.
pub struct Span {
    stage: Stage,
    start: Option<Instant>,
}

impl Span {
    #[inline]
    pub fn enter(stage: Stage) -> Span {
        if !registry().enabled() {
            return Span { stage, start: None };
        }
        #[cfg(feature = "obs-flops")]
        STAGE_STACK.with(|s| s.borrow_mut().push(stage));
        Span {
            stage,
            start: Some(Instant::now()),
        }
    }
}

impl Drop for Span {
    #[inline]
    fn drop(&mut self) {
        if let Some(t0) = self.start {
            let dur = t0.elapsed();
            registry().record_ns(self.stage, dur.as_nanos() as u64);
            // per-request flight recording: one thread-local check when no
            // batch context is open on this thread (see `recorder`)
            recorder::note_span(self.stage, t0, dur);
            #[cfg(feature = "obs-flops")]
            STAGE_STACK.with(|s| {
                s.borrow_mut().pop();
            });
        }
    }
}

/// Attribute `flops` floating-point operations and `bytes` of weight
/// traffic to the innermost active span on this thread. Compiles to a
/// no-op unless the `obs-flops` feature is enabled, so kernels stay
/// stage-agnostic and cost nothing in default builds.
#[inline]
pub fn count_flops(flops: u64, bytes: u64) {
    #[cfg(feature = "obs-flops")]
    STAGE_STACK.with(|s| {
        if let Some(&st) = s.borrow().last() {
            registry().add_counters(st, flops, bytes);
        }
    });
    #[cfg(not(feature = "obs-flops"))]
    let _ = (flops, bytes);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_names_and_indices_stable() {
        assert_eq!(Stage::ALL.len(), Stage::COUNT);
        for (i, &s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
        }
        assert_eq!(Stage::QueueWait.name(), "queue_wait");
        assert_eq!(Stage::HssWalk.name(), "hss_walk");
        assert_eq!(Stage::ReplyRoute.name(), "reply_route");
    }

    #[test]
    fn record_and_query() {
        let r = StageRegistry::new();
        r.record_ns(Stage::Spmm, 5_000); // 5us
        r.record_ns(Stage::Spmm, 7_000);
        assert_eq!(r.count(Stage::Spmm), 2);
        assert_eq!(r.total_ns(Stage::Spmm), 12_000);
        assert!((r.mean_ns(Stage::Spmm) - 6_000.0).abs() < 1e-9);
        let p50 = r.percentile_us(Stage::Spmm, 0.5);
        assert!((4..=8).contains(&p50), "{p50}");
        assert_eq!(r.count(Stage::Attention), 0);
        assert_eq!(r.mean_ns(Stage::Attention), 0.0);
    }

    #[test]
    fn span_guard_records_into_global() {
        let reg = registry();
        let was = reg.enabled();
        reg.set_enabled(true);
        let before = reg.count(Stage::SwapInstall);
        {
            let _span = Span::enter(Stage::SwapInstall);
            std::hint::black_box(3 + 4);
        }
        // other parallel tests may also record; count only moves up
        assert!(reg.count(Stage::SwapInstall) > before);
        reg.set_enabled(was);
    }

    #[test]
    fn disabled_span_records_nothing() {
        // exercise the inert-guard path on a private registry by driving
        // the guard logic manually (the global one is shared with other
        // parallel tests, so "nothing changed" can't be asserted there)
        let r = StageRegistry::new();
        r.set_enabled(false);
        assert!(!r.enabled());
        if r.enabled() {
            r.record_ns(Stage::Mlp, 1);
        }
        assert_eq!(r.count(Stage::Mlp), 0);
    }

    /// Satellite: 8 threads hammer one registry; totals are exact, stage
    /// percentiles monotone, and the JSON key set stable across snapshots.
    #[test]
    fn concurrent_recording_exact_totals_and_stable_keys() {
        let r = std::sync::Arc::new(StageRegistry::new());
        let threads = 8;
        let per = 1_000u64;
        let keys_before = json_keys(&r.to_json());
        std::thread::scope(|s| {
            for t in 0..threads {
                let r = r.clone();
                s.spawn(move || {
                    for i in 0..per {
                        // spread across stages and buckets
                        let st = Stage::ALL[(t + i as usize) % Stage::COUNT];
                        r.record_ns(st, (i + 1) * 1_000);
                    }
                });
            }
        });
        assert_eq!(r.total_count(), threads as u64 * per);
        let total: u64 = Stage::ALL.iter().map(|&s| r.total_ns(s)).sum();
        // each thread records sum_{i=1..per} i*1000 ns
        let per_thread: u64 = (1..=per).map(|i| i * 1_000).sum();
        assert_eq!(total, threads as u64 * per_thread);
        for &st in Stage::ALL.iter() {
            let p50 = r.percentile_us(st, 0.50);
            let p99 = r.percentile_us(st, 0.99);
            let p999 = r.percentile_us(st, 0.999);
            assert!(p50 <= p99 && p99 <= p999, "{}: {p50} {p99} {p999}", st.name());
        }
        assert_eq!(json_keys(&r.to_json()), keys_before, "key set must be stable");
    }

    #[test]
    fn json_roundtrips_and_has_required_keys() {
        let r = StageRegistry::new();
        r.record_ns(Stage::HssWalk, 123_456);
        let j = r.to_json();
        let text = j.to_string();
        assert!(text.contains("\"hss_walk\""));
        assert!(text.contains("\"p999_us\""));
        assert!(text.contains("\"bytes_saved_fusion\""));
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn fusion_saved_bytes_accumulates_and_resets() {
        let r = StageRegistry::new();
        assert_eq!(r.fusion_saved_bytes(), 0);
        r.add_fusion_saved_bytes(256);
        r.add_fusion_saved_bytes(44);
        assert_eq!(r.fusion_saved_bytes(), 300);
        assert!(r
            .to_json()
            .to_string()
            .contains("\"bytes_saved_fusion\":300"));
        r.reset();
        assert_eq!(r.fusion_saved_bytes(), 0);
        assert!(r
            .to_json()
            .to_string()
            .contains("\"bytes_saved_fusion\":0"));
    }

    #[test]
    fn table_lists_every_stage() {
        let r = StageRegistry::new();
        r.record_ns(Stage::Attention, 1_000_000);
        let rendered = r.table().to_string();
        for &st in Stage::ALL.iter() {
            assert!(rendered.contains(st.name()), "{rendered}");
        }
        assert!(rendered.contains("100.0"), "{rendered}"); // attention holds all time
    }

    #[test]
    fn flop_counters_inert_or_attributed() {
        let reg = registry();
        let was = reg.enabled();
        reg.set_enabled(true);
        let before = reg.flops(Stage::Spmm);
        {
            let _span = Span::enter(Stage::Spmm);
            count_flops(640, 64);
        }
        let gained = reg.flops(Stage::Spmm) - before;
        if cfg!(feature = "obs-flops") {
            assert!(gained >= 640, "{gained}");
        } else {
            assert_eq!(gained, 0);
        }
        reg.set_enabled(was);
        // outside any span this must be a safe no-op either way
        count_flops(1, 1);
    }

    /// Recursively collect the key paths of a JSON value.
    fn json_keys(j: &Json) -> Vec<String> {
        fn walk(j: &Json, prefix: &str, out: &mut Vec<String>) {
            if let Json::Obj(m) = j {
                for (k, v) in m {
                    let path = if prefix.is_empty() {
                        k.clone()
                    } else {
                        format!("{prefix}.{k}")
                    };
                    walk(v, &path, out);
                    out.push(path);
                }
            }
        }
        let mut out = Vec::new();
        walk(j, "", &mut out);
        out.sort();
        out
    }
}
