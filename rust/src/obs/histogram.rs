//! Lock-free log2-bucketed histogram — the one bucket scheme shared by the
//! serving [`Metrics`](crate::coordinator::Metrics) latency histograms and
//! the per-stage span registry ([`crate::obs::StageRegistry`]), so every
//! percentile in the system is computed by the same walk over the same
//! bucket bounds.

use std::sync::atomic::{AtomicU64, Ordering};

/// log2 buckets over 1us .. ~1099s; bucket `i` holds values whose highest
/// set bit is `i`, i.e. `[2^i, 2^(i+1))` microseconds (values ≥ 2^39 us
/// saturate into the last bucket).
pub const BUCKETS: usize = 40;

/// Bucket index for a microsecond value (0 maps to bucket 0).
#[inline]
pub fn bucket_of(us: u64) -> usize {
    (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1)
}

/// Upper bound (us) of bucket `i` — what percentile queries report.
#[inline]
pub fn bucket_upper_us(i: usize) -> u64 {
    1u64 << (i + 1)
}

/// Percentile over a bucket-count snapshot: walks counts to the
/// `ceil(p·total)`-th sample and returns that bucket's upper bound; 0 when
/// empty. `p` in [0, 1].
pub fn percentile_from_counts(counts: &[u64], p: f64) -> u64 {
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return 0;
    }
    let want = ((p * total as f64).ceil() as u64).clamp(1, total);
    let mut acc = 0u64;
    for (i, c) in counts.iter().enumerate() {
        acc += c;
        if acc >= want {
            return bucket_upper_us(i);
        }
    }
    bucket_upper_us(counts.len() - 1)
}

/// A fixed-size log2 histogram of microsecond values. All operations are
/// relaxed atomics: concurrent recorders never contend on a lock, and
/// readers see a (possibly slightly stale) consistent-enough snapshot.
pub struct LogHistogram {
    buckets: [AtomicU64; BUCKETS],
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    #[inline]
    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_of(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of all bucket counts.
    pub fn counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.counts().iter().sum()
    }

    /// Approximate percentile (upper bucket bound), p in [0, 1]; 0 if empty.
    pub fn percentile_us(&self, p: f64) -> u64 {
        percentile_from_counts(&self.counts(), p)
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
    }
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_upper_us(0), 2);
        assert_eq!(bucket_upper_us(10), 2048);
    }

    #[test]
    fn percentiles_walk_and_saturate() {
        let h = LogHistogram::new();
        assert_eq!(h.percentile_us(0.99), 0);
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..100 {
                h.record_us(us);
            }
        }
        assert_eq!(h.count(), 500);
        let p50 = h.percentile_us(0.5);
        assert!((1000..=2048).contains(&p50), "{p50}");
        assert!(h.percentile_us(0.5) <= h.percentile_us(0.95));
        assert!(h.percentile_us(0.95) <= h.percentile_us(0.999));
    }

    #[test]
    fn empty_percentile_is_zero_at_every_p() {
        let h = LogHistogram::new();
        for p in [0.0, 0.5, 0.99, 0.999, 1.0] {
            assert_eq!(h.percentile_us(p), 0, "p={p}");
        }
        assert_eq!(percentile_from_counts(&[0u64; BUCKETS], 0.5), 0);
    }

    #[test]
    fn single_sample_dominates_every_percentile() {
        let h = LogHistogram::new();
        h.record_us(300); // bucket 8: [256, 512)
        assert_eq!(h.count(), 1);
        // With one sample, every percentile (including p=0, which clamps
        // `want` up to 1) lands on that sample's bucket upper bound.
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.percentile_us(p), 512, "p={p}");
        }
    }

    #[test]
    fn top_bucket_saturates_beyond_range() {
        let h = LogHistogram::new();
        // All of these exceed the 2^39 us bucket-39 lower bound — the log2
        // range is exhausted, so they must pile into the last bucket rather
        // than index out of bounds.
        for us in [1u64 << 39, 1u64 << 45, u64::MAX] {
            assert_eq!(bucket_of(us), BUCKETS - 1, "us={us}");
            h.record_us(us);
        }
        let counts = h.counts();
        assert_eq!(counts[BUCKETS - 1], 3);
        assert_eq!(counts[..BUCKETS - 1].iter().sum::<u64>(), 0);
        // Saturated percentile reports the top bucket's upper bound, 2^40.
        assert_eq!(h.percentile_us(0.99), 1u64 << 40);
        // The final fallback return (acc never reaching `want` is impossible,
        // but the explicit tail) agrees with the same bound.
        assert_eq!(bucket_upper_us(BUCKETS - 1), 1u64 << 40);
    }

    #[test]
    fn reset_clears() {
        let h = LogHistogram::new();
        h.record_us(7);
        assert_eq!(h.count(), 1);
        h.reset();
        assert_eq!(h.count(), 0);
    }
}
