//! Per-request flight recorder: trace IDs end-to-end, lock-light span
//! rings, tail sampling, and Chrome/Perfetto trace-event export.
//!
//! The stage registry ([`super::StageRegistry`]) aggregates — it can say
//! `hss_walk` holds 60% of served microseconds, but not *why this specific
//! p99 request was slow*. The flight recorder answers that question:
//!
//! - every [`crate::coordinator::ScoreRequest`] is minted a process-unique,
//!   monotone [`TraceId`] at submission, carried through batcher → bucket →
//!   worker → reply;
//! - the worker opens a **batch context** ([`FlightRecorder::begin_batch`])
//!   around each scored chunk: every [`super::Span`] guard that fires on
//!   that thread while the context is open (one `hss_walk`, `attention`,
//!   `mlp`, … per kernel call) is captured as a timestamped event tagged
//!   with the batch id — a batch span thereby attributes to *all* trace IDs
//!   the batch served, which is the truthful cost model of batched serving;
//! - at reply time each request contributes a [`RequestEvent`] (submit
//!   offset, queue/service split, window length, variant, error flag)
//!   keyed by both its trace and its batch, so offline tools can join
//!   requests to the kernel work that served them.
//!
//! # Memory bound and wraparound
//!
//! Events land in fixed-capacity rings of atomic words (default
//! [`SPAN_RING_CAP`] span slots + [`REQ_RING_CAP`] request slots, ~3 MiB
//! total): writers reserve a slot with one `fetch_add` and publish it
//! seqlock-style (odd seq while writing, even when done), so recording
//! never takes a lock and never allocates on the hot path. When the ring
//! wraps, the oldest events are overwritten — except that **tail
//! sampling** keeps a separate bounded reserve ([`TAIL_TRACES`] traces) of
//! the slowest requests seen so far *with a copy of their batch's spans*,
//! so the export always contains the timeline of the slowest-percentile
//! traces even after hours of wraparound. Per-batch span capture is
//! bounded by [`MAX_BATCH_SPANS`]; overflow is counted, not recorded.
//!
//! # Export schema
//!
//! [`FlightRecorder::export`] emits Chrome trace-event JSON (loadable in
//! Perfetto / `chrome://tracing`): `{"traceEvents": [...]}` where every
//! event is a `"ph":"X"` complete event with `ts`/`dur` in microseconds
//! since the recorder epoch. Requests render as two events on `pid` 1
//! (one track per trace id): `request` spanning submit → reply and
//! `queue_wait` spanning its queue share, with
//! `args: {trace, batch, len, variant, queue_us, service_us,
//! tail_sampled, error}`. Stage spans render on `pid` 2, one track per
//! worker thread, with `args: {batch}` as the join key. `hisolo trace
//! <file>` consumes the same schema offline to print per-trace critical
//! paths and per-bucket stage breakdowns.
//!
//! Recording is off by default (zero cost beyond one thread-local check
//! per span); `hisolo serve --trace-out <path>` switches it on. With
//! `HISOLO_TRACE=off` the span guards themselves are inert, so a trace
//! taken that way contains request lifecycles but no kernel spans.

use super::Stage;
use crate::util::json::{num, obj, s, Json};
use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Span-ring capacity (slots) of the process-wide recorder.
pub const SPAN_RING_CAP: usize = 65_536;
/// Request-ring capacity (slots) of the process-wide recorder.
pub const REQ_RING_CAP: usize = 16_384;
/// Max spans captured per batch context; overflow is counted as dropped.
pub const MAX_BATCH_SPANS: usize = 4_096;
/// Slow traces retained by tail sampling (top-N by end-to-end latency).
pub const TAIL_TRACES: usize = 32;

/// Process-unique, monotone per-request trace identifier. Minted once at
/// `Coordinator::submit` and propagated on both the request and the reply,
/// so every hop of a request's life can be joined offline.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceId(pub u64);

static NEXT_TRACE: AtomicU64 = AtomicU64::new(0);

impl TraceId {
    /// Mint the next trace id: strictly monotone and unique process-wide
    /// (ids from concurrent minters never collide, and each thread's
    /// sequence of minted ids is increasing).
    pub fn next() -> TraceId {
        TraceId(NEXT_TRACE.fetch_add(1, Ordering::Relaxed) + 1)
    }
}

/// One kernel-stage span captured inside a batch context.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    /// Batch (scored chunk) this span served — the join key to requests.
    pub batch: u64,
    pub stage: Stage,
    /// Worker-thread number (small dense id, for the export's `tid`).
    pub tid: u64,
    /// Start offset from the recorder epoch, µs.
    pub start_us: u64,
    pub dur_us: u64,
}

/// One request's completed lifecycle, recorded at reply time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestEvent {
    pub trace: TraceId,
    /// Batch that scored it (0 until [`FlightRecorder::end_batch`] stamps it).
    pub batch: u64,
    /// Submit-instant offset from the recorder epoch, µs.
    pub submit_us: u64,
    pub queue_us: u64,
    pub service_us: u64,
    /// Window length in tokens (the offline bucket key).
    pub window_len: u32,
    /// `Variant::index()` of the serving lane.
    pub variant: u8,
    pub error: bool,
}

impl RequestEvent {
    /// End-to-end latency: the worker computes it as exactly queue + service.
    pub fn latency_us(&self) -> u64 {
        self.queue_us + self.service_us
    }
}

// --- lock-light ring ------------------------------------------------------

/// Seqlock slot: `seq` is 0 when never written, `2·idx+1` while record
/// `idx` is being written, `2·idx+2` once it is published. Readers accept
/// a slot only when they observe the same even, nonzero seq before and
/// after reading the payload words — a torn read (writer lapping the
/// reader) is detected and skipped, never returned.
struct Slot<const W: usize> {
    seq: AtomicU64,
    words: [AtomicU64; W],
}

struct AtomicRing<const W: usize> {
    slots: Vec<Slot<W>>,
    /// Total records ever pushed (slot = head % capacity).
    head: AtomicU64,
}

impl<const W: usize> AtomicRing<W> {
    fn new(cap: usize) -> AtomicRing<W> {
        AtomicRing {
            slots: (0..cap.max(1))
                .map(|_| Slot {
                    seq: AtomicU64::new(0),
                    words: std::array::from_fn(|_| AtomicU64::new(0)),
                })
                .collect(),
            head: AtomicU64::new(0),
        }
    }

    fn push(&self, words: [u64; W]) {
        let idx = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(idx % self.slots.len() as u64) as usize];
        slot.seq.store(2 * idx + 1, Ordering::Release);
        for (w, v) in slot.words.iter().zip(words) {
            w.store(v, Ordering::Relaxed);
        }
        slot.seq.store(2 * idx + 2, Ordering::Release);
    }

    /// Total records ever pushed (≥ what the ring still holds).
    fn pushed(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Consistent snapshot of the surviving records, oldest first.
    fn drain(&self) -> Vec<[u64; W]> {
        let mut out: Vec<(u64, [u64; W])> = Vec::new();
        for slot in &self.slots {
            let s1 = slot.seq.load(Ordering::Acquire);
            if s1 == 0 || s1 % 2 == 1 {
                continue; // never written, or mid-write
            }
            let words = std::array::from_fn(|i| slot.words[i].load(Ordering::Relaxed));
            let s2 = slot.seq.load(Ordering::Acquire);
            if s1 != s2 {
                continue; // torn by a lapping writer
            }
            out.push(((s1 - 2) / 2, words));
        }
        out.sort_unstable_by_key(|(idx, _)| *idx);
        out.into_iter().map(|(_, w)| w).collect()
    }

    fn reset(&self) {
        for slot in &self.slots {
            slot.seq.store(0, Ordering::Relaxed);
        }
        self.head.store(0, Ordering::Relaxed);
    }
}

// --- batch context --------------------------------------------------------

struct BatchCtx {
    batch: u64,
    tid: u64,
    epoch: Instant,
    spans: Vec<SpanEvent>,
    dropped: u64,
}

thread_local! {
    static CTX: RefCell<Option<BatchCtx>> = const { RefCell::new(None) };
    static WORKER_TID: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

static NEXT_TID: AtomicU64 = AtomicU64::new(0);

fn worker_tid() -> u64 {
    WORKER_TID.with(|t| {
        if t.get() == 0 {
            t.set(NEXT_TID.fetch_add(1, Ordering::Relaxed) + 1);
        }
        t.get()
    })
}

/// Capture one finished span into the thread's open batch context, if any.
/// Called from [`super::Span`]'s drop; one thread-local check when no
/// context is open, so idle cost is independent of recorder state.
#[inline]
pub(crate) fn note_span(stage: Stage, start: Instant, dur: Duration) {
    CTX.with(|c| {
        if let Some(ctx) = c.borrow_mut().as_mut() {
            if ctx.spans.len() >= MAX_BATCH_SPANS {
                ctx.dropped += 1;
                return;
            }
            ctx.spans.push(SpanEvent {
                batch: ctx.batch,
                stage,
                tid: ctx.tid,
                start_us: start
                    .checked_duration_since(ctx.epoch)
                    .unwrap_or_default()
                    .as_micros() as u64,
                dur_us: dur.as_micros() as u64,
            });
        }
    });
}

/// Open-batch handle returned by [`FlightRecorder::begin_batch`]. Pass it
/// back to [`FlightRecorder::end_batch`] with the batch's request events;
/// if the batch is abandoned (panic, early return) the drop impl clears
/// the thread-local context so later batches don't inherit stale spans.
pub struct BatchGuard {
    batch: u64,
    active: bool,
}

impl BatchGuard {
    /// Whether this batch is actually recording (false when the recorder
    /// is disabled — callers can skip building [`RequestEvent`]s).
    pub fn active(&self) -> bool {
        self.active
    }
}

impl Drop for BatchGuard {
    fn drop(&mut self) {
        if self.active {
            CTX.with(|c| *c.borrow_mut() = None);
        }
    }
}

// --- tail sampling --------------------------------------------------------

#[derive(Clone)]
struct SlowTrace {
    req: RequestEvent,
    /// The serving batch's spans, shared across slow members of one batch.
    spans: Arc<Vec<SpanEvent>>,
}

// --- the recorder ---------------------------------------------------------

/// The flight recorder: see the module docs for the full story. One
/// process-wide instance lives behind [`recorder`]; tests build their own
/// with [`FlightRecorder::with_caps`] for exact assertions.
pub struct FlightRecorder {
    enabled: AtomicBool,
    epoch: Instant,
    next_batch: AtomicU64,
    spans: AtomicRing<4>,
    reqs: AtomicRing<6>,
    tail: Mutex<Vec<SlowTrace>>,
    tail_cap: usize,
    dropped_spans: AtomicU64,
}

impl FlightRecorder {
    pub fn new() -> FlightRecorder {
        FlightRecorder::with_caps(SPAN_RING_CAP, REQ_RING_CAP, TAIL_TRACES)
    }

    /// Recorder with explicit ring / tail capacities (tests exercise
    /// wraparound with tiny rings).
    pub fn with_caps(span_cap: usize, req_cap: usize, tail_cap: usize) -> FlightRecorder {
        FlightRecorder {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            next_batch: AtomicU64::new(0),
            spans: AtomicRing::new(span_cap),
            reqs: AtomicRing::new(req_cap),
            tail: Mutex::new(Vec::new()),
            tail_cap,
            dropped_spans: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Switch recording on/off. Off (the default) makes [`begin_batch`]
    /// return an inert guard, so the serving path's only recording cost is
    /// one relaxed load per batch plus one thread-local check per span.
    ///
    /// [`begin_batch`]: FlightRecorder::begin_batch
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Microsecond offset of `t` from the recorder epoch (0 if earlier).
    pub fn offset_us(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch)
            .unwrap_or_default()
            .as_micros() as u64
    }

    /// Open a batch context on the calling thread: until the matching
    /// [`FlightRecorder::end_batch`], every span fired on this thread is
    /// captured and attributed to this batch.
    pub fn begin_batch(&self) -> BatchGuard {
        if !self.enabled() {
            return BatchGuard { batch: 0, active: false };
        }
        let batch = self.next_batch.fetch_add(1, Ordering::Relaxed) + 1;
        CTX.with(|c| {
            *c.borrow_mut() = Some(BatchCtx {
                batch,
                tid: worker_tid(),
                epoch: self.epoch,
                spans: Vec::new(),
                dropped: 0,
            })
        });
        BatchGuard { batch, active: true }
    }

    /// Close a batch context: flush its captured spans into the span ring,
    /// record each member request (its `batch` field is stamped here), and
    /// offer every member to the tail reserve — the slowest
    /// [`TAIL_TRACES`]-by-latency requests keep a copy of the batch's
    /// spans that outlives ring wraparound.
    pub fn end_batch(&self, mut guard: BatchGuard, completions: &[RequestEvent]) {
        if !guard.active {
            return;
        }
        guard.active = false;
        let ctx = match CTX.with(|c| c.borrow_mut().take()) {
            Some(ctx) if ctx.batch == guard.batch => ctx,
            _ => return, // nested/foreign context; nothing safe to flush
        };
        for ev in &ctx.spans {
            let packed = ev.stage.index() as u64 | (ev.tid << 8);
            self.spans.push([ev.batch, packed, ev.start_us, ev.dur_us]);
        }
        if ctx.dropped > 0 {
            self.dropped_spans.fetch_add(ctx.dropped, Ordering::Relaxed);
        }
        let shared: Arc<Vec<SpanEvent>> = Arc::new(ctx.spans);
        let mut tail = self.tail.lock().unwrap();
        for c in completions {
            let mut ev = *c;
            ev.batch = guard.batch;
            self.reqs.push([
                ev.trace.0,
                ev.batch,
                ev.submit_us,
                ev.queue_us,
                ev.service_us,
                ev.window_len as u64 | ((ev.variant as u64) << 32) | ((ev.error as u64) << 40),
            ]);
            // tail sampling: keep the top-N slowest requests seen so far
            if tail.len() < self.tail_cap {
                tail.push(SlowTrace { req: ev, spans: shared.clone() });
            } else {
                let min = tail.iter().enumerate().min_by_key(|(_, t)| t.req.latency_us());
                if let Some((mi, _)) = min {
                    if ev.latency_us() > tail[mi].req.latency_us() {
                        tail[mi] = SlowTrace { req: ev, spans: shared.clone() };
                    }
                }
            }
        }
    }

    /// Surviving span events, oldest first (ring snapshot).
    pub fn span_events(&self) -> Vec<SpanEvent> {
        self.spans
            .drain()
            .into_iter()
            .map(|[batch, packed, start_us, dur_us]| SpanEvent {
                batch,
                stage: Stage::ALL[(packed & 0xff) as usize % Stage::COUNT],
                tid: packed >> 8,
                start_us,
                dur_us,
            })
            .collect()
    }

    /// Surviving request events, oldest first (ring snapshot).
    pub fn request_events(&self) -> Vec<RequestEvent> {
        self.reqs
            .drain()
            .into_iter()
            .map(|[trace, batch, submit_us, queue_us, service_us, packed]| RequestEvent {
                trace: TraceId(trace),
                batch,
                submit_us,
                queue_us,
                service_us,
                window_len: (packed & 0xffff_ffff) as u32,
                variant: ((packed >> 32) & 0xff) as u8,
                error: (packed >> 40) & 1 == 1,
            })
            .collect()
    }

    /// Trace ids currently held by the tail reserve (slowest-N).
    pub fn tail_traces(&self) -> Vec<TraceId> {
        self.tail.lock().unwrap().iter().map(|t| t.req.trace).collect()
    }

    /// Spans dropped by per-batch capture overflow ([`MAX_BATCH_SPANS`]).
    pub fn dropped_spans(&self) -> u64 {
        self.dropped_spans.load(Ordering::Relaxed)
    }

    /// Total span / request events ever recorded (including overwritten).
    pub fn recorded(&self) -> (u64, u64) {
        (self.spans.pushed(), self.reqs.pushed())
    }

    /// Clear everything (bench/test isolation).
    pub fn reset(&self) {
        self.spans.reset();
        self.reqs.reset();
        self.tail.lock().unwrap().clear();
        self.dropped_spans.store(0, Ordering::Relaxed);
    }

    /// Build the Chrome trace-event export (see the module docs for the
    /// schema). Ring survivors and the tail reserve are merged — a trace
    /// whose ring slots were overwritten still exports completely if it
    /// was tail-sampled — and every tail-sampled request is flagged
    /// `tail_sampled: true` in its args.
    pub fn export(&self) -> TraceExport {
        let tail: Vec<SlowTrace> = self.tail.lock().unwrap().clone();
        let tail_set: BTreeSet<u64> = tail.iter().map(|t| t.req.trace.0).collect();

        // batch -> spans: ring survivors first, tail copies fill the gaps
        let mut by_batch: BTreeMap<u64, Vec<SpanEvent>> = BTreeMap::new();
        for ev in self.span_events() {
            by_batch.entry(ev.batch).or_default().push(ev);
        }
        for t in &tail {
            by_batch
                .entry(t.req.batch)
                .or_insert_with(|| t.spans.as_ref().clone());
        }
        // trace -> request: ring survivors first, tail fills the gaps
        let mut by_trace: BTreeMap<u64, RequestEvent> = BTreeMap::new();
        for ev in self.request_events() {
            by_trace.insert(ev.trace.0, ev);
        }
        for t in &tail {
            by_trace.entry(t.req.trace.0).or_insert(t.req);
        }

        let mut events: Vec<Json> = vec![
            meta_event(1, "requests (one track per trace id)"),
            meta_event(2, "workers (stage spans per thread)"),
        ];
        let mut tail_sampled = 0usize;
        for (tid, req) in &by_trace {
            let tailed = tail_set.contains(tid);
            tail_sampled += tailed as usize;
            events.push(obj(vec![
                ("name", s("request")),
                ("cat", s("request")),
                ("ph", s("X")),
                ("ts", num(req.submit_us as f64)),
                ("dur", num(req.latency_us() as f64)),
                ("pid", num(1.0)),
                ("tid", num(*tid as f64)),
                (
                    "args",
                    obj(vec![
                        ("trace", num(*tid as f64)),
                        ("batch", num(req.batch as f64)),
                        ("len", num(req.window_len as f64)),
                        ("variant", num(req.variant as f64)),
                        ("queue_us", num(req.queue_us as f64)),
                        ("service_us", num(req.service_us as f64)),
                        ("tail_sampled", Json::Bool(tailed)),
                        ("error", Json::Bool(req.error)),
                    ]),
                ),
            ]));
            events.push(obj(vec![
                ("name", s("queue_wait")),
                ("cat", s("request")),
                ("ph", s("X")),
                ("ts", num(req.submit_us as f64)),
                ("dur", num(req.queue_us as f64)),
                ("pid", num(1.0)),
                ("tid", num(*tid as f64)),
                ("args", obj(vec![("batch", num(req.batch as f64))])),
            ]));
        }
        let mut span_events = 0usize;
        for spans in by_batch.values() {
            for ev in spans {
                span_events += 1;
                events.push(obj(vec![
                    ("name", s(ev.stage.name())),
                    ("cat", s("stage")),
                    ("ph", s("X")),
                    ("ts", num(ev.start_us as f64)),
                    ("dur", num(ev.dur_us as f64)),
                    ("pid", num(2.0)),
                    ("tid", num(ev.tid as f64)),
                    ("args", obj(vec![("batch", num(ev.batch as f64))])),
                ]));
            }
        }
        let requests = by_trace.len();
        TraceExport {
            json: obj(vec![
                ("displayTimeUnit", s("ms")),
                ("traceEvents", Json::Arr(events)),
            ]),
            span_events,
            requests,
            tail_sampled,
            dropped_spans: self.dropped_spans(),
        }
    }
}

impl Default for FlightRecorder {
    fn default() -> Self {
        FlightRecorder::new()
    }
}

fn meta_event(pid: u64, name: &str) -> Json {
    obj(vec![
        ("name", s("process_name")),
        ("ph", s("M")),
        ("pid", num(pid as f64)),
        ("tid", num(0.0)),
        ("args", obj(vec![("name", s(name))])),
    ])
}

/// The export payload plus its headline counts (for serve's summary line).
pub struct TraceExport {
    pub json: Json,
    pub span_events: usize,
    pub requests: usize,
    pub tail_sampled: usize,
    pub dropped_spans: u64,
}

static GLOBAL: OnceLock<FlightRecorder> = OnceLock::new();

/// The process-wide flight recorder (disabled until someone calls
/// [`FlightRecorder::set_enabled`] — `hisolo serve --trace-out` does).
pub fn recorder() -> &'static FlightRecorder {
    GLOBAL.get_or_init(FlightRecorder::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Span;

    fn req(trace: u64, queue_us: u64, service_us: u64) -> RequestEvent {
        RequestEvent {
            trace: TraceId(trace),
            batch: 0,
            submit_us: 0,
            queue_us,
            service_us,
            window_len: 33,
            variant: 1,
            error: false,
        }
    }

    #[test]
    fn trace_ids_unique_and_monotone_across_8_threads() {
        let per = 500usize;
        let mut all: Vec<Vec<u64>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    s.spawn(move || {
                        let ids: Vec<u64> = (0..per).map(|_| TraceId::next().0).collect();
                        // per-thread: strictly monotone
                        assert!(ids.windows(2).all(|w| w[0] < w[1]));
                        ids
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut flat: Vec<u64> = all.drain(..).flatten().collect();
        flat.sort_unstable();
        flat.dedup();
        assert_eq!(flat.len(), 8 * per, "trace ids must be unique");
    }

    #[test]
    fn batch_ctx_captures_spans_and_fans_out_to_members() {
        let r = FlightRecorder::with_caps(64, 64, 8);
        r.set_enabled(true);
        let reg = crate::obs::registry();
        let was = reg.enabled();
        reg.set_enabled(true);
        let g = r.begin_batch();
        assert!(g.active());
        {
            let _a = Span::enter(Stage::HssWalk);
            let _b = Span::enter(Stage::Spmm);
        }
        r.end_batch(g, &[req(101, 10, 90), req(102, 20, 80)]);
        reg.set_enabled(was);

        let spans = r.span_events();
        assert_eq!(spans.len(), 2);
        let batch = spans[0].batch;
        assert!(batch > 0);
        assert!(spans.iter().all(|e| e.batch == batch));
        let stages: Vec<Stage> = spans.iter().map(|e| e.stage).collect();
        assert!(stages.contains(&Stage::HssWalk) && stages.contains(&Stage::Spmm));

        // both member requests share the batch id — the fan-out join key
        let reqs = r.request_events();
        assert_eq!(reqs.len(), 2);
        assert!(reqs.iter().all(|e| e.batch == batch));
        assert_eq!(reqs[0].trace, TraceId(101));
        assert_eq!(reqs[1].trace, TraceId(102));
        assert_eq!(reqs[0].latency_us(), 100);
        assert_eq!(reqs[0].window_len, 33);
        assert_eq!(reqs[0].variant, 1);
        assert!(!reqs[0].error);
    }

    #[test]
    fn disabled_recorder_is_inert() {
        let r = FlightRecorder::with_caps(8, 8, 2);
        assert!(!r.enabled());
        let g = r.begin_batch();
        assert!(!g.active());
        r.end_batch(g, &[req(1, 1, 1)]);
        assert!(r.span_events().is_empty());
        assert!(r.request_events().is_empty());
        assert!(r.tail_traces().is_empty());
    }

    /// Satellite: ring wraparound under 8 concurrent writers — every
    /// drained record is consistent (no torn reads), capacity bounds hold,
    /// and the slowest trace survives the wrap via the tail reserve.
    #[test]
    fn ring_wraparound_under_8_concurrent_writers() {
        let cap = 64usize;
        let r = std::sync::Arc::new(FlightRecorder::with_caps(cap, cap, 4));
        r.set_enabled(true);
        let per = 200usize;
        std::thread::scope(|sc| {
            for t in 0..8u64 {
                let r = r.clone();
                sc.spawn(move || {
                    for i in 0..per {
                        let g = r.begin_batch();
                        // bypass Span guards (global registry state is
                        // shared with parallel tests): capture directly
                        note_span(
                            Stage::ALL[i % Stage::COUNT],
                            Instant::now(),
                            Duration::from_micros(5),
                        );
                        let trace = TraceId::next();
                        // one request per batch; thread 7's last request is
                        // made very slow so tail sampling must keep it
                        let slow = (t == 7 && i == per - 1) as u64;
                        r.end_batch(
                            g,
                            &[RequestEvent {
                                trace,
                                batch: 0,
                                submit_us: 0,
                                queue_us: 1 + slow * 1_000_000,
                                service_us: 1,
                                window_len: 9,
                                variant: 0,
                                error: false,
                            }],
                        );
                    }
                });
            }
        });
        let (spans_pushed, reqs_pushed) = r.recorded();
        assert_eq!(spans_pushed, 8 * per as u64);
        assert_eq!(reqs_pushed, 8 * per as u64);
        let spans = r.span_events();
        let reqs = r.request_events();
        assert!(spans.len() <= cap, "{}", spans.len());
        assert!(reqs.len() <= cap);
        assert!(!reqs.is_empty());
        // consistency: every surviving record decodes to sane fields
        for e in &reqs {
            assert!(e.trace.0 > 0 && e.batch > 0 && e.window_len == 9);
        }
        for e in &spans {
            assert!(e.batch > 0 && e.dur_us >= 5);
        }
        // the slow outlier survived the wrap in the tail reserve
        let tail: Vec<TraceId> = r.tail_traces();
        assert!(!tail.is_empty() && tail.len() <= 4);
        let export = r.export();
        assert!(export.tail_sampled >= 1);
        let text = export.json.to_string();
        assert!(text.contains("\"ph\":\"X\""));
        // the tail-sampled slow request exports with its batch's spans
        // even though its ring slots were overwritten long ago
        assert!(text.contains("\"tail_sampled\":true"), "{text}");
    }

    #[test]
    fn tail_reserve_keeps_the_slowest() {
        let r = FlightRecorder::with_caps(16, 16, 2);
        r.set_enabled(true);
        for (trace, lat) in [(1u64, 10u64), (2, 500), (3, 20), (4, 900), (5, 30)] {
            let g = r.begin_batch();
            r.end_batch(g, &[req(trace, 0, lat)]);
        }
        let mut tail: Vec<u64> = r.tail_traces().iter().map(|t| t.0).collect();
        tail.sort_unstable();
        assert_eq!(tail, vec![2, 4], "top-2 by latency");
    }

    #[test]
    fn export_schema_has_duration_events_and_roundtrips() {
        let r = FlightRecorder::with_caps(32, 32, 4);
        r.set_enabled(true);
        let reg = crate::obs::registry();
        let was = reg.enabled();
        reg.set_enabled(true);
        let g = r.begin_batch();
        {
            let _a = Span::enter(Stage::Attention);
        }
        r.end_batch(g, &[req(7, 40, 60)]);
        reg.set_enabled(was);
        let export = r.export();
        assert_eq!(export.requests, 1);
        assert!(export.span_events >= 1);
        let text = export.json.to_string();
        // Perfetto-loadable: traceEvents array of ph:X complete events
        assert!(text.contains("\"traceEvents\""));
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"attention\""));
        assert!(text.contains("\"queue_wait\""));
        let back = Json::parse(&text).unwrap();
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(evs.len() >= 4); // 2 meta + request + queue_wait + span
        // request event joins to its batch through args.batch
        let req_ev = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("request"))
            .unwrap();
        let arg_batch = |e: &Json| {
            e.get("args")
                .and_then(|a| a.get("batch"))
                .and_then(|b| b.as_f64())
                .unwrap()
        };
        let batch = arg_batch(req_ev);
        let span_ev = evs
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("attention"))
            .unwrap();
        assert_eq!(arg_batch(span_ev), batch);
    }

    #[test]
    fn batch_span_capture_is_bounded() {
        let r = FlightRecorder::with_caps(8, 8, 2);
        r.set_enabled(true);
        let g = r.begin_batch();
        for _ in 0..(MAX_BATCH_SPANS + 10) {
            note_span(Stage::Spmm, Instant::now(), Duration::from_micros(1));
        }
        r.end_batch(g, &[]);
        assert_eq!(r.dropped_spans(), 10);
    }

    #[test]
    fn abandoned_batch_clears_thread_context() {
        let r = FlightRecorder::with_caps(8, 8, 2);
        r.set_enabled(true);
        {
            let _g = r.begin_batch(); // dropped without end_batch
        }
        // a fresh batch starts clean: no stale spans from the abandoned one
        let g2 = r.begin_batch();
        r.end_batch(g2, &[req(9, 1, 1)]);
        assert!(r.span_events().is_empty());
        assert_eq!(r.request_events().len(), 1);
    }
}
