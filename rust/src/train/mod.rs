//! End-to-end fine-tuning of compressed factors — the paper's claim that
//! the hierarchical sparse-plus-low-rank representation "can be trained
//! end-to-end with standard optimisers", made concrete.
//!
//! One-shot compression (top-k + SVD/rSVD, `compress::Compressor`) fixes
//! the *structure* — sparsity patterns, tree shape, permutations, ranks —
//! and this module recovers *accuracy* by training the surviving values
//! against the dense teacher:
//!
//! - [`grad`] — batched backward passes for every `CompressedMatrix`
//!   variant over [n, k] sample blocks: CSR value gradients under a
//!   frozen pattern (k-wide dots), low-rank L/R factor gradients as
//!   rank-k GEMM updates, and a recursive matrix-Jacobian product through
//!   the HSS tree (leaves, U/R couplings, spike values), with per-level
//!   scratch reuse mirroring the apply `Workspace` so the hot loop is
//!   allocation-free after warmup. Also owns the canonical flat parameter
//!   view (`visit_params`, `copy_params`, `load_params`).
//! - [`optim`] — SGD (+momentum) and Adam (bias-corrected) over that flat
//!   view.
//! - [`calibrate`] — the layer-wise loop: minimise ‖W x − Ŵ x‖² over
//!   batches of post-ln1 activations captured from corpus windows
//!   (`Transformer::qkv_inputs`), cosine LR decay, early stopping on a
//!   held-out split, best-checkpoint restore, per-layer progress via
//!   `util::logging`.
//!
//! The refined factors flow back out through the existing deployment
//! story: `compress::pipeline::refine_reports` updates layer reports in
//! place, `ModelStore::save_model` persists the result as a new `HSB1`
//! variant, and `Coordinator::swap_variant` hot-swaps it under live
//! traffic — compress once, refine offline, swap without downtime.

pub mod calibrate;
pub mod grad;
pub mod optim;

pub use calibrate::{
    calibrate_matrix, calibrate_model, calibrate_model_with, collect_activations,
    CalibrationReport, TrainConfig,
};
pub use grad::{
    accumulate_grad, copy_params, load_params, num_params, visit_params, visit_params_mut,
    GradWorkspace,
};
pub use optim::{Adam, Optimizer, OptimizerKind, Sgd};
