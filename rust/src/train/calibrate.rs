//! Layer-wise calibration: fine-tune a compressed matrix's factors against
//! its dense teacher on real activations.
//!
//! The objective per projection is the reconstruction loss the
//! sparse-plus-low-rank literature calibrates with (HASSLE-free's
//! layer-wise ‖W x − Ŵ x‖², arXiv 2502.00899): activations x are drawn
//! from the base model's forward pass over corpus windows
//! ([`crate::model::Transformer::qkv_inputs`]), targets are the dense
//! teacher's outputs, and only factor *values* train — sparsity patterns
//! and permutations stay frozen.
//!
//! The loop is the standard recipe: mini-batch gradients through
//! `train::grad`, an optimizer from `train::optim`, cosine LR decay from
//! `lr` down to `lr · min_lr_frac`, periodic evaluation on a held-out
//! split with early stopping, and best-checkpoint restore so a noisy tail
//! can never leave the matrix worse than its best seen state.

use crate::compress::CompressedMatrix;
use crate::linalg::Matrix;
use crate::model::CompressedModel;
use crate::train::grad::{
    accumulate_grad, copy_params_into, load_params, num_params, GradWorkspace,
};
use crate::train::optim::{Optimizer, OptimizerKind};
use crate::util::rng::Rng;

/// Hyper-parameters of one calibration run (shared by every projection).
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    /// max optimizer steps per projection
    pub steps: usize,
    /// samples per mini-batch
    pub batch: usize,
    /// peak learning rate
    pub lr: f32,
    /// cosine floor as a fraction of `lr`
    pub min_lr_frac: f32,
    pub optimizer: OptimizerKind,
    /// fraction of samples held out for early stopping
    pub holdout_frac: f64,
    /// evaluate the holdout split every this many steps
    pub eval_every: usize,
    /// stop after this many evaluations without improvement
    pub patience: usize,
    pub seed: u64,
    /// threads for the per-projection calibration fan-out (0 = all
    /// available cores); projections are independent, so the result is
    /// identical at any thread count
    pub threads: usize,
}

impl Default for TrainConfig {
    fn default() -> TrainConfig {
        TrainConfig {
            steps: 200,
            batch: 16,
            lr: 1e-2,
            min_lr_frac: 0.05,
            optimizer: OptimizerKind::Adam,
            holdout_frac: 0.2,
            eval_every: 25,
            patience: 4,
            seed: 0x7E57,
            threads: 0,
        }
    }
}

impl TrainConfig {
    /// Cosine decay from `lr` to `lr · min_lr_frac` over `steps`.
    pub fn lr_at(&self, step: usize) -> f32 {
        let min_lr = self.lr * self.min_lr_frac;
        if self.steps <= 1 {
            return self.lr;
        }
        let t = step as f32 / (self.steps - 1) as f32;
        min_lr + 0.5 * (self.lr - min_lr) * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

/// Outcome of calibrating one projection.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    pub name: String,
    /// optimizer steps actually run (≤ cfg.steps under early stopping)
    pub steps_run: usize,
    pub params: usize,
    /// relative activation loss Σ‖ŷ−t‖²/Σ‖t‖² on the eval split
    pub loss_before: f64,
    pub loss_after: f64,
    /// relative Frobenius reconstruction error vs the dense teacher
    pub rel_err_before: f64,
    pub rel_err_after: f64,
}

impl CalibrationReport {
    fn unchanged(name: &str, params: usize, rel_err: f64) -> CalibrationReport {
        CalibrationReport {
            name: name.to_string(),
            steps_run: 0,
            params,
            loss_before: 0.0,
            loss_after: 0.0,
            rel_err_before: rel_err,
            rel_err_after: rel_err,
        }
    }
}

/// Samples per `apply_batch` call during holdout evaluation.
const EVAL_CHUNK: usize = 32;

/// Pack sample columns `idxs` (via `xs`) into a fresh [n, k] column block.
fn pack_block(xs: &[Vec<f32>], idxs: &[usize], n: usize) -> Matrix {
    let k = idxs.len();
    let mut xb = Matrix::zeros(n, k);
    for (c, &i) in idxs.iter().enumerate() {
        for (r, &v) in xs[i].iter().enumerate() {
            xb.data[r * k + c] = v;
        }
    }
    xb
}

/// Relative activation loss Σ‖ŴX − T‖² / Σ‖T‖² over an index set,
/// evaluated in column blocks (one traversal per chunk).
fn eval_loss(
    student: &CompressedMatrix,
    xs: &[Vec<f32>],
    targets: &[Vec<f32>],
    idxs: &[usize],
    ws: &mut crate::compress::BatchWorkspace,
) -> f64 {
    let n = student.n();
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for chunk in idxs.chunks(EVAL_CHUNK) {
        let k = chunk.len();
        let xb = pack_block(xs, chunk, n);
        let mut yb = Matrix::zeros(n, k);
        student.apply_batch(&xb, &mut yb, ws);
        for (c, &i) in chunk.iter().enumerate() {
            for (r, &tt) in targets[i].iter().enumerate() {
                let d = (yb.data[r * k + c] - tt) as f64;
                num += d * d;
                den += tt as f64 * tt as f64;
            }
        }
    }
    if den > 0.0 {
        num / den
    } else {
        num
    }
}

/// Fine-tune one compressed matrix against its dense teacher (both in the
/// column convention A = Wᵀ the compressor uses) on activation samples
/// `xs`. Returns what happened; `student` is updated in place to its best
/// observed parameters.
pub fn calibrate_matrix(
    name: &str,
    teacher: &Matrix,
    student: &mut CompressedMatrix,
    xs: &[Vec<f32>],
    cfg: &TrainConfig,
) -> CalibrationReport {
    let n = student.n();
    assert_eq!(teacher.rows, n, "teacher/student dim mismatch");
    assert_eq!(teacher.cols, n, "teacher must be square");
    let np = num_params(student);
    let rel_before = student.rel_error(teacher);
    if xs.is_empty() || np == 0 || cfg.steps == 0 {
        return CalibrationReport::unchanged(name, np, rel_before);
    }

    // precompute dense-teacher targets once — they never change
    let targets: Vec<Vec<f32>> = xs.iter().map(|x| teacher.matvec(x)).collect();

    // deterministic holdout split
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    let mut rng = Rng::new(cfg.seed);
    rng.shuffle(&mut idx);
    let n_hold = if xs.len() >= 8 {
        (((xs.len() as f64) * cfg.holdout_frac) as usize).clamp(1, xs.len() - 1)
    } else {
        0
    };
    let (hold, train) = idx.split_at(n_hold);
    // early stopping needs a holdout; without one, evaluate on everything
    let eval_set: &[usize] = if hold.is_empty() { train } else { hold };

    // zero-proof the divisors a hand-written CLI config can zero out
    let batch = cfg.batch.max(1);
    let eval_every = cfg.eval_every.max(1);

    let mut opt = cfg.optimizer.build();
    let mut ws = student.workspace_for(batch.max(EVAL_CHUNK));
    let mut gws = GradWorkspace::for_matrix_batch(student, batch);
    let mut grad = vec![0.0f32; np];
    // the whole mini-batch flows through one apply_batch + one rank-k
    // accumulate_grad per step — these blocks are reused across steps
    let mut batch_idx = vec![0usize; batch];
    let mut xb = Matrix::zeros(n, batch);
    let mut gb = Matrix::zeros(n, batch);

    let loss_before = eval_loss(student, xs, &targets, eval_set, &mut ws);
    let mut best_loss = loss_before;
    let mut best_params = vec![0.0f32; np];
    copy_params_into(student, &mut best_params);
    let mut stale = 0usize;
    let mut steps_run = 0usize;

    for step in 0..cfg.steps {
        grad.fill(0.0);
        for (c, slot) in batch_idx.iter_mut().enumerate() {
            let i = train[rng.below(train.len())];
            *slot = i;
            for (r, &v) in xs[i].iter().enumerate() {
                xb.data[r * batch + c] = v;
            }
        }
        student.apply_batch(&xb, &mut gb, &mut ws);
        for (c, &i) in batch_idx.iter().enumerate() {
            for (r, &tt) in targets[i].iter().enumerate() {
                gb.data[r * batch + c] -= tt; // gb becomes the residual G = Ŷ − T
            }
        }
        accumulate_grad(student, &xb, &gb, &mut grad, &mut gws);
        let inv = 1.0 / batch as f32;
        for g in grad.iter_mut() {
            *g *= inv;
        }
        opt.step(student, &grad, cfg.lr_at(step));
        steps_run = step + 1;

        if !hold.is_empty() && steps_run % eval_every == 0 {
            let l = eval_loss(student, xs, &targets, eval_set, &mut ws);
            crate::log_debug!("calibrate {name}: step {steps_run} holdout {l:.5}");
            if l < best_loss {
                best_loss = l;
                copy_params_into(student, &mut best_params);
                stale = 0;
            } else {
                stale += 1;
                if stale >= cfg.patience {
                    crate::log_debug!("calibrate {name}: early stop at step {steps_run}");
                    break;
                }
            }
        }
    }

    // best-checkpoint restore: never end worse than the best seen state.
    // The explicit NaN arm matters — a diverged run (loss NaN) must roll
    // back to the checkpoint, and NaN compares false under every ordering.
    let final_loss = eval_loss(student, xs, &targets, eval_set, &mut ws);
    let loss_after = if final_loss.is_nan() || final_loss > best_loss {
        load_params(student, &best_params);
        best_loss
    } else {
        final_loss
    };
    let rel_after = student.rel_error(teacher);
    crate::log_info!(
        "calibrate {name}: {steps_run} steps ({} params, {}), rel err {rel_before:.4} -> {rel_after:.4}, loss {loss_before:.5} -> {loss_after:.5}",
        np,
        opt.name(),
    );
    CalibrationReport {
        name: name.to_string(),
        steps_run,
        params: np,
        loss_before,
        loss_after,
        rel_err_before: rel_before,
        rel_err_after: rel_after,
    }
}

/// Collect calibration activations for every layer: rows of the post-ln1
/// matrices the q/k/v projections consume, over the given token windows
/// (each truncated to the model's context length). All windows run as
/// one batched capture pass — one tall projection per layer — instead of
/// one forward per window.
pub fn collect_activations(
    base: &crate::model::Transformer,
    windows: &[Vec<u32>],
) -> Vec<Vec<Vec<f32>>> {
    let truncated: Vec<&[u32]> = windows
        .iter()
        .map(|w| &w[..w.len().min(base.cfg.seq_len)])
        .filter(|w| !w.is_empty())
        .collect();
    let mut per_layer: Vec<Vec<Vec<f32>>> = vec![Vec::new(); base.cfg.n_layers];
    if truncated.is_empty() {
        return per_layer;
    }
    let tall = base.qkv_inputs_batch(&truncated);
    for (layer, a) in tall.into_iter().enumerate() {
        per_layer[layer] = (0..a.rows).map(|i| a.row(i).to_vec()).collect();
    }
    per_layer
}

/// End-to-end refinement of a whole [`CompressedModel`]: capture
/// activations, run the pipeline refine stage over every q/k/v report,
/// and install the refined factors into the serving copies. Returns one
/// report per projection (layer-major, q/k/v order).
pub fn calibrate_model(
    cm: &mut CompressedModel,
    windows: &[Vec<u32>],
    cfg: &TrainConfig,
) -> Vec<CalibrationReport> {
    let base = cm.base.clone();
    crate::log_info!(
        "calibrating {} projections over {} windows ({} steps max each)",
        cm.reports.len(),
        windows.len(),
        cfg.steps
    );
    let activations = collect_activations(&base, windows);
    let projections = base.qkv_projections();
    calibrate_model_with(cm, &projections, &activations, cfg)
}

/// Refinement core for callers that precompute teachers and activations —
/// a sweep grid captures activations once and reuses them for every
/// (method, config) cell instead of re-running the dense forward pass
/// per cell.
pub fn calibrate_model_with(
    cm: &mut CompressedModel,
    projections: &[(String, Matrix)],
    activations: &[Vec<Vec<f32>>],
    cfg: &TrainConfig,
) -> Vec<CalibrationReport> {
    let reports =
        crate::compress::pipeline::refine_reports(&mut cm.reports, projections, activations, cfg);
    // the serving copies and the report copies are separate shallow
    // clones — sync the refined factors into the matrices `forward` uses
    for layer in 0..cm.qkv.len() {
        for j in 0..3 {
            cm.qkv[layer][j] = cm.reports[layer * 3 + j].compressed.clone_shallow();
        }
    }
    reports
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, CompressorConfig, Method};
    use crate::data::synthetic;
    use crate::model::{ModelConfig, Transformer};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn samples(n: usize, count: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..count)
            .map(|_| (0..n).map(|_| rng.gaussian_f32()).collect())
            .collect()
    }

    #[test]
    fn cosine_schedule_endpoints() {
        let cfg = TrainConfig {
            steps: 100,
            lr: 1.0,
            min_lr_frac: 0.1,
            ..Default::default()
        };
        assert!((cfg.lr_at(0) - 1.0).abs() < 1e-6);
        assert!((cfg.lr_at(99) - 0.1).abs() < 1e-6);
        assert!(cfg.lr_at(50) < cfg.lr_at(10));
    }

    #[test]
    fn calibrate_reduces_error_for_lowrank() {
        let n = 32;
        let teacher = synthetic::trained_like(n, 3);
        let mut student = Compressor::new(CompressorConfig {
            rank: 4,
            sparsity: 0.05,
            ..Default::default()
        })
        .compress(&teacher, Method::SSvd);
        let xs = samples(n, 64, 4);
        let cfg = TrainConfig {
            steps: 150,
            ..Default::default()
        };
        let rep = calibrate_matrix("test.lowrank", &teacher, &mut student, &xs, &cfg);
        assert!(rep.steps_run > 0);
        assert!(
            rep.rel_err_after < rep.rel_err_before,
            "rel err {} -> {}",
            rep.rel_err_before,
            rep.rel_err_after
        );
        assert!(rep.loss_after <= rep.loss_before);
    }

    #[test]
    fn calibrate_reduces_error_for_hss() {
        let n = 32;
        let teacher = synthetic::trained_like(n, 5);
        let mut student = Compressor::new(CompressorConfig {
            rank: 4,
            sparsity: 0.05,
            depth: 2,
            min_leaf: 4,
            ..Default::default()
        })
        .compress(&teacher, Method::SHssRcm);
        let xs = samples(n, 64, 6);
        let cfg = TrainConfig {
            steps: 150,
            ..Default::default()
        };
        let rep = calibrate_matrix("test.hss", &teacher, &mut student, &xs, &cfg);
        assert!(
            rep.rel_err_after < rep.rel_err_before,
            "rel err {} -> {}",
            rep.rel_err_before,
            rep.rel_err_after
        );
    }

    #[test]
    fn empty_samples_is_a_noop() {
        let teacher = synthetic::trained_like(16, 7);
        let mut student = Compressor::new(CompressorConfig {
            rank: 4,
            ..Default::default()
        })
        .compress(&teacher, Method::Svd);
        let before = crate::train::grad::copy_params(&student);
        let rep = calibrate_matrix("noop", &teacher, &mut student, &[], &TrainConfig::default());
        assert_eq!(rep.steps_run, 0);
        assert_eq!(crate::train::grad::copy_params(&student), before);
    }

    #[test]
    fn deterministic_given_seed() {
        let teacher = synthetic::trained_like(16, 8);
        let xs = samples(16, 32, 9);
        let cfg = TrainConfig {
            steps: 40,
            ..Default::default()
        };
        let mk = || {
            Compressor::new(CompressorConfig {
                rank: 3,
                ..Default::default()
            })
            .compress(&teacher, Method::Svd)
        };
        let mut a = mk();
        let mut b = mk();
        calibrate_matrix("det", &teacher, &mut a, &xs, &cfg);
        calibrate_matrix("det", &teacher, &mut b, &xs, &cfg);
        assert_eq!(
            crate::train::grad::copy_params(&a),
            crate::train::grad::copy_params(&b)
        );
    }

    #[test]
    fn collect_activations_shapes() {
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 2,
            d_ff: 64,
            seq_len: 16,
        };
        let base = Transformer::random(cfg, 1);
        let windows: Vec<Vec<u32>> = (0..3)
            .map(|s| (0..17u32).map(|i| (i * 3 + s) % 64).collect())
            .collect();
        let acts = collect_activations(&base, &windows);
        assert_eq!(acts.len(), 2);
        for layer in &acts {
            assert_eq!(layer.len(), 3 * 16); // windows truncate to seq_len
            assert!(layer.iter().all(|x| x.len() == 32));
        }
    }

    #[test]
    fn calibrate_model_refines_serving_copies_and_reports() {
        let cfg = ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 2,
            d_ff: 64,
            seq_len: 16,
        };
        let base = Arc::new(Transformer::random(cfg, 2));
        let mut cm = CompressedModel::compress(
            base.clone(),
            Method::SSvd,
            CompressorConfig {
                rank: 4,
                sparsity: 0.05,
                ..Default::default()
            },
        );
        let before = cm.mean_rel_error();
        let windows: Vec<Vec<u32>> = (0..6)
            .map(|s| (0..17u32).map(|i| (i * 5 + s) % 64).collect())
            .collect();
        let reps = calibrate_model(
            &mut cm,
            &windows,
            &TrainConfig {
                steps: 80,
                ..Default::default()
            },
        );
        assert_eq!(reps.len(), 6);
        let after = cm.mean_rel_error();
        assert!(after < before, "mean rel err {before} -> {after}");
        // reports and serving copies agree after the sync
        for (i, rep) in cm.reports.iter().enumerate() {
            let (layer, j) = (i / 3, i % 3);
            assert_eq!(
                rep.compressed.reconstruct().data,
                cm.qkv[layer][j].reconstruct().data,
                "{}",
                rep.name
            );
            assert!((rep.rel_error - reps[i].rel_err_after).abs() < 1e-12);
        }
    }
}
