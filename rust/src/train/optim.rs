//! Optimizers over the flat parameter view of a [`CompressedMatrix`].
//!
//! State (momentum / Adam moments) is laid out against the canonical
//! parameter order of `train::grad`, so one optimizer instance tracks one
//! matrix across steps. Updates walk the structure chunk-wise via
//! `visit_params_mut` — no flatten/unflatten copies in the hot loop.

use crate::compress::CompressedMatrix;
use crate::train::grad::visit_params_mut;
use std::str::FromStr;

/// One optimizer update given the averaged flat gradient for this step.
/// State is per-matrix: `calibrate_matrix` builds a fresh instance per
/// projection, so there is deliberately no reset/clear method.
pub trait Optimizer {
    fn step(&mut self, m: &mut CompressedMatrix, grad: &[f32], lr: f32);
    fn name(&self) -> &'static str;
}

/// SGD with classical momentum (momentum 0 = plain gradient descent).
pub struct Sgd {
    pub momentum: f32,
    vel: Vec<f32>,
}

impl Sgd {
    pub fn new(momentum: f32) -> Sgd {
        Sgd {
            momentum,
            vel: Vec::new(),
        }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, m: &mut CompressedMatrix, grad: &[f32], lr: f32) {
        if self.vel.len() < grad.len() {
            self.vel.resize(grad.len(), 0.0);
        }
        let mu = self.momentum;
        let vel = &mut self.vel;
        let mut off = 0;
        visit_params_mut(m, &mut |chunk: &mut [f32]| {
            for (j, p) in chunk.iter_mut().enumerate() {
                let i = off + j;
                let v = mu * vel[i] + grad[i];
                vel[i] = v;
                *p -= lr * v;
            }
            off += chunk.len();
        });
        debug_assert_eq!(off, grad.len());
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// Adam with bias correction (Kingma & Ba defaults).
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u32,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Default for Adam {
    fn default() -> Adam {
        Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, model: &mut CompressedMatrix, grad: &[f32], lr: f32) {
        if self.m.len() < grad.len() {
            self.m.resize(grad.len(), 0.0);
            self.v.resize(grad.len(), 0.0);
        }
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (b1, b2, eps) = (self.beta1, self.beta2, self.eps);
        let (ms, vs) = (&mut self.m, &mut self.v);
        let mut off = 0;
        visit_params_mut(model, &mut |chunk: &mut [f32]| {
            for (j, p) in chunk.iter_mut().enumerate() {
                let i = off + j;
                let gi = grad[i];
                ms[i] = b1 * ms[i] + (1.0 - b1) * gi;
                vs[i] = b2 * vs[i] + (1.0 - b2) * gi * gi;
                let mhat = ms[i] / bc1;
                let vhat = vs[i] / bc2;
                *p -= lr * mhat / (vhat.sqrt() + eps);
            }
            off += chunk.len();
        });
        debug_assert_eq!(off, grad.len());
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

/// Optimizer selector for configs / the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    Adam,
}

impl OptimizerKind {
    pub fn build(&self) -> Box<dyn Optimizer> {
        match self {
            OptimizerKind::Sgd => Box::new(Sgd::new(0.9)),
            OptimizerKind::Adam => Box::new(Adam::default()),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Adam => "adam",
        }
    }
}

impl FromStr for OptimizerKind {
    type Err = String;

    fn from_str(s: &str) -> Result<OptimizerKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "sgd" => Ok(OptimizerKind::Sgd),
            "adam" => Ok(OptimizerKind::Adam),
            other => Err(format!("unknown optimizer '{other}' (expected sgd|adam)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::train::grad::{accumulate_grad, num_params, GradWorkspace};
    use crate::util::rng::Rng;

    /// Train a tiny dense matrix toward a fixed teacher on one input; any
    /// reasonable optimizer must shrink the residual monotonically-ish.
    fn residual_after(opt: &mut dyn Optimizer, steps: usize, lr: f32) -> f64 {
        let teacher = Matrix::randn(8, 8, 1);
        let mut student = CompressedMatrix::Dense {
            w: Matrix::zeros(8, 8),
        };
        let mut rng = Rng::new(2);
        let xs: Vec<Vec<f32>> = (0..16)
            .map(|_| (0..8).map(|_| rng.gaussian_f32()).collect())
            .collect();
        let mut grad = vec![0.0f32; num_params(&student)];
        let mut ws = GradWorkspace::for_matrix(&student);
        for step in 0..steps {
            grad.fill(0.0);
            let x = &xs[step % xs.len()];
            let y = student.matvec(x);
            let t = teacher.matvec(x);
            let g: Vec<f32> = y.iter().zip(&t).map(|(&a, &b)| a - b).collect();
            let xm = Matrix::from_vec(8, 1, x.clone());
            let gm = Matrix::from_vec(8, 1, g);
            accumulate_grad(&student, &xm, &gm, &mut grad, &mut ws);
            opt.step(&mut student, &grad, lr);
        }
        student.rel_error(&teacher)
    }

    #[test]
    fn sgd_reduces_reconstruction_error() {
        let before = residual_after(&mut Sgd::new(0.0), 0, 0.05);
        let after = residual_after(&mut Sgd::new(0.0), 300, 0.05);
        assert!(after < 0.5 * before, "sgd: {before} -> {after}");
    }

    #[test]
    fn adam_reduces_reconstruction_error() {
        let after = residual_after(&mut Adam::default(), 500, 0.05);
        assert!(after < 0.5, "adam residual {after}");
    }

    #[test]
    fn adam_bias_correction_first_step_is_full_sized() {
        // with bias correction the very first step moves by ≈ lr, not
        // lr·(1−β1)
        let mut a = Adam::default();
        let mut m = CompressedMatrix::Dense {
            w: Matrix::zeros(2, 2),
        };
        a.step(&mut m, &[1.0, 1.0, 1.0, 1.0], 0.1);
        if let CompressedMatrix::Dense { w } = &m {
            for &p in &w.data {
                assert!((p + 0.1).abs() < 1e-3, "first step {p}");
            }
        }
    }

    #[test]
    fn kind_parse_and_build() {
        assert_eq!("adam".parse::<OptimizerKind>().unwrap(), OptimizerKind::Adam);
        assert_eq!("SGD".parse::<OptimizerKind>().unwrap(), OptimizerKind::Sgd);
        assert!("rmsprop".parse::<OptimizerKind>().is_err());
        assert_eq!(OptimizerKind::Adam.build().name(), "adam");
        assert_eq!(OptimizerKind::Sgd.build().name(), "sgd");
    }

    #[test]
    fn sgd_momentum_accumulates_velocity() {
        let mut s = Sgd::new(0.9);
        let mut m = CompressedMatrix::Dense {
            w: Matrix::zeros(2, 2),
        };
        // two identical-gradient steps: second moves farther (velocity)
        s.step(&mut m, &[1.0; 4], 0.1);
        let after_one = if let CompressedMatrix::Dense { w } = &m {
            w.data[0]
        } else {
            unreachable!()
        };
        s.step(&mut m, &[1.0; 4], 0.1);
        let after_two = if let CompressedMatrix::Dense { w } = &m {
            w.data[0]
        } else {
            unreachable!()
        };
        assert!((after_one + 0.1).abs() < 1e-6);
        assert!((after_two - after_one + 0.19).abs() < 1e-6, "{after_two}");
    }
}
