//! Backward passes for every [`CompressedMatrix`] variant.
//!
//! The training objective is the layer-wise reconstruction loss
//! L = ½‖Ŵ x − W x‖² per calibration sample. Every variant's matvec is
//! linear in its parameters, so given the output-space gradient
//! g = ∂L/∂y = Ŵ x − W x, parameter gradients are vector-Jacobian
//! products that never need stored forward activations — each
//! intermediate is recomputable from x during the backward walk:
//!
//! - `Dense`:    y = W x            ⇒ dW = g xᵀ
//! - `LowRank`:  y = L (R x) + S x  ⇒ dL = g tᵀ (t = R x),
//!               dR = (Lᵀ g) xᵀ, dS restricted to the frozen pattern
//! - `Hss`:      recursive VJP — the permutation routes g down exactly
//!               as it routes x (y = Pᵀ z ⇒ ∂L/∂z = P g), so leaves see
//!               (x-slice, g-slice) pairs and couplings get rank-k outer
//!               products, level by level.
//!
//! The flat parameter view (`visit_params` / `visit_params_mut`) fixes one
//! canonical traversal order shared by gradient accumulation, optimizers,
//! and snapshots: Dense → [W]; LowRank → [L, R, S-values]; Branch →
//! [S-values, U0, R0, U1, R1, child0, child1]; Leaf → [D]. Sparsity
//! patterns and permutations are frozen — only values train.
//!
//! [`GradWorkspace`] mirrors the `hss::matvec::Workspace` buffer
//! discipline (one scratch set per tree level, sized by the same
//! `collect_dims` walk) so the training hot loop allocates nothing after
//! warmup.

use crate::compress::CompressedMatrix;
use crate::hss::matvec::collect_dims;
use crate::hss::HssNode;

/// Number of trainable parameters of a compressed matrix (the length of
/// the flat gradient / optimizer-state vectors).
pub fn num_params(m: &CompressedMatrix) -> usize {
    let mut n = 0;
    visit_params(m, &mut |chunk| n += chunk.len());
    n
}

/// Visit every trainable parameter chunk in canonical order.
pub fn visit_params<F: FnMut(&[f32])>(m: &CompressedMatrix, f: &mut F) {
    match m {
        CompressedMatrix::Dense { w } => f(&w.data),
        CompressedMatrix::LowRank { l, r, sparse } => {
            f(&l.data);
            f(&r.data);
            if let Some(s) = sparse {
                f(&s.data);
            }
        }
        CompressedMatrix::Hss { tree } => visit_node(tree, f),
    }
}

fn visit_node<F: FnMut(&[f32])>(node: &HssNode, f: &mut F) {
    match node {
        HssNode::Leaf { d } => f(&d.data),
        HssNode::Branch {
            sparse,
            u0,
            r0,
            u1,
            r1,
            c0,
            c1,
            ..
        } => {
            f(&sparse.data);
            f(&u0.data);
            f(&r0.data);
            f(&u1.data);
            f(&r1.data);
            visit_node(c0, f);
            visit_node(c1, f);
        }
    }
}

/// Visit every trainable parameter chunk mutably, in the same canonical
/// order as [`visit_params`] — the write side used by optimizers and
/// snapshot restore.
pub fn visit_params_mut<F: FnMut(&mut [f32])>(m: &mut CompressedMatrix, f: &mut F) {
    match m {
        CompressedMatrix::Dense { w } => f(&mut w.data),
        CompressedMatrix::LowRank { l, r, sparse } => {
            f(&mut l.data);
            f(&mut r.data);
            if let Some(s) = sparse {
                f(&mut s.data);
            }
        }
        CompressedMatrix::Hss { tree } => visit_node_mut(tree, f),
    }
}

fn visit_node_mut<F: FnMut(&mut [f32])>(node: &mut HssNode, f: &mut F) {
    match node {
        HssNode::Leaf { d } => f(&mut d.data),
        HssNode::Branch {
            sparse,
            u0,
            r0,
            u1,
            r1,
            c0,
            c1,
            ..
        } => {
            f(&mut sparse.data);
            f(&mut u0.data);
            f(&mut r0.data);
            f(&mut u1.data);
            f(&mut r1.data);
            visit_node_mut(c0, f);
            visit_node_mut(c1, f);
        }
    }
}

/// Snapshot the flat parameter vector into a preallocated buffer.
pub fn copy_params_into(m: &CompressedMatrix, out: &mut [f32]) {
    let mut off = 0;
    visit_params(m, &mut |chunk| {
        out[off..off + chunk.len()].copy_from_slice(chunk);
        off += chunk.len();
    });
    assert_eq!(off, out.len(), "param snapshot length mismatch");
}

/// Snapshot the flat parameter vector (allocating convenience form).
pub fn copy_params(m: &CompressedMatrix) -> Vec<f32> {
    let mut out = vec![0.0; num_params(m)];
    copy_params_into(m, &mut out);
    out
}

/// Restore parameters from a flat vector (inverse of [`copy_params`]).
pub fn load_params(m: &mut CompressedMatrix, flat: &[f32]) {
    let mut off = 0;
    visit_params_mut(m, &mut |chunk| {
        chunk.copy_from_slice(&flat[off..off + chunk.len()]);
        off += chunk.len();
    });
    assert_eq!(off, flat.len(), "param restore length mismatch");
}

/// out += a bᵀ, row-major — the rank-1 update every factor gradient
/// reduces to.
pub fn outer_add(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), a.len() * b.len());
    let cols = b.len();
    for (i, &ai) in a.iter().enumerate() {
        if ai == 0.0 {
            continue;
        }
        let row = &mut out[i * cols..(i + 1) * cols];
        for (o, &bj) in row.iter_mut().zip(b) {
            *o += ai * bj;
        }
    }
}

struct GradLevel {
    /// permuted input x[perm]
    xp: Vec<f32>,
    /// permuted output-gradient g[perm]
    gp: Vec<f32>,
    /// coupling intermediate t = R·x  (rank-sized)
    t: Vec<f32>,
    /// coupling cotangent v = Uᵀ·g  (rank-sized)
    v: Vec<f32>,
}

/// Per-matrix scratch for [`accumulate_grad`]; same per-level discipline
/// as the matvec `Workspace`, so repeated backward passes allocate
/// nothing after warmup (including the dims scratch used to size levels).
#[derive(Default)]
pub struct GradWorkspace {
    levels: Vec<GradLevel>,
    /// LowRank intermediates (t = R x, v = Lᵀ g)
    t: Vec<f32>,
    v: Vec<f32>,
    dims: Vec<(usize, usize)>,
}

impl GradWorkspace {
    pub fn for_matrix(m: &CompressedMatrix) -> GradWorkspace {
        let mut ws = GradWorkspace::default();
        ws.ensure(m);
        ws
    }

    /// Grow buffers to fit `m` (idempotent, allocation-free once warm).
    pub fn ensure(&mut self, m: &CompressedMatrix) {
        match m {
            CompressedMatrix::Dense { .. } => {}
            CompressedMatrix::LowRank { r, .. } => {
                if self.t.len() < r.rows {
                    self.t.resize(r.rows, 0.0);
                    self.v.resize(r.rows, 0.0);
                }
            }
            CompressedMatrix::Hss { tree } => {
                self.dims.clear();
                collect_dims(tree, 0, &mut self.dims);
                for (lvl, &(n, k)) in self.dims.iter().enumerate() {
                    if self.levels.len() <= lvl {
                        self.levels.push(GradLevel {
                            xp: vec![0.0; n],
                            gp: vec![0.0; n],
                            t: vec![0.0; k],
                            v: vec![0.0; k],
                        });
                    } else {
                        let b = &mut self.levels[lvl];
                        if b.xp.len() < n {
                            b.xp.resize(n, 0.0);
                            b.gp.resize(n, 0.0);
                        }
                        if b.t.len() < k {
                            b.t.resize(k, 0.0);
                            b.v.resize(k, 0.0);
                        }
                    }
                }
            }
        }
    }
}

/// Accumulate ∂L/∂θ into `grad` (flat, canonical order) for one sample,
/// given the input `x` and the output-space gradient `g = ŷ − t`.
/// `grad` is accumulated into, not overwritten — callers average over a
/// batch by zeroing once and dividing at the end.
pub fn accumulate_grad(
    m: &CompressedMatrix,
    x: &[f32],
    g: &[f32],
    grad: &mut [f32],
    ws: &mut GradWorkspace,
) {
    debug_assert_eq!(grad.len(), num_params(m));
    ws.ensure(m);
    match m {
        CompressedMatrix::Dense { w } => {
            debug_assert_eq!(x.len(), w.cols);
            outer_add(g, x, grad);
        }
        CompressedMatrix::LowRank { l, r, sparse } => {
            let t = &mut ws.t[..r.rows];
            r.matvec_into(x, t);
            let ln = l.data.len();
            outer_add(g, t, &mut grad[..ln]);
            let v = &mut ws.v[..l.cols];
            l.matvec_t_into(g, v);
            let rn = r.data.len();
            outer_add(v, x, &mut grad[ln..ln + rn]);
            if let Some(s) = sparse {
                s.value_grads_add(x, g, &mut grad[ln + rn..]);
            }
        }
        CompressedMatrix::Hss { tree } => {
            let mut off = 0;
            hss_grad(tree, x, g, grad, &mut off, &mut ws.levels);
            debug_assert_eq!(off, grad.len());
        }
    }
}

/// Recursive VJP through one HSS node. `off` is the cursor into the flat
/// gradient; the write order must match `visit_params` exactly.
fn hss_grad(
    node: &HssNode,
    x: &[f32],
    g: &[f32],
    grad: &mut [f32],
    off: &mut usize,
    levels: &mut [GradLevel],
) {
    match node {
        HssNode::Leaf { d } => {
            let len = d.data.len();
            outer_add(g, x, &mut grad[*off..*off + len]);
            *off += len;
        }
        HssNode::Branch {
            n,
            sparse,
            perm,
            u0,
            r0,
            u1,
            r1,
            c0,
            c1,
        } => {
            let n0 = n / 2;
            // spike values see the unpermuted coordinates: y += S x
            let nnz = sparse.nnz();
            sparse.value_grads_add(x, g, &mut grad[*off..*off + nnz]);
            *off += nnz;

            let (buf, rest) = levels
                .split_first_mut()
                .expect("grad workspace depth too small");
            // y = Pᵀ z ⇒ ∂L/∂z = P g: the gradient permutes down exactly
            // like the input
            let xp = &mut buf.xp[..*n];
            perm.apply_into(x, xp);
            let gp = &mut buf.gp[..*n];
            perm.apply_into(g, gp);
            let (x0, x1) = xp.split_at(n0);
            let (g0, g1) = gp.split_at(n0);

            // z0 += U0 (R0 x1): dU0 = g0 t0ᵀ, dR0 = (U0ᵀ g0) x1ᵀ
            let t0 = &mut buf.t[..r0.rows];
            r0.matvec_into(x1, t0);
            let len = u0.data.len();
            outer_add(g0, t0, &mut grad[*off..*off + len]);
            *off += len;
            let v0 = &mut buf.v[..u0.cols];
            u0.matvec_t_into(g0, v0);
            let len = r0.data.len();
            outer_add(v0, x1, &mut grad[*off..*off + len]);
            *off += len;

            // z1 += U1 (R1 x0): dU1 = g1 t1ᵀ, dR1 = (U1ᵀ g1) x0ᵀ
            let t1 = &mut buf.t[..r1.rows];
            r1.matvec_into(x0, t1);
            let len = u1.data.len();
            outer_add(g1, t1, &mut grad[*off..*off + len]);
            *off += len;
            let v1 = &mut buf.v[..u1.cols];
            u1.matvec_t_into(g1, v1);
            let len = r1.data.len();
            outer_add(v1, x0, &mut grad[*off..*off + len]);
            *off += len;

            // diagonal blocks: children consume (x-slice, g-slice) pairs
            hss_grad(c0, x0, g0, grad, off, rest);
            hss_grad(c1, x1, g1, grad, off, rest);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, CompressorConfig, Method};
    use crate::linalg::Matrix;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn spiky(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut a = Matrix::randn(n, n, seed).scale(0.1);
        for _ in 0..2 * n {
            let i = rng.below(n);
            let j = rng.below(n);
            a.data[i * n + j] += rng.gaussian_f32();
        }
        a
    }

    /// ½‖Ŵx − t‖² accumulated in f64 (finite-difference reference).
    fn loss(m: &CompressedMatrix, x: &[f32], tgt: &[f32]) -> f64 {
        let y = m.matvec(x);
        y.iter()
            .zip(tgt)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                0.5 * d * d
            })
            .sum()
    }

    /// Central-difference check of every parameter. The loss is exactly
    /// quadratic in each individual parameter (matvec is linear in θ_i),
    /// so central differences carry no truncation error and a generous
    /// step h keeps f32 round-off far below the 1e-3 tolerance.
    fn fd_check_all(m: &mut CompressedMatrix, seed: u64, what: &str) {
        let n = m.n();
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let tgt: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();

        let np = num_params(m);
        assert!(np > 0, "{what}: no trainable params");
        let mut grad = vec![0.0f32; np];
        let mut ws = GradWorkspace::for_matrix(m);
        let y = m.matvec(&x);
        let g: Vec<f32> = y.iter().zip(&tgt).map(|(&a, &b)| a - b).collect();
        accumulate_grad(m, &x, &g, &mut grad, &mut ws);

        let mut flat = copy_params(m);
        for i in 0..np {
            let h = (1e-2 * flat[i].abs()).max(1e-2);
            let orig = flat[i];
            flat[i] = orig + h;
            load_params(m, &flat);
            let lp = loss(m, &x, &tgt);
            flat[i] = orig - h;
            load_params(m, &flat);
            let lm = loss(m, &x, &tgt);
            flat[i] = orig;
            load_params(m, &flat);
            let fd = ((lp - lm) / (2.0 * h as f64)) as f32;
            let an = grad[i];
            let tol = 1e-3 * an.abs().max(fd.abs()).max(1.0);
            assert!(
                (fd - an).abs() <= tol,
                "{what}: grad[{i}] analytic {an} vs fd {fd}"
            );
        }
    }

    #[test]
    fn fd_grads_lowrank() {
        let w = spiky(16, 1);
        let cfg = CompressorConfig {
            rank: 4,
            ..Default::default()
        };
        let mut c = Compressor::new(cfg).compress(&w, Method::Svd);
        fd_check_all(&mut c, 11, "svd");
    }

    #[test]
    fn fd_grads_lowrank_with_csr_values() {
        let w = spiky(16, 2);
        let cfg = CompressorConfig {
            rank: 4,
            sparsity: 0.15,
            ..Default::default()
        };
        let mut c = Compressor::new(cfg).compress(&w, Method::SSvd);
        if let CompressedMatrix::LowRank { sparse, .. } = &c {
            assert!(sparse.as_ref().is_some_and(|s| s.nnz() > 0));
        } else {
            panic!("ssvd should produce LowRank + sparse");
        }
        fd_check_all(&mut c, 12, "ssvd");
    }

    #[test]
    fn fd_grads_depth2_hss() {
        let w = spiky(32, 3);
        let cfg = CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 2,
            min_leaf: 4,
            ..Default::default()
        };
        let mut c = Compressor::new(cfg).compress(&w, Method::SHssRcm);
        if let CompressedMatrix::Hss { tree } = &c {
            assert_eq!(tree.depth(), 2, "want a depth-2 tree");
        } else {
            panic!("shss-rcm should produce Hss");
        }
        fd_check_all(&mut c, 13, "shss-rcm depth2");
    }

    #[test]
    fn fd_grads_dense() {
        let w = spiky(8, 4);
        let mut c = CompressedMatrix::Dense { w };
        fd_check_all(&mut c, 14, "dense");
    }

    #[test]
    fn param_roundtrip_all_methods() {
        check(8, |rng| {
            let n = 16 + 16 * rng.below(2);
            let w = spiky(n, rng.next_u64());
            let cfg = CompressorConfig {
                rank: 4,
                sparsity: 0.1,
                depth: 2,
                min_leaf: 4,
                ..Default::default()
            };
            let comp = Compressor::new(cfg);
            for m in Method::ALL {
                let mut c = comp.compress(&w, m);
                let before = c.reconstruct();
                let flat = copy_params(&c);
                if flat.len() != num_params(&c) {
                    return Err(format!("{m:?}: flat len mismatch"));
                }
                // perturb then restore — reconstruction must be identical
                let zeros = vec![0.0; flat.len()];
                load_params(&mut c, &zeros);
                load_params(&mut c, &flat);
                if c.reconstruct().data != before.data {
                    return Err(format!("{m:?}: param roundtrip changed the matrix"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn grad_is_zero_at_the_optimum() {
        // student == teacher ⇒ residual 0 ⇒ all gradients exactly 0
        let w = spiky(16, 6);
        let c = CompressedMatrix::Dense { w: w.clone() };
        let x: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let y = c.matvec(&x);
        let t = w.matvec(&x);
        let g: Vec<f32> = y.iter().zip(&t).map(|(&a, &b)| a - b).collect();
        let mut grad = vec![0.0f32; num_params(&c)];
        let mut ws = GradWorkspace::for_matrix(&c);
        accumulate_grad(&c, &x, &g, &mut grad, &mut ws);
        assert!(grad.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn workspace_reuse_gives_identical_grads() {
        let w = spiky(32, 7);
        let cfg = CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 2,
            min_leaf: 4,
            ..Default::default()
        };
        let c = Compressor::new(cfg).compress(&w, Method::SHss);
        let mut rng = Rng::new(8);
        let x: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
        let g: Vec<f32> = (0..32).map(|_| rng.gaussian_f32()).collect();
        let mut ws = GradWorkspace::for_matrix(&c);
        let mut g1 = vec![0.0f32; num_params(&c)];
        accumulate_grad(&c, &x, &g, &mut g1, &mut ws);
        let mut g2 = vec![0.0f32; num_params(&c)];
        accumulate_grad(&c, &x, &g, &mut g2, &mut ws);
        assert_eq!(g1, g2);
    }
}
