//! Batched backward passes for every [`CompressedMatrix`] variant.
//!
//! The training objective is the layer-wise reconstruction loss
//! L = ½‖Ŵ X − W X‖² over a column block X of k calibration samples.
//! Every variant's apply is linear in its parameters, so given the
//! output-space gradient block G = ∂L/∂Y = Ŵ X − W X, parameter gradients
//! are matrix-Jacobian products that never need stored forward
//! activations — each intermediate is recomputable from X during the
//! backward walk, and every factor update is one **rank-k** GEMM
//! (`gemm_nt_add`, the kernel behind `Matrix::matmul_bt_into`) instead of
//! k rank-1 outer products:
//!
//! - `Dense`:    Y = W X            ⇒ dW += G Xᵀ
//! - `LowRank`:  Y = L (R X) + S X  ⇒ dL += G Tᵀ (T = R X),
//!               dR += (Lᵀ G) Xᵀ, dS restricted to the frozen pattern
//!               (a k-wide dot per stored value)
//! - `Hss`:      recursive VJP — the permutation routes G down exactly
//!               as it routes X (Y = Pᵀ Z ⇒ ∂L/∂Z = P G), so leaves see
//!               (X-block, G-block) pairs and couplings get rank-k GEMM
//!               updates, level by level. k = 1 recovers the per-sample
//!               backward pass exactly.
//!
//! The flat parameter view (`visit_params` / `visit_params_mut`) fixes one
//! canonical traversal order shared by gradient accumulation, optimizers,
//! and snapshots: Dense → [W]; LowRank → [L, R, S-values]; Branch →
//! [S-values, U0, R0, U1, R1, child0, child1]; Leaf → [D]. Sparsity
//! patterns and permutations are frozen — only values train.
//!
//! [`GradWorkspace`] mirrors the `hss::matvec::Workspace` buffer
//! discipline (one scratch set per tree level, sized by the same
//! `collect_dims` walk and widened to the batch) so the training hot loop
//! allocates nothing after warmup.

use crate::compress::CompressedMatrix;
use crate::hss::matvec::collect_dims;
use crate::hss::HssNode;
use crate::linalg::matrix::gemm_nt_add;
use crate::linalg::Matrix;

/// Number of trainable parameters of a compressed matrix (the length of
/// the flat gradient / optimizer-state vectors).
pub fn num_params(m: &CompressedMatrix) -> usize {
    let mut n = 0;
    visit_params(m, &mut |chunk| n += chunk.len());
    n
}

/// Visit every trainable parameter chunk in canonical order.
pub fn visit_params<F: FnMut(&[f32])>(m: &CompressedMatrix, f: &mut F) {
    match m {
        CompressedMatrix::Dense { w } => f(&w.data),
        CompressedMatrix::LowRank { l, r, sparse } => {
            f(&l.data);
            f(&r.data);
            if let Some(s) = sparse {
                f(&s.data);
            }
        }
        CompressedMatrix::Hss { tree } => visit_node(tree, f),
    }
}

fn visit_node<F: FnMut(&[f32])>(node: &HssNode, f: &mut F) {
    match node {
        HssNode::Leaf { d } => f(&d.data),
        HssNode::Branch {
            sparse,
            u0,
            r0,
            u1,
            r1,
            c0,
            c1,
            ..
        } => {
            f(&sparse.data);
            f(&u0.data);
            f(&r0.data);
            f(&u1.data);
            f(&r1.data);
            visit_node(c0, f);
            visit_node(c1, f);
        }
    }
}

/// Visit every trainable parameter chunk mutably, in the same canonical
/// order as [`visit_params`] — the write side used by optimizers and
/// snapshot restore.
pub fn visit_params_mut<F: FnMut(&mut [f32])>(m: &mut CompressedMatrix, f: &mut F) {
    match m {
        CompressedMatrix::Dense { w } => f(&mut w.data),
        CompressedMatrix::LowRank { l, r, sparse } => {
            f(&mut l.data);
            f(&mut r.data);
            if let Some(s) = sparse {
                f(&mut s.data);
            }
        }
        CompressedMatrix::Hss { tree } => visit_node_mut(tree, f),
    }
}

fn visit_node_mut<F: FnMut(&mut [f32])>(node: &mut HssNode, f: &mut F) {
    match node {
        HssNode::Leaf { d } => f(&mut d.data),
        HssNode::Branch {
            sparse,
            u0,
            r0,
            u1,
            r1,
            c0,
            c1,
            ..
        } => {
            f(&mut sparse.data);
            f(&mut u0.data);
            f(&mut r0.data);
            f(&mut u1.data);
            f(&mut r1.data);
            visit_node_mut(c0, f);
            visit_node_mut(c1, f);
        }
    }
}

/// Snapshot the flat parameter vector into a preallocated buffer.
pub fn copy_params_into(m: &CompressedMatrix, out: &mut [f32]) {
    let mut off = 0;
    visit_params(m, &mut |chunk| {
        out[off..off + chunk.len()].copy_from_slice(chunk);
        off += chunk.len();
    });
    assert_eq!(off, out.len(), "param snapshot length mismatch");
}

/// Snapshot the flat parameter vector (allocating convenience form).
pub fn copy_params(m: &CompressedMatrix) -> Vec<f32> {
    let mut out = vec![0.0; num_params(m)];
    copy_params_into(m, &mut out);
    out
}

/// Restore parameters from a flat vector (inverse of [`copy_params`]).
pub fn load_params(m: &mut CompressedMatrix, flat: &[f32]) {
    let mut off = 0;
    visit_params_mut(m, &mut |chunk| {
        chunk.copy_from_slice(&flat[off..off + chunk.len()]);
        off += chunk.len();
    });
    assert_eq!(off, flat.len(), "param restore length mismatch");
}

struct GradLevel {
    /// permuted input block X[perm] ([n, k] row-major)
    xp: Vec<f32>,
    /// permuted output-gradient block G[perm]
    gp: Vec<f32>,
    /// coupling intermediate T = R·X  (rank × k)
    t: Vec<f32>,
    /// coupling cotangent V = Uᵀ·G  (rank × k)
    v: Vec<f32>,
}

/// Per-matrix scratch for [`accumulate_grad`]; same per-level discipline
/// as the apply `Workspace` (widened to the batch), so repeated backward
/// passes allocate nothing after warmup (including the dims scratch used
/// to size levels).
#[derive(Default)]
pub struct GradWorkspace {
    levels: Vec<GradLevel>,
    /// LowRank intermediates (T = R X, V = Lᵀ G)
    t: Vec<f32>,
    v: Vec<f32>,
    dims: Vec<(usize, usize)>,
}

impl GradWorkspace {
    /// Workspace sized for single-sample (k = 1) backward passes; grows
    /// on demand when a wider batch comes through.
    pub fn for_matrix(m: &CompressedMatrix) -> GradWorkspace {
        GradWorkspace::for_matrix_batch(m, 1)
    }

    /// Workspace pre-sized for batches of `k` samples.
    pub fn for_matrix_batch(m: &CompressedMatrix, k: usize) -> GradWorkspace {
        let mut ws = GradWorkspace::default();
        ws.ensure(m, k);
        ws
    }

    /// Grow buffers to fit `m` at batch width `k` (idempotent,
    /// allocation-free once warm).
    pub fn ensure(&mut self, m: &CompressedMatrix, k: usize) {
        match m {
            CompressedMatrix::Dense { .. } => {}
            CompressedMatrix::LowRank { r, .. } => {
                if self.t.len() < r.rows * k {
                    self.t.resize(r.rows * k, 0.0);
                    self.v.resize(r.rows * k, 0.0);
                }
            }
            CompressedMatrix::Hss { tree } => {
                self.dims.clear();
                collect_dims(tree, 0, &mut self.dims);
                for (lvl, &(n, rank)) in self.dims.iter().enumerate() {
                    if self.levels.len() <= lvl {
                        self.levels.push(GradLevel {
                            xp: vec![0.0; n * k],
                            gp: vec![0.0; n * k],
                            t: vec![0.0; rank * k],
                            v: vec![0.0; rank * k],
                        });
                    } else {
                        let b = &mut self.levels[lvl];
                        if b.xp.len() < n * k {
                            b.xp.resize(n * k, 0.0);
                            b.gp.resize(n * k, 0.0);
                        }
                        if b.t.len() < rank * k {
                            b.t.resize(rank * k, 0.0);
                            b.v.resize(rank * k, 0.0);
                        }
                    }
                }
            }
        }
    }
}

/// Accumulate ∂L/∂θ into `grad` (flat, canonical order) for a column
/// block of samples: `x` is [n, k] (column c = input c) and `g` the
/// matching output-space gradient block G = Ŷ − T. Every factor update is
/// a rank-k GEMM, so one call with k samples replaces k per-sample calls
/// (k = 1 is exactly the old per-sample path). `grad` is accumulated
/// into, not overwritten — callers average over a batch by zeroing once
/// and dividing at the end.
pub fn accumulate_grad(
    m: &CompressedMatrix,
    x: &Matrix,
    g: &Matrix,
    grad: &mut [f32],
    ws: &mut GradWorkspace,
) {
    let n = m.n();
    let k = x.cols;
    assert!(k > 0, "empty sample block");
    assert_eq!((x.rows, g.rows, g.cols), (n, n, k), "sample block shape mismatch");
    debug_assert_eq!(grad.len(), num_params(m));
    ws.ensure(m, k);
    match m {
        CompressedMatrix::Dense { w } => {
            // dW += G Xᵀ
            gemm_nt_add(&g.data, &x.data, w.rows, w.cols, k, grad);
        }
        CompressedMatrix::LowRank { l, r, sparse } => {
            // T = R X; dL += G Tᵀ
            let t = &mut ws.t[..r.rows * k];
            r.apply_batch_into(&x.data, t, k);
            let ln = l.data.len();
            gemm_nt_add(&g.data, t, l.rows, l.cols, k, &mut grad[..ln]);
            // V = Lᵀ G; dR += V Xᵀ
            let v = &mut ws.v[..l.cols * k];
            l.apply_batch_t_into(&g.data, v, k);
            let rn = r.data.len();
            gemm_nt_add(v, &x.data, r.rows, r.cols, k, &mut grad[ln..ln + rn]);
            if let Some(s) = sparse {
                s.value_grads_add(&x.data, &g.data, k, &mut grad[ln + rn..]);
            }
        }
        CompressedMatrix::Hss { tree } => {
            let mut off = 0;
            hss_grad(tree, &x.data, &g.data, k, grad, &mut off, &mut ws.levels);
            debug_assert_eq!(off, grad.len());
        }
    }
}

/// Recursive VJP through one HSS node over [·, k] column blocks. `off` is
/// the cursor into the flat gradient; the write order must match
/// `visit_params` exactly.
fn hss_grad(
    node: &HssNode,
    x: &[f32],
    g: &[f32],
    k: usize,
    grad: &mut [f32],
    off: &mut usize,
    levels: &mut [GradLevel],
) {
    match node {
        HssNode::Leaf { d } => {
            let len = d.data.len();
            gemm_nt_add(g, x, d.rows, d.cols, k, &mut grad[*off..*off + len]);
            *off += len;
        }
        HssNode::Branch {
            n,
            sparse,
            perm,
            u0,
            r0,
            u1,
            r1,
            c0,
            c1,
        } => {
            let n0 = n / 2;
            // spike values see the unpermuted coordinates: Y += S X
            let nnz = sparse.nnz();
            sparse.value_grads_add(x, g, k, &mut grad[*off..*off + nnz]);
            *off += nnz;

            let (buf, rest) = levels
                .split_first_mut()
                .expect("grad workspace depth too small");
            // Y = Pᵀ Z ⇒ ∂L/∂Z = P G: the gradient block permutes down
            // exactly like the input block
            let xp = &mut buf.xp[..n * k];
            perm.apply_cols_into(x, xp, k);
            let gp = &mut buf.gp[..n * k];
            perm.apply_cols_into(g, gp, k);
            let (x0, x1) = xp.split_at(n0 * k);
            let (g0, g1) = gp.split_at(n0 * k);

            // Z0 += U0 (R0 X1): dU0 += G0 T0ᵀ, dR0 += (U0ᵀ G0) X1ᵀ
            let t0 = &mut buf.t[..r0.rows * k];
            r0.apply_batch_into(x1, t0, k);
            let len = u0.data.len();
            gemm_nt_add(g0, t0, u0.rows, u0.cols, k, &mut grad[*off..*off + len]);
            *off += len;
            let v0 = &mut buf.v[..u0.cols * k];
            u0.apply_batch_t_into(g0, v0, k);
            let len = r0.data.len();
            gemm_nt_add(v0, x1, r0.rows, r0.cols, k, &mut grad[*off..*off + len]);
            *off += len;

            // Z1 += U1 (R1 X0): dU1 += G1 T1ᵀ, dR1 += (U1ᵀ G1) X0ᵀ
            let t1 = &mut buf.t[..r1.rows * k];
            r1.apply_batch_into(x0, t1, k);
            let len = u1.data.len();
            gemm_nt_add(g1, t1, u1.rows, u1.cols, k, &mut grad[*off..*off + len]);
            *off += len;
            let v1 = &mut buf.v[..u1.cols * k];
            u1.apply_batch_t_into(g1, v1, k);
            let len = r1.data.len();
            gemm_nt_add(v1, x0, r1.rows, r1.cols, k, &mut grad[*off..*off + len]);
            *off += len;

            // diagonal blocks: children consume (X-block, G-block) pairs
            hss_grad(c0, x0, g0, k, grad, off, rest);
            hss_grad(c1, x1, g1, k, grad, off, rest);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, CompressorConfig, Method};
    use crate::linalg::Matrix;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn spiky(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut a = Matrix::randn(n, n, seed).scale(0.1);
        for _ in 0..2 * n {
            let i = rng.below(n);
            let j = rng.below(n);
            a.data[i * n + j] += rng.gaussian_f32();
        }
        a
    }

    /// ½‖Ŵx − t‖² accumulated in f64 (finite-difference reference).
    fn loss(m: &CompressedMatrix, x: &[f32], tgt: &[f32]) -> f64 {
        let y = m.matvec(x);
        y.iter()
            .zip(tgt)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                0.5 * d * d
            })
            .sum()
    }

    /// Central-difference check of every parameter. The loss is exactly
    /// quadratic in each individual parameter (matvec is linear in θ_i),
    /// so central differences carry no truncation error and a generous
    /// step h keeps f32 round-off far below the 1e-3 tolerance.
    fn fd_check_all(m: &mut CompressedMatrix, seed: u64, what: &str) {
        let n = m.n();
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();
        let tgt: Vec<f32> = (0..n).map(|_| rng.gaussian_f32()).collect();

        let np = num_params(m);
        assert!(np > 0, "{what}: no trainable params");
        let mut grad = vec![0.0f32; np];
        let mut ws = GradWorkspace::for_matrix(m);
        let y = m.matvec(&x);
        let g: Vec<f32> = y.iter().zip(&tgt).map(|(&a, &b)| a - b).collect();
        let xm = Matrix::from_vec(n, 1, x.clone());
        let gm = Matrix::from_vec(n, 1, g);
        accumulate_grad(m, &xm, &gm, &mut grad, &mut ws);

        let mut flat = copy_params(m);
        for i in 0..np {
            let h = (1e-2 * flat[i].abs()).max(1e-2);
            let orig = flat[i];
            flat[i] = orig + h;
            load_params(m, &flat);
            let lp = loss(m, &x, &tgt);
            flat[i] = orig - h;
            load_params(m, &flat);
            let lm = loss(m, &x, &tgt);
            flat[i] = orig;
            load_params(m, &flat);
            let fd = ((lp - lm) / (2.0 * h as f64)) as f32;
            let an = grad[i];
            let tol = 1e-3 * an.abs().max(fd.abs()).max(1.0);
            assert!(
                (fd - an).abs() <= tol,
                "{what}: grad[{i}] analytic {an} vs fd {fd}"
            );
        }
    }

    #[test]
    fn fd_grads_lowrank() {
        let w = spiky(16, 1);
        let cfg = CompressorConfig {
            rank: 4,
            ..Default::default()
        };
        let mut c = Compressor::new(cfg).compress(&w, Method::Svd);
        fd_check_all(&mut c, 11, "svd");
    }

    #[test]
    fn fd_grads_lowrank_with_csr_values() {
        let w = spiky(16, 2);
        let cfg = CompressorConfig {
            rank: 4,
            sparsity: 0.15,
            ..Default::default()
        };
        let mut c = Compressor::new(cfg).compress(&w, Method::SSvd);
        if let CompressedMatrix::LowRank { sparse, .. } = &c {
            assert!(sparse.as_ref().is_some_and(|s| s.nnz() > 0));
        } else {
            panic!("ssvd should produce LowRank + sparse");
        }
        fd_check_all(&mut c, 12, "ssvd");
    }

    #[test]
    fn fd_grads_depth2_hss() {
        let w = spiky(32, 3);
        let cfg = CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 2,
            min_leaf: 4,
            ..Default::default()
        };
        let mut c = Compressor::new(cfg).compress(&w, Method::SHssRcm);
        if let CompressedMatrix::Hss { tree } = &c {
            assert_eq!(tree.depth(), 2, "want a depth-2 tree");
        } else {
            panic!("shss-rcm should produce Hss");
        }
        fd_check_all(&mut c, 13, "shss-rcm depth2");
    }

    #[test]
    fn fd_grads_dense() {
        let w = spiky(8, 4);
        let mut c = CompressedMatrix::Dense { w };
        fd_check_all(&mut c, 14, "dense");
    }

    #[test]
    fn param_roundtrip_all_methods() {
        check(8, |rng| {
            let n = 16 + 16 * rng.below(2);
            let w = spiky(n, rng.next_u64());
            let cfg = CompressorConfig {
                rank: 4,
                sparsity: 0.1,
                depth: 2,
                min_leaf: 4,
                ..Default::default()
            };
            let comp = Compressor::new(cfg);
            for m in Method::ALL {
                let mut c = comp.compress(&w, m);
                let before = c.reconstruct();
                let flat = copy_params(&c);
                if flat.len() != num_params(&c) {
                    return Err(format!("{m:?}: flat len mismatch"));
                }
                // perturb then restore — reconstruction must be identical
                let zeros = vec![0.0; flat.len()];
                load_params(&mut c, &zeros);
                load_params(&mut c, &flat);
                if c.reconstruct().data != before.data {
                    return Err(format!("{m:?}: param roundtrip changed the matrix"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn grad_is_zero_at_the_optimum() {
        // student == teacher ⇒ residual 0 ⇒ all gradients exactly 0
        let w = spiky(16, 6);
        let c = CompressedMatrix::Dense { w: w.clone() };
        let x: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let y = c.matvec(&x);
        let t = w.matvec(&x);
        let g: Vec<f32> = y.iter().zip(&t).map(|(&a, &b)| a - b).collect();
        let mut grad = vec![0.0f32; num_params(&c)];
        let mut ws = GradWorkspace::for_matrix(&c);
        let xm = Matrix::from_vec(16, 1, x);
        let gm = Matrix::from_vec(16, 1, g);
        accumulate_grad(&c, &xm, &gm, &mut grad, &mut ws);
        assert!(grad.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn workspace_reuse_gives_identical_grads() {
        let w = spiky(32, 7);
        let cfg = CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 2,
            min_leaf: 4,
            ..Default::default()
        };
        let c = Compressor::new(cfg).compress(&w, Method::SHss);
        let x = Matrix::randn(32, 1, 81);
        let g = Matrix::randn(32, 1, 82);
        let mut ws = GradWorkspace::for_matrix(&c);
        let mut g1 = vec![0.0f32; num_params(&c)];
        accumulate_grad(&c, &x, &g, &mut g1, &mut ws);
        let mut g2 = vec![0.0f32; num_params(&c)];
        accumulate_grad(&c, &x, &g, &mut g2, &mut ws);
        assert_eq!(g1, g2);
    }

    /// The satellite grad-check: on a fixed seed, the rank-k batched
    /// `accumulate_grad` must match the old per-sample path (k = 1 calls
    /// summed) for every variant — the batch is a pure kernel change, not
    /// a semantic one.
    #[test]
    fn batched_grad_matches_per_sample_sum() {
        let n = 32;
        let k = 8;
        let w = spiky(n, 9);
        let cfg = CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 2,
            min_leaf: 4,
            ..Default::default()
        };
        let comp = Compressor::new(cfg);
        for m in [Method::Dense, Method::Svd, Method::SSvd, Method::SHssRcm] {
            let c = comp.compress(&w, m);
            let np = num_params(&c);
            let mut rng = Rng::new(10);
            let mut x = Matrix::zeros(n, k);
            let mut g = Matrix::zeros(n, k);
            for v in x.data.iter_mut() {
                *v = rng.gaussian_f32();
            }
            for v in g.data.iter_mut() {
                *v = rng.gaussian_f32();
            }
            let mut batched = vec![0.0f32; np];
            let mut ws = GradWorkspace::for_matrix_batch(&c, k);
            accumulate_grad(&c, &x, &g, &mut batched, &mut ws);

            let mut summed = vec![0.0f32; np];
            let mut ws1 = GradWorkspace::for_matrix(&c);
            for col in 0..k {
                let xc = Matrix::from_vec(n, 1, x.col(col));
                let gc = Matrix::from_vec(n, 1, g.col(col));
                accumulate_grad(&c, &xc, &gc, &mut summed, &mut ws1);
            }
            crate::util::proptest::slices_close(&batched, &summed, 1e-4, 1e-4, m.name()).unwrap();
        }
    }
}
