//! # hisolo — Hierarchical Sparse Plus Low-Rank compression of LLMs
//!
//! A production-shaped reproduction of *"Hierarchical Sparse Plus Low Rank
//! Compression of LLM"* (Kumar & Gupta, CODS '25) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L1/L2 (build path, python/)** — Pallas kernels + the JAX transformer
//!   are lowered once to HLO text artifacts (`make artifacts`).
//! - **L3 (this crate)** — everything at runtime: the compression library
//!   itself (native [`linalg`], [`sparse`], [`hss`], [`compress`]), the
//!   model/eval harness ([`model`], [`data`], [`eval`]), the PJRT runtime
//!   ([`runtime`]) and the serving coordinator ([`coordinator`]).
//!
//! The paper's method, in one expression:
//!
//! ```text
//! W  ≈  S  +  Pᵀ · [ D₀      U₀R₀ᵀ ] · P         (recursively, per level:
//!             [ U₁R₁ᵀ   D₁    ]                 sparse spikes out, RCM
//!                                               reorder, 2×2 split, low-rank
//!                                               off-diagonals, rank halves)
//! ```
//!
//! Quickstart (after `make artifacts`):
//!
//! ```no_run
//! use hisolo::compress::{Compressor, CompressorConfig, Method};
//! use hisolo::linalg::Matrix;
//!
//! let w = Matrix::randn(256, 256, 42);
//! let cfg = CompressorConfig { rank: 32, sparsity: 0.3, ..Default::default() };
//! let compressed = Compressor::new(cfg).compress(&w, Method::SHssRcm);
//! let x = vec![1.0f32; 256];
//! let y = compressed.matvec(&x);
//! println!("storage: {} of dense, rel err {:.4}",
//!          compressed.storage_ratio(), compressed.rel_error(&w));
//! # let _ = y;
//! ```
//!
//! Compression is minutes of SVD work; serving shouldn't repeat it. The
//! [`store`] module persists any compressed matrix as a native `HSB1`
//! artifact (crc-checked, fp16 factors) and loads it back — with its matvec
//! workspace pre-sized — without recompression:
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use hisolo::compress::{Compressor, CompressorConfig, Method};
//! use hisolo::linalg::Matrix;
//! use hisolo::store::{StoreFile, StoreWriter};
//! use std::path::Path;
//!
//! let w = Matrix::randn(256, 256, 42);
//! let cfg = CompressorConfig { rank: 32, sparsity: 0.3, ..Default::default() };
//! let compressed = Compressor::new(cfg).compress(&w, Method::SHssRcm);
//!
//! // save once (atomic temp + rename) ...
//! let mut writer = StoreWriter::new();
//! writer.push("layer0.wq", &compressed);
//! writer.finish(Path::new("layer0.hsb1"))?;
//!
//! // ... cold-start forever: parse only, no SVD — fp16 factors stay
//! // f16-resident (half the bytes) and widen inside the batched kernels
//! let file = StoreFile::open(Path::new("layer0.hsb1"))?;
//! let (loaded, mut ws) = file.load_native_with_workspace("layer0.wq")?;
//! let mut y = vec![0.0f32; 256];
//! loaded.matvec_with(&vec![1.0f32; 256], &mut y, &mut ws);
//! # Ok(())
//! # }
//! ```
//!
//! Whole models go through [`store::ModelStore`] in one of two on-disk
//! forms behind the same [`store::VariantFile`] API: a monolithic `HSB1`
//! file, or the sharded `HSB2` directory (one shard per layer plus a
//! crc-checked manifest, written shards-first/manifest-last so a variant
//! is never visible half-written). `HSB2` payloads keep every value run
//! 8-byte aligned, so on unix the reader mmaps each shard and hands out
//! weight buffers that **borrow the mapping zero-copy**: N serving
//! processes share one page-cache copy of a variant, per-process cold
//! start drops to fault-in time, and the bytes the kernels consume are
//! bit-for-bit the bytes on disk (`HISOLO_MMAP=off` is the kill-switch
//! back to buffered reads). The serving [`coordinator`] cold-starts
//! workers from either form **at the store's dtype** (f16-resident
//! factors — the format's memory claim is the resident memory claim),
//! loads layers in parallel (`CompressedModel::from_store`), reports
//! per-variant `resident_weight_bytes` in its metrics, and atomically
//! hot-swaps a variant under live traffic via
//! `Coordinator::swap_variant` (or `swap_variant_prefetched` /
//! `swap_variant_streamed`, which build the incoming scorer on a helper
//! thread — the streamed form reporting per-layer progress as shards
//! decode).
//!
//! The serving pass itself is **bucket → stack → batched attention**
//! (the paper's "one sparse and a sequence of thin-matrix
//! multiplications", end to end): the batcher coalesces polled requests
//! into power-of-two length buckets (`Batcher::poll_buckets`; padding
//! overhead is a metrics gauge), each bucket is scored in one
//! `forward_batch` that stacks its windows into a single tall [Σt, d]
//! block — one compressed traversal per (layer, projection) for the
//! whole bucket — and causal attention runs as one
//! `model::attention_batch` call per layer over that same block, driven
//! by a per-window offset table. No per-window loop survives anywhere
//! on the hot path; `eval` buckets identically so sweep numbers measure
//! the code that serves.
//!
//! That is the *stateless* path — every request rescores its full
//! window. Conversations use the **session path** instead: a prefill
//! request runs the same batched forward once while writing each
//! layer's K/V rows (f16-quantized in place, so attention consumes the
//! exact bits the cache holds) into a paged pool
//! ([`model::kvcache`] — fixed-size token blocks, prefix-hash page
//! sharing with copy-on-write, LRU session eviction, memory fixed by
//! `--kv-pages`), and each decode request then appends one token in
//! O(t): a single new query row per sequence attends over the cached
//! pages (`model::attention_batch`'s last-row kernel sequence, replayed
//! by `decode_batch`), so decode NLLs are **bit-identical** to
//! rescoring the grown window and `hisolo serve --decode` asserts it.
//! The coordinator buckets decode traffic separately from prefill
//! (class-keyed bucketing), and cache hit/occupancy gauges ride the
//! metrics JSON; see [`coordinator`] for the session lifecycle.
//!
//! # The SIMD kernel layer
//!
//! Every dense multiply, widen, softmax, and layernorm on the hot path
//! bottoms out in [`linalg::simd`]: a small set of explicit-width kernels
//! (`dot8_acc`, `gemm_nt_microkernel`, `axpy_k`, `widen_f16_lanes`,
//! `exp_softmax_row`, `layernorm_row`) behind one safe
//! `simd::kernels() -> &KernelDispatch` table, selected once per process.
//!
//! - **Dispatch policy**: runtime detection — AVX2+FMA+F16C on x86_64
//!   (`is_x86_feature_detected!`), NEON on aarch64, portable scalar
//!   everywhere else and under `HISOLO_SIMD=off`. No compile-time feature
//!   flags; one binary serves every host.
//! - **ULP contract**: every accelerated arm is **bit-identical (0 ULP)**
//!   to the scalar arm — same multiply/add split (no FMA contraction),
//!   same 8-lane accumulator shapes reduced by the same
//!   `simd::hsum8_tree` fold, tails summed sequentially after the tree,
//!   and a shared polynomial `exp`. Changing the active level can never
//!   change a logit bit, which is what lets the serving stack keep its
//!   bit-reproducibility guarantees (batch-invariance, f16 == quantized
//!   f32) independent of the host CPU.
//! - **Fusion**: batch widths are rounded up to lane multiples
//!   (`simd::padded_k`) with zero columns so kernels run tail-free, and
//!   the transformer folds residual-add + layernorm (+ the f16 re-widen
//!   on staged paths) into single row passes — the avoided activation
//!   round-trips surface as `bytes_saved_fusion` in the metrics JSON.
//!   See [`linalg::simd`] for the full contract and how to add an
//!   architecture.
//!
//! One-shot compression is only half the paper's deployment story: the
//! [`train`] module fine-tunes the surviving factor values end-to-end
//! against the dense teacher (layer-wise ‖W x − Ŵ x‖² calibration with
//! SGD/Adam, frozen sparsity patterns), and the refined model rides the
//! same store → hot-swap path (`hisolo finetune` on the CLI).
//!
//! # Observability
//!
//! The serving stack is traced at stage granularity by [`obs`]: RAII span
//! guards around every batched kernel call (`spmm`, `hss_walk`, `lowrank`,
//! `attention`, `mlp`, `softmax`) and every coordinator hop (`queue_wait`,
//! `bucket_form`, `reply_route`, `swap_install`), each backed by the same
//! lock-free log-bucketed histogram the coordinator's `Metrics` uses for
//! request latency. `Metrics` additionally splits every request's
//! end-to-end latency into queue-wait + service (they sum exactly) and
//! carries queue-depth / in-flight gauges. `Metrics::to_json()` exports
//! the whole picture — counters, p50/p95/p99/p999, gauges, per-stage
//! breakdown — through [`util::json`]; `hisolo serve --metrics-json <path>
//! --metrics-interval-secs N` emits periodic snapshots, and
//! `HISOLO_LOG=off` / `HISOLO_TRACE=off` silence the reporter and the span
//! guards respectively. See [`obs`] for the stage taxonomy and the
//! span-guard rules for hot loops.
//!
//! Aggregates answer "where do microseconds go on average"; the
//! **per-request flight recorder** (`obs::recorder`) answers "why was
//! *this* request slow". Every request is minted a `TraceId` at
//! `Coordinator::submit` and carries it to the reply; the worker opens a
//! batch context per scored chunk so each kernel span attributes to every
//! trace the batch served. Events land in bounded lock-light rings
//! (~3 MiB at the default capacities — memory never grows with uptime;
//! old events are overwritten), while **tail sampling** keeps the
//! slowest-N requests *with a copy of their batch's spans* across
//! wraparound. `hisolo serve --trace-out t.json` enables recording and
//! writes a Chrome trace-event / Perfetto JSON export; `hisolo trace
//! t.json` prints per-trace critical paths offline. `HISOLO_TRACE=off`
//! also strips kernel spans from traces (span guards are inert), leaving
//! request lifecycles only.
//!
//! **SLO burn rate**: `hisolo serve --slo-p99-us N` arms an error budget
//! in `Metrics` — 1% of requests may exceed the target p99
//! (`SLO_EPSILON`); `burn_rate = violation_rate / 0.01`, so burn 1.0
//! consumes the budget exactly as fast as it accrues. The lifetime rate,
//! a rolling-window rate (advanced once per reporter tick), and the
//! remaining budget surface in `Metrics::summary`, `Metrics::to_json`
//! (`slo` object), and serve's `slo_burn_check` line.

pub mod compress;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod hss;
pub mod linalg;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod sparse;
pub mod store;
pub mod train;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
