//! # hisolo — Hierarchical Sparse Plus Low-Rank compression of LLMs
//!
//! A production-shaped reproduction of *"Hierarchical Sparse Plus Low Rank
//! Compression of LLM"* (Kumar & Gupta, CODS '25) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **L1/L2 (build path, python/)** — Pallas kernels + the JAX transformer
//!   are lowered once to HLO text artifacts (`make artifacts`).
//! - **L3 (this crate)** — everything at runtime: the compression library
//!   itself (native [`linalg`], [`sparse`], [`hss`], [`compress`]), the
//!   model/eval harness ([`model`], [`data`], [`eval`]), the PJRT runtime
//!   ([`runtime`]) and the serving coordinator ([`coordinator`]).
//!
//! The paper's method, in one expression:
//!
//! ```text
//! W  ≈  S  +  Pᵀ · [ D₀      U₀R₀ᵀ ] · P         (recursively, per level:
//!             [ U₁R₁ᵀ   D₁    ]                 sparse spikes out, RCM
//!                                               reorder, 2×2 split, low-rank
//!                                               off-diagonals, rank halves)
//! ```
//!
//! Quickstart (after `make artifacts`):
//!
//! ```no_run
//! use hisolo::compress::{Compressor, CompressorConfig, Method};
//! use hisolo::linalg::Matrix;
//!
//! let w = Matrix::randn(256, 256, 42);
//! let cfg = CompressorConfig { rank: 32, sparsity: 0.3, ..Default::default() };
//! let compressed = Compressor::new(cfg).compress(&w, Method::SHssRcm);
//! let x = vec![1.0f32; 256];
//! let y = compressed.matvec(&x);
//! println!("storage: {} of dense, rel err {:.4}",
//!          compressed.storage_ratio(), compressed.rel_error(&w));
//! # let _ = y;
//! ```

pub mod compress;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod hss;
pub mod linalg;
pub mod model;
pub mod runtime;
pub mod sparse;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
