//! Transformer with compressed q/k/v projections — the deployable unit the
//! paper produces (everything else left dense, matching §5's targeting of
//! q_proj/k_proj/v_proj only).
//!
//! # Observability
//!
//! A forward through this model is fully covered by the stage spans of
//! [`crate::obs`]: the compressed q/k/v applies report as `lowrank` +
//! `spmm` (and `hss_walk` when the factor is hierarchical), the attention
//! kernel as `attention`, the dense FFN as `mlp`, and the output
//! log-softmax as `softmax`. Dense projections inside the base
//! transformer are deliberately unspanned — they are the baseline the
//! compressed stages are compared against, and the `mlp` stage already
//! bounds their cost class.

use crate::compress::pipeline::{compress_model_qkv, summarize, LayerReport};
use crate::compress::{CompressedMatrix, CompressorConfig, Method};
use crate::linalg::simd;
use crate::linalg::Matrix;
use crate::model::transformer::{Proj, QkvProjector, Transformer};
use std::sync::Arc;

/// A base model plus one compressed matrix per q/k/v projection.
/// Owns the base via `Arc` so serving workers can hold it across threads.
pub struct CompressedModel {
    pub base: Arc<Transformer>,
    pub method: Method,
    /// per layer: [q, k, v] — each stores A = Wᵀ (column convention)
    pub qkv: Vec<[CompressedMatrix; 3]>,
    pub reports: Vec<LayerReport>,
}

impl CompressedModel {
    /// Compress the base model's q/k/v with the given method/config.
    pub fn compress(base: Arc<Transformer>, method: Method, cfg: CompressorConfig) -> Self {
        let projections = base.qkv_projections();
        let mut reports = compress_model_qkv(&projections, method, cfg);
        let mut qkv = Vec::with_capacity(base.cfg.n_layers);
        let mut drain = reports.drain(..).collect::<Vec<_>>();
        // reports come in (wq, wk, wv) per layer order
        let mut kept = Vec::with_capacity(drain.len());
        for _ in 0..base.cfg.n_layers {
            let q = drain.remove(0);
            let k = drain.remove(0);
            let v = drain.remove(0);
            qkv.push([
                q.compressed.clone_shallow(),
                k.compressed.clone_shallow(),
                v.compressed.clone_shallow(),
            ]);
            kept.push(q);
            kept.push(k);
            kept.push(v);
        }
        CompressedModel {
            base,
            method,
            qkv,
            reports: kept,
        }
    }

    /// Rebuild from a store variant **without recompression** — the
    /// cold-start path. The variant (monolithic `HSB1` or sharded `HSB2`)
    /// must hold `layer{i}.{wq,wk,wv}` for every layer of `base`; layer
    /// reports are reconstructed from the stored metadata (method,
    /// compression-time rel error) plus the decoded matrices' own storage
    /// accounting.
    ///
    /// Entries keep their **on-disk dtype**: fp16 factors stay f16-resident
    /// (the batched kernels widen lane-by-lane), so a served model is
    /// resident at the bytes the format pays for — no load-time widening.
    /// With a sharded mmap'd variant the factors aren't even copied: the
    /// weight buffers borrow the mapping, shared page-cache-cold across
    /// every serving process on the host. Training a store-loaded model
    /// requires [`CompressedModel::widen_to_f32`] first.
    ///
    /// Layers decode **in parallel** across scoped threads — per-layer
    /// loads are independent (per-shard for `HSB2`, per-section for
    /// `HSB1`), so cold-start wall time is the slowest layer, not the sum.
    pub fn from_store(
        base: Arc<Transformer>,
        store: &crate::store::VariantFile,
    ) -> anyhow::Result<CompressedModel> {
        CompressedModel::from_store_with_progress(base, store, |_, _| {})
    }

    /// [`CompressedModel::from_store`] invoking `on_layer(layer, took)`
    /// as each layer's q/k/v triple finishes decoding — the hook the
    /// streaming hot-swap path uses to surface per-layer progress while
    /// the load is still running. Called from the loader's worker
    /// threads, completion order, not layer order.
    pub fn from_store_with_progress(
        base: Arc<Transformer>,
        store: &crate::store::VariantFile,
        on_layer: impl Fn(usize, std::time::Duration) + Sync,
    ) -> anyhow::Result<CompressedModel> {
        let d = base.cfg.d_model;
        let dense_bytes = d * d * crate::hss::storage::VALUE_BYTES;
        let n_layers = base.cfg.n_layers;

        // one independently-loadable unit per layer, claimed off a shared
        // counter so fast layers don't idle a thread while slow ones run
        type LayerLoad = (Vec<LayerReport>, Vec<CompressedMatrix>);
        fn load_layer(
            store: &crate::store::VariantFile,
            layer: usize,
            d: usize,
            dense_bytes: usize,
        ) -> anyhow::Result<LayerLoad> {
            let mut triple: Vec<CompressedMatrix> = Vec::with_capacity(3);
            let mut reports = Vec::with_capacity(3);
            for p in [Proj::Q, Proj::K, Proj::V] {
                let name = crate::store::entry_name(layer, p);
                let meta = store
                    .meta(&name)
                    .ok_or_else(|| anyhow::anyhow!("store is missing entry '{name}'"))?
                    .clone();
                let c = store.load_native(&name)?;
                if c.n() != d {
                    anyhow::bail!(
                        "entry '{name}' has n={} but the base model has d_model={d}",
                        c.n()
                    );
                }
                reports.push(LayerReport {
                    name,
                    method: meta.method_or_default(),
                    rel_error: meta.rel_error,
                    params: c.params(),
                    bytes: c.bytes(),
                    dense_bytes,
                    compressed: c.clone_shallow(),
                });
                triple.push(c);
            }
            Ok((reports, triple))
        }

        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(n_layers.max(1));
        let slots: Vec<std::sync::Mutex<Option<anyhow::Result<LayerLoad>>>> =
            (0..n_layers).map(|_| std::sync::Mutex::new(None)).collect();
        let next = std::sync::atomic::AtomicUsize::new(0);
        let on_layer = &on_layer;
        if threads <= 1 {
            for layer in 0..n_layers {
                let t0 = std::time::Instant::now();
                let r = load_layer(store, layer, d, dense_bytes);
                let ok = r.is_ok();
                *slots[layer].lock().unwrap() = Some(r);
                if ok {
                    on_layer(layer, t0.elapsed());
                }
            }
        } else {
            std::thread::scope(|s| {
                for _ in 0..threads {
                    s.spawn(|| loop {
                        let layer = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if layer >= n_layers {
                            break;
                        }
                        let t0 = std::time::Instant::now();
                        let r = load_layer(store, layer, d, dense_bytes);
                        let ok = r.is_ok();
                        *slots[layer].lock().unwrap() = Some(r);
                        if ok {
                            on_layer(layer, t0.elapsed());
                        }
                    });
                }
            });
        }

        let mut qkv = Vec::with_capacity(n_layers);
        let mut reports = Vec::with_capacity(3 * n_layers);
        let mut method: Option<Method> = None;
        for (layer, slot) in slots.into_iter().enumerate() {
            let (layer_reports, triple) = slot
                .into_inner()
                .unwrap()
                .unwrap_or_else(|| panic!("layer {layer} never loaded"))?;
            for r in &layer_reports {
                method.get_or_insert(r.method);
            }
            reports.extend(layer_reports);
            let mut it = triple.into_iter();
            qkv.push([
                it.next().unwrap(),
                it.next().unwrap(),
                it.next().unwrap(),
            ]);
        }
        Ok(CompressedModel {
            base,
            method: method.unwrap_or(Method::Dense),
            qkv,
            reports,
        })
    }

    /// Logits [t, vocab] through the compressed projections.
    pub fn forward(&self, tokens: &[u32]) -> Matrix {
        self.base.forward_with(tokens, self)
    }

    /// Batched forward: logits per window, with each compressed q/k/v
    /// projection applied to the whole batch in **one**
    /// [`CompressedMatrix::apply_batch`] traversal per (layer, projection).
    pub fn forward_batch(&self, windows: &[&[u32]]) -> Vec<Matrix> {
        self.base.forward_batch_with(windows, self)
    }

    /// Storage of the compressed q/k/v subset at fp16, paper-style (stored
    /// values only; index overhead reported separately by `qkv_raw_bytes`).
    pub fn qkv_bytes(&self) -> usize {
        summarize(&self.reports).total_params * crate::hss::storage::VALUE_BYTES
    }

    /// Byte count including sparse-index/permutation overhead.
    pub fn qkv_raw_bytes(&self) -> usize {
        summarize(&self.reports).total_bytes
    }

    /// Dense fp16 bytes of the same subset.
    pub fn qkv_dense_bytes(&self) -> usize {
        summarize(&self.reports).total_dense_bytes
    }

    /// Whole-model storage ratio counting non-qkv params as dense (the
    /// paper's storage axis: only q/k/v shrink).
    pub fn model_storage_ratio(&self) -> f64 {
        let total_dense =
            self.base.cfg.param_count() * crate::hss::storage::VALUE_BYTES;
        let qkv_dense = self.qkv_dense_bytes();
        let rest = total_dense - qkv_dense;
        (rest + self.qkv_bytes()) as f64 / total_dense as f64
    }

    pub fn mean_rel_error(&self) -> f64 {
        summarize(&self.reports).mean_rel_error
    }

    /// Narrow every compressed factor to f16 residency in place
    /// (idempotent) — both the served `qkv` matrices and the layer
    /// reports' copies, so whole-process factor memory really halves.
    /// Serving numerics are bit-identical to applying the fp16-quantized
    /// values at f32 residency — only the memory halves.
    pub fn narrow_to_f16(&mut self) {
        for triple in &mut self.qkv {
            for m in triple {
                m.narrow_to_f16();
            }
        }
        for r in &mut self.reports {
            r.compressed.narrow_to_f16();
        }
    }

    /// Widen every compressed factor back to f32 residency (exact;
    /// idempotent) — required before `train::calibrate` touches the
    /// model (both `qkv` and the report copies the refine stage trains).
    pub fn widen_to_f32(&mut self) {
        for triple in &mut self.qkv {
            for m in triple {
                m.widen_to_f32();
            }
        }
        for r in &mut self.reports {
            r.compressed.widen_to_f32();
        }
    }

    /// Dtype of the served (q/k/v) weight buffers.
    pub fn weights_dtype(&self) -> crate::linalg::Dtype {
        self.qkv
            .first()
            .map(|t| t[0].weights_dtype())
            .unwrap_or(crate::linalg::Dtype::F32)
    }

    /// Bytes actually resident for the variant-specific (compressed q/k/v)
    /// weights at their current dtype — the number the coordinator reports
    /// per scorer and logs on hot-swap.
    pub fn resident_weight_bytes(&self) -> usize {
        self.qkv
            .iter()
            .flat_map(|t| t.iter())
            .map(|m| m.resident_weight_bytes())
            .sum()
    }
}

thread_local! {
    /// Per-thread apply scratch for the serving projector: `ensure` only
    /// ever grows it, so one workspace serves every layer's q/k/v (and
    /// every model on this thread) with no allocation after warmup.
    static PROJECT_WS: std::cell::RefCell<crate::compress::BatchWorkspace> =
        std::cell::RefCell::new(crate::compress::BatchWorkspace::default());
}

impl QkvProjector for CompressedModel {
    fn project(&self, layer: usize, which: Proj, a: &Matrix) -> Matrix {
        let c = match which {
            Proj::Q => &self.qkv[layer][0],
            Proj::K => &self.qkv[layer][1],
            Proj::V => &self.qkv[layer][2],
        };
        if a.rows == 0 {
            return Matrix::zeros(0, a.cols);
        }
        // c stores A = Wᵀ so Outᵀ = A · aᵀ: transpose the activations into
        // a column block and run ONE batched traversal for all rows of `a`
        // (every token of every stacked window at once), instead of one
        // tree walk / spmv per token. The batch width (k = tokens) is the
        // SIMD lane axis of every kernel under `apply_batch_with`, so pad
        // it to a lane multiple with zero columns: input columns are
        // independent, so the pad lanes stay zero end-to-end and the real
        // columns are bit-identical — the kernels just run whole lane
        // groups with no scalar tail.
        let (t, d) = (a.rows, a.cols);
        let kp = simd::padded_k(t);
        let mut xt = vec![0.0f32; d * kp];
        for i in 0..t {
            let row = a.row(i);
            for j in 0..d {
                xt[j * kp + i] = row[j];
            }
        }
        let mut yt = vec![0.0f32; d * kp];
        PROJECT_WS.with(|ws| c.apply_batch_with(&xt, &mut yt, kp, &mut ws.borrow_mut()));
        // transpose back, dropping the pad columns
        let mut out = Matrix::zeros(t, d);
        for i in 0..t {
            let orow = out.row_mut(i);
            for j in 0..d {
                orow[j] = yt[j * kp + i];
            }
        }
        out
    }
}

impl CompressedMatrix {
    /// Cheap structural clone (weights are shared semantics-free copies;
    /// used when a report and the model both need the matrix).
    pub fn clone_shallow(&self) -> CompressedMatrix {
        match self {
            CompressedMatrix::Dense { w } => CompressedMatrix::Dense { w: w.clone() },
            CompressedMatrix::LowRank { l, r, sparse } => CompressedMatrix::LowRank {
                l: l.clone(),
                r: r.clone(),
                sparse: sparse.clone(),
            },
            CompressedMatrix::Hss { tree } => CompressedMatrix::Hss { tree: tree.clone() },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            vocab: 64,
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            d_ff: 128,
            seq_len: 16,
        }
    }

    #[test]
    fn near_exact_compression_matches_dense_forward() {
        let base = std::sync::Arc::new(Transformer::random(tiny_cfg(), 1));
        // depth-1, full off-diag rank, exact SVD => near-lossless
        let cfg = CompressorConfig {
            rank: 32,
            sparsity: 0.2,
            depth: 1,
            hss_rsvd: false,
            min_leaf: 4,
            ..Default::default()
        };
        let cm = CompressedModel::compress(base.clone(), Method::SHssRcm, cfg);
        let tokens: Vec<u32> = (0..16).map(|i| (i * 3) % 64).collect();
        let dense = base.forward(&tokens);
        let comp = cm.forward(&tokens);
        let mut max_diff = 0.0f32;
        for (a, b) in dense.data.iter().zip(&comp.data) {
            max_diff = max_diff.max((a - b).abs());
        }
        assert!(max_diff < 2e-2, "max logit diff {max_diff}");
    }

    #[test]
    fn lossy_compression_still_finite() {
        let base = std::sync::Arc::new(Transformer::random(tiny_cfg(), 2));
        let cfg = CompressorConfig {
            rank: 4,
            sparsity: 0.1,
            depth: 2,
            min_leaf: 4,
            ..Default::default()
        };
        for m in [Method::SSvd, Method::SRsvd, Method::SHss, Method::SHssRcm] {
            let cm = CompressedModel::compress(base.clone(), m, cfg);
            let tokens: Vec<u32> = (0..16).map(|i| i % 64).collect();
            let logits = cm.forward(&tokens);
            assert!(logits.data.iter().all(|v| v.is_finite()), "{m:?}");
            assert!(cm.qkv_bytes() < cm.qkv_dense_bytes(), "{m:?}");
        }
    }

    #[test]
    fn model_storage_ratio_below_one_when_compressed() {
        let base = std::sync::Arc::new(Transformer::random(tiny_cfg(), 3));
        let cfg = CompressorConfig {
            rank: 4,
            sparsity: 0.05,
            depth: 2,
            min_leaf: 4,
            ..Default::default()
        };
        let cm = CompressedModel::compress(base.clone(), Method::SHssRcm, cfg);
        let ratio = cm.model_storage_ratio();
        assert!(ratio < 1.0 && ratio > 0.3, "ratio {ratio}");
    }

    #[test]
    fn reports_cover_all_projections() {
        let base = std::sync::Arc::new(Transformer::random(tiny_cfg(), 4));
        let cm = CompressedModel::compress(
            base.clone(),
            Method::SSvd,
            CompressorConfig {
                rank: 8,
                ..Default::default()
            },
        );
        assert_eq!(cm.reports.len(), 6);
        assert_eq!(cm.qkv.len(), 2);
    }
}
