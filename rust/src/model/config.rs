//! Transformer configuration (mirrors `python/compile/model.py::CONFIG`).

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub seq_len: usize,
}

impl Default for ModelConfig {
    /// The artifact model (DESIGN.md §2 scaling of LLaMA-7B).
    fn default() -> Self {
        ModelConfig {
            vocab: 256,
            d_model: 256,
            n_heads: 8,
            n_layers: 4,
            d_ff: 1024,
            seq_len: 128,
        }
    }
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let per_layer = 4 * d * d + 2 * d * self.d_ff + self.d_ff + d + 4 * d;
        self.vocab * d + self.seq_len * d + self.n_layers * per_layer + 2 * d
    }

    /// q/k/v parameters (the paper's compression target subset).
    pub fn qkv_params(&self) -> usize {
        3 * self.n_layers * self.d_model * self.d_model
    }

    /// Parse the `model_config` object of artifacts/manifest.json.
    pub fn from_manifest(j: &Json) -> anyhow::Result<ModelConfig> {
        let mc = j
            .get("model_config")
            .ok_or_else(|| anyhow::anyhow!("manifest missing model_config"))?;
        let field = |k: &str| -> anyhow::Result<usize> {
            mc.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow::anyhow!("model_config missing {k}"))
        };
        Ok(ModelConfig {
            vocab: field("vocab")?,
            d_model: field("d_model")?,
            n_heads: field("n_heads")?,
            n_layers: field("n_layers")?,
            d_ff: field("d_ff")?,
            seq_len: field("seq_len")?,
        })
    }

    /// Canonical parameter order — must match python `model.param_names()`.
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["tok_emb".to_string(), "pos_emb".to_string()];
        for i in 0..self.n_layers {
            for p in [
                "ln1_g", "ln1_b", "wq", "wk", "wv", "wo", "ln2_g", "ln2_b", "w1", "b1", "w2",
                "b2",
            ] {
                names.push(format!("layer{i}.{p}"));
            }
        }
        names.push("lnf_g".to_string());
        names.push("lnf_b".to_string());
        names
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_python() {
        let c = ModelConfig::default();
        assert_eq!(c.head_dim(), 32);
        assert_eq!(c.param_names().len(), 2 + 4 * 12 + 2);
        assert_eq!(c.param_names()[2], "layer0.ln1_g");
        assert_eq!(c.qkv_params(), 3 * 4 * 256 * 256);
    }

    #[test]
    fn from_manifest_parses() {
        let j = Json::parse(
            r#"{"model_config": {"vocab": 256, "d_model": 64, "n_heads": 4,
                "n_layers": 2, "d_ff": 128, "seq_len": 32}}"#,
        )
        .unwrap();
        let c = ModelConfig::from_manifest(&j).unwrap();
        assert_eq!(c.d_model, 64);
        assert_eq!(c.n_layers, 2);
    }

    #[test]
    fn from_manifest_rejects_missing() {
        let j = Json::parse(r#"{"model_config": {"vocab": 256}}"#).unwrap();
        assert!(ModelConfig::from_manifest(&j).is_err());
    }
}
