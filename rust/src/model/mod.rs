//! The substitute transformer LM on the Rust side: config, `.hwt` weight
//! IO (shared binary contract with `python/compile/hwt.py`), byte
//! tokenizer, the native forward pass, and the compressed-projection
//! variant used by the evaluation harness.

pub mod attention;
pub mod compressed_model;
pub mod config;
pub mod kvcache;
pub mod tokenizer;
pub mod transformer;
pub mod weights;

pub use attention::{attention_batch, causal_mha, decode_batch, AttnWorkspace};
pub use kvcache::{KvCacheConfig, KvState, KvStatsSnapshot, PagePool, SeqKv};

pub use compressed_model::CompressedModel;
pub use config::ModelConfig;
pub use transformer::Transformer;
pub use weights::WeightFile;
