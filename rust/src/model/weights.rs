//! HWT weight container (Rust side of the cross-language contract).
//!
//! Format (little endian, see `python/compile/hwt.py`):
//! `"HWT1"` · u32 count · per tensor: u32 name-len, name, u8 dtype
//! (0=f32, 1=f16, 2=i32), u32 ndim, u32×ndim dims, raw data.

use crate::linalg::Matrix;
use crate::util::binio::{
    check_magic, read_exact_vec, read_string, read_u32, read_u8, write_string, write_u32,
    write_u8, DT_F16, DT_F32, DT_I32,
};
use crate::util::fp16;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

const MAGIC: &[u8; 4] = b"HWT1";

/// One named tensor; data always widened to f32 in memory (i32 kept raw).
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub f32_data: Vec<f32>,
    pub i32_data: Vec<i32>,
    pub dtype: Dtype,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F16,
    I32,
}

impl Tensor {
    pub fn len(&self) -> usize {
        if self.dims.is_empty() {
            1
        } else {
            self.dims.iter().product()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Interpret as a 2-D matrix.
    pub fn to_matrix(&self) -> Result<Matrix> {
        if self.dims.len() != 2 {
            bail!("tensor {} has rank {} (want 2)", self.name, self.dims.len());
        }
        Ok(Matrix::from_vec(
            self.dims[0],
            self.dims[1],
            self.f32_data.clone(),
        ))
    }

    pub fn to_vec1(&self) -> Result<Vec<f32>> {
        if self.dims.len() != 1 {
            bail!("tensor {} has rank {} (want 1)", self.name, self.dims.len());
        }
        Ok(self.f32_data.clone())
    }
}

/// An ordered collection of named tensors (order = AOT operand order).
#[derive(Default)]
pub struct WeightFile {
    pub tensors: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl WeightFile {
    pub fn load(path: &Path) -> Result<WeightFile> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        check_magic(&mut f, MAGIC, "HWT1")
            .with_context(|| format!("{}", path.display()))?;
        let count = read_u32(&mut f)? as usize;
        let mut out = WeightFile::default();
        for _ in 0..count {
            let name = read_string(&mut f).context("tensor name")?;
            let dtype_code = read_u8(&mut f)?;
            let ndim = read_u32(&mut f)? as usize;
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(read_u32(&mut f)? as usize);
            }
            // scalar (ndim=0) has one element; an explicit 0-dim is empty
            let count: usize = if dims.is_empty() {
                1
            } else {
                dims.iter().product()
            };
            let (dtype, f32_data, i32_data) = match dtype_code {
                DT_F32 => {
                    let raw = read_exact_vec(&mut f, count * 4)?;
                    let data = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    (Dtype::F32, data, Vec::new())
                }
                DT_F16 => {
                    let raw = read_exact_vec(&mut f, count * 2)?;
                    (Dtype::F16, fp16::decode_f16_le(&raw), Vec::new())
                }
                DT_I32 => {
                    let raw = read_exact_vec(&mut f, count * 4)?;
                    let data: Vec<i32> = raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    (Dtype::I32, Vec::new(), data)
                }
                d => bail!("unknown dtype code {d}"),
            };
            out.push(Tensor {
                name,
                dims,
                f32_data,
                i32_data,
                dtype,
            });
        }
        Ok(out)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        f.write_all(MAGIC)?;
        write_u32(&mut f, self.tensors.len() as u32)?;
        for t in &self.tensors {
            write_string(&mut f, &t.name)?;
            let code: u8 = match t.dtype {
                Dtype::F32 => DT_F32,
                Dtype::F16 => DT_F16,
                Dtype::I32 => DT_I32,
            };
            write_u8(&mut f, code)?;
            write_u32(&mut f, t.dims.len() as u32)?;
            for &d in &t.dims {
                write_u32(&mut f, d as u32)?;
            }
            match t.dtype {
                Dtype::F32 => {
                    for v in &t.f32_data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
                Dtype::F16 => f.write_all(&fp16::encode_f16_le(&t.f32_data))?,
                Dtype::I32 => {
                    for v in &t.i32_data {
                        f.write_all(&v.to_le_bytes())?;
                    }
                }
            }
        }
        Ok(())
    }

    pub fn push(&mut self, t: Tensor) {
        self.index.insert(t.name.clone(), self.tensors.len());
        self.tensors.push(t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| anyhow!("tensor '{name}' not found"))
    }

    pub fn matrix(&self, name: &str) -> Result<Matrix> {
        self.get(name)?.to_matrix()
    }

    pub fn vec1(&self, name: &str) -> Result<Vec<f32>> {
        self.get(name)?.to_vec1()
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.iter().map(|t| t.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor_f32(name: &str, dims: Vec<usize>, data: Vec<f32>) -> Tensor {
        Tensor {
            name: name.to_string(),
            dims,
            f32_data: data,
            i32_data: Vec::new(),
            dtype: Dtype::F32,
        }
    }

    #[test]
    fn roundtrip_f32_f16_i32() {
        let dir = std::env::temp_dir().join("hisolo_test_hwt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.hwt");
        let mut wf = WeightFile::default();
        wf.push(tensor_f32("a", vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        wf.push(Tensor {
            name: "h".into(),
            dims: vec![4],
            f32_data: vec![0.5, -1.5, 2.0, 0.0],
            i32_data: Vec::new(),
            dtype: Dtype::F16,
        });
        wf.push(Tensor {
            name: "i".into(),
            dims: vec![2],
            f32_data: Vec::new(),
            i32_data: vec![7, -9],
            dtype: Dtype::I32,
        });
        wf.save(&path).unwrap();
        let back = WeightFile::load(&path).unwrap();
        assert_eq!(back.names(), vec!["a", "h", "i"]);
        assert_eq!(back.get("a").unwrap().f32_data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(back.get("h").unwrap().f32_data, vec![0.5, -1.5, 2.0, 0.0]);
        assert_eq!(back.get("i").unwrap().i32_data, vec![7, -9]);
        let m = back.matrix("a").unwrap();
        assert_eq!((m.rows, m.cols), (2, 3));
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("hisolo_test_hwt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.hwt");
        std::fs::write(&path, b"NOPE\x00\x00\x00\x00").unwrap();
        assert!(WeightFile::load(&path).is_err());
    }

    #[test]
    fn missing_tensor_error() {
        let wf = WeightFile::default();
        assert!(wf.get("nope").is_err());
    }

    #[test]
    fn reads_python_written_artifacts_if_present() {
        // cross-language check against the real artifact (skipped if absent)
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/model.hwt");
        if !path.exists() {
            return;
        }
        let wf = WeightFile::load(&path).unwrap();
        assert_eq!(wf.tensors[0].name, "tok_emb");
        let m = wf.matrix("layer0.wq").unwrap();
        assert_eq!((m.rows, m.cols), (256, 256));
        assert!(m.data.iter().all(|v| v.is_finite()));
    }
}
