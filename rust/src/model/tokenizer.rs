//! Byte-level tokenizer (vocab = 256), matching the python training path
//! which feeds raw corpus bytes as token ids.

/// Byte tokenizer: token id == byte value.
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: usize = 256;

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    pub fn encode_bytes(&self, bytes: &[u8]) -> Vec<u32> {
        bytes.iter().map(|&b| b as u32).collect()
    }

    /// Lossy decode (invalid utf-8 replaced).
    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| t as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let tok = ByteTokenizer;
        let s = "the model compresses the weight matrix.";
        assert_eq!(tok.decode(&tok.encode(s)), s);
    }

    #[test]
    fn ids_are_bytes() {
        let tok = ByteTokenizer;
        assert_eq!(tok.encode("Ab"), vec![65, 98]);
    }

    #[test]
    fn all_ids_below_vocab() {
        let tok = ByteTokenizer;
        let ids = tok.encode_bytes(&(0..=255u8).collect::<Vec<_>>());
        assert!(ids.iter().all(|&t| (t as usize) < ByteTokenizer::VOCAB));
        assert_eq!(ids.len(), 256);
    }
}
