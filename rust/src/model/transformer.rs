//! Pure-Rust transformer forward pass, numerically mirroring
//! `python/compile/model.py::fwd` (layernorm eps 1e-5, tanh-GELU, causal
//! attention, learned positions, tied output embedding).
//!
//! This is the native eval path: the evaluation harness runs perplexity
//! through it with either dense or compressed q/k/v projections (see
//! [`crate::model::CompressedModel`]); the AOT HLO executables provide the
//! serving path and a cross-check.
//!
//! # Fused residual + layernorm epilogues
//!
//! The batched pass keeps each residual add *pending* instead of
//! materialising it eagerly: the attention residual is folded into `h`
//! inside the same row pass that computes ln2's output, and the MLP
//! residual is carried across the layer boundary and folded inside the
//! next layer's ln1 (or the final layernorm). Each fusion point
//! ([`fused_add_layernorm`]) touches every activation row exactly once —
//! add in place, then [`crate::linalg::simd`]'s `layernorm_row` kernel on
//! the freshly written (cache-hot) row — where the unfused sequence
//! (`h = h.add(&r)` allocate+write, then a separate layernorm read) cost
//! three full [Σt, d] memory round-trips. The avoided traffic is counted
//! in the always-on `bytes_saved_fusion` gauge
//! ([`crate::obs::StageRegistry::add_fusion_saved_bytes`]).
//!
//! Numerics: f32 addition is commutative and the fused add performs the
//! same per-element `h[j] + r[j]`, and both the fused path and the public
//! [`layernorm`] route through the same dispatched `layernorm_row`
//! kernel, so fusion is bit-invisible — `forward_batch` output and the
//! `qkv_inputs` capture are bit-identical to an unfused pass over the
//! same kernels.

use crate::linalg::simd;
use crate::linalg::Matrix;
use crate::model::weights::WeightFile;
use crate::model::ModelConfig;
use anyhow::Result;

use crate::model::attention::{attention_batch, decode_batch, AttnWorkspace};
use crate::model::kvcache::{PagePool, SeqKv};

thread_local! {
    /// Per-thread attention scratch for the serving forward pass: sized to
    /// the longest window seen on this thread and only ever grown, so one
    /// workspace serves every layer of every batch with zero per-window
    /// allocation after warmup (the attention twin of
    /// `compressed_model::PROJECT_WS`).
    static ATTN_WS: std::cell::RefCell<AttnWorkspace> =
        std::cell::RefCell::new(AttnWorkspace::default());
}

/// Which projection a [`QkvProjector`] is asked for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Proj {
    Q,
    K,
    V,
}

/// Strategy for the q/k/v projections — the only part the compression
/// methods replace.
pub trait QkvProjector {
    /// a: [t, d] activations → [t, d] projection output (rows(a) · W).
    fn project(&self, layer: usize, which: Proj, a: &Matrix) -> Matrix;
}

/// Dense projector reading the original weights.
pub struct DenseProjector<'a> {
    pub layers: &'a [LayerWeights],
}

impl QkvProjector for DenseProjector<'_> {
    fn project(&self, layer: usize, which: Proj, a: &Matrix) -> Matrix {
        let l = &self.layers[layer];
        let w = match which {
            Proj::Q => &l.wq,
            Proj::K => &l.wk,
            Proj::V => &l.wv,
        };
        a.matmul(w)
    }
}

pub struct LayerWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Matrix,
    pub wk: Matrix,
    pub wv: Matrix,
    pub wo: Matrix,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Matrix,
    pub b1: Vec<f32>,
    pub w2: Matrix,
    pub b2: Vec<f32>,
}

pub struct Transformer {
    pub cfg: ModelConfig,
    pub tok_emb: Matrix,
    pub pos_emb: Matrix,
    pub layers: Vec<LayerWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
}

impl Transformer {
    /// Load from a `.hwt` weight file in canonical order.
    pub fn from_weights(wf: &WeightFile, cfg: ModelConfig) -> Result<Transformer> {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |s: &str| format!("layer{i}.{s}");
            layers.push(LayerWeights {
                ln1_g: wf.vec1(&p("ln1_g"))?,
                ln1_b: wf.vec1(&p("ln1_b"))?,
                wq: wf.matrix(&p("wq"))?,
                wk: wf.matrix(&p("wk"))?,
                wv: wf.matrix(&p("wv"))?,
                wo: wf.matrix(&p("wo"))?,
                ln2_g: wf.vec1(&p("ln2_g"))?,
                ln2_b: wf.vec1(&p("ln2_b"))?,
                w1: wf.matrix(&p("w1"))?,
                b1: wf.vec1(&p("b1"))?,
                w2: wf.matrix(&p("w2"))?,
                b2: wf.vec1(&p("b2"))?,
            });
        }
        Ok(Transformer {
            cfg,
            tok_emb: wf.matrix("tok_emb")?,
            pos_emb: wf.matrix("pos_emb")?,
            layers,
            lnf_g: wf.vec1("lnf_g")?,
            lnf_b: wf.vec1("lnf_b")?,
        })
    }

    /// Random-init model (tests/benches).
    pub fn random(cfg: ModelConfig, seed: u64) -> Transformer {
        let d = cfg.d_model;
        let scale = |m: Matrix, fan_in: usize| m.scale(1.0 / (fan_in as f32).sqrt());
        let mut s = seed;
        let mut next = || {
            s += 1;
            s
        };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                ln1_g: vec![1.0; d],
                ln1_b: vec![0.0; d],
                wq: scale(Matrix::randn(d, d, next()), d),
                wk: scale(Matrix::randn(d, d, next()), d),
                wv: scale(Matrix::randn(d, d, next()), d),
                wo: scale(Matrix::randn(d, d, next()), d),
                ln2_g: vec![1.0; d],
                ln2_b: vec![0.0; d],
                w1: scale(Matrix::randn(d, cfg.d_ff, next()), d),
                b1: vec![0.0; cfg.d_ff],
                w2: scale(Matrix::randn(cfg.d_ff, d, next()), cfg.d_ff),
                b2: vec![0.0; d],
            })
            .collect();
        Transformer {
            cfg,
            tok_emb: scale(Matrix::randn(cfg.vocab, d, next()), cfg.vocab),
            pos_emb: scale(Matrix::randn(cfg.seq_len, d, next()), cfg.seq_len),
            layers,
            lnf_g: vec![1.0; d],
            lnf_b: vec![0.0; d],
        }
    }

    /// The (name, Wᵀ-untransposed) q/k/v projections — compression targets.
    pub fn qkv_projections(&self) -> Vec<(String, Matrix)> {
        let mut out = Vec::new();
        for (i, l) in self.layers.iter().enumerate() {
            out.push((format!("layer{i}.wq"), l.wq.clone()));
            out.push((format!("layer{i}.wk"), l.wk.clone()));
            out.push((format!("layer{i}.wv"), l.wv.clone()));
        }
        out
    }

    /// Logits [t, vocab] for one token window, with the given projector —
    /// the batch-of-one case of [`Transformer::forward_batch_with`].
    pub fn forward_with<P: QkvProjector>(&self, tokens: &[u32], proj: &P) -> Matrix {
        self.forward_batch_with(&[tokens], proj)
            .pop()
            .expect("one window in, one logits matrix out")
    }

    /// Batched forward: logits per window, with every q/k/v projection
    /// applied to the **whole batch at once**. The windows' activations
    /// are stacked into one tall [Σt, d] block, so a compressed projector
    /// traverses its sparse-plus-low-rank structure once per (layer,
    /// projection) for the entire batch instead of once per window (or,
    /// pre-batching, once per token). Attention runs as one
    /// [`attention_batch`] call per layer, driven by the windows' offset
    /// table — there is no per-window loop left in the pass.
    pub fn forward_batch_with<P: QkvProjector>(&self, windows: &[&[u32]], proj: &P) -> Vec<Matrix> {
        self.forward_batch_inner(windows, proj, None, None)
    }

    /// Cache-writing prefill: `forward_batch_with` that additionally
    /// quantizes every layer's K/V rows to f16 **in place** (attention
    /// consumes the round-tripped values — exactly the bits the pages
    /// hold) and stores them into each window's paged cache. `seqs[w]`
    /// must have a block table covering `windows[w].len()` tokens
    /// (`KvState` acquires it, reusing prefix-shared pages, whose writes
    /// are skipped). Because decode steps read those same pages, a
    /// decode continuation is bit-identical to re-prefilling the grown
    /// window — the rescore reference for every `decode_check`.
    pub fn prefill_batch_with<P: QkvProjector>(
        &self,
        windows: &[&[u32]],
        proj: &P,
        pool: &mut PagePool,
        seqs: &mut [&mut SeqKv],
    ) -> Vec<Matrix> {
        assert_eq!(windows.len(), seqs.len(), "one sequence per window");
        for (w, seq) in windows.iter().zip(seqs.iter()) {
            assert!(
                seq.n_blocks() * pool.config().block_size >= w.len(),
                "block table does not cover the window"
            );
        }
        self.forward_batch_inner(windows, proj, None, Some((pool, seqs)))
    }

    /// Calibration inputs for the q/k/v projections: the post-ln1
    /// activations feeding each layer's attention block, one [t, d] matrix
    /// per layer (q, k, and v of a layer all consume the same input). This
    /// is the data side of the layer-wise reconstruction objective
    /// ‖W x − Ŵ x‖² that `train::calibrate` minimises.
    pub fn qkv_inputs(&self, tokens: &[u32]) -> Vec<Matrix> {
        self.qkv_inputs_batch(&[tokens])
    }

    /// Batched capture: one tall [Σt, d] post-ln1 matrix per layer for
    /// many windows at once (rows window-major), driving the whole
    /// capture pass through the batched kernels.
    pub fn qkv_inputs_batch(&self, windows: &[&[u32]]) -> Vec<Matrix> {
        let mut cap = Vec::with_capacity(self.cfg.n_layers);
        let _ = self.forward_batch_inner(
            windows,
            &DenseProjector {
                layers: &self.layers,
            },
            Some(&mut cap),
            None,
        );
        cap
    }

    fn forward_batch_inner<P: QkvProjector>(
        &self,
        windows: &[&[u32]],
        proj: &P,
        mut capture: Option<&mut Vec<Matrix>>,
        mut kv: Option<(&mut PagePool, &mut [&mut SeqKv])>,
    ) -> Vec<Matrix> {
        assert!(
            capture.is_none() || kv.is_none(),
            "capture and cache-writing prefill are exclusive modes"
        );
        let d = self.cfg.d_model;
        let ts: Vec<usize> = windows.iter().map(|w| w.len()).collect();
        for &t in &ts {
            assert!(t <= self.cfg.seq_len, "window longer than seq_len");
        }
        let total: usize = ts.iter().sum();
        // per-window offset table: window w occupies rows
        // offsets[w]..offsets[w + 1] of every stacked block
        let mut offsets = Vec::with_capacity(ts.len() + 1);
        offsets.push(0usize);
        for &t in &ts {
            offsets.push(offsets[offsets.len() - 1] + t);
        }

        // embeddings, windows stacked row-major (window-major order)
        let mut h = Matrix::zeros(total, d);
        let mut off = 0;
        for (w, &t) in windows.iter().zip(&ts) {
            for (i, &tok) in w.iter().enumerate() {
                let te = self.tok_emb.row(tok as usize);
                let pe = self.pos_emb.row(i);
                let row = h.row_mut(off + i);
                for j in 0..d {
                    row[j] = te[j] + pe[j];
                }
            }
            off += t;
        }

        // the most recent residual branch (this layer's MLP output) not yet
        // folded into `h` — each fold fuses with the next layernorm so the
        // rows make one memory round-trip instead of three
        let mut pending: Option<Matrix> = None;

        for (li, l) in self.layers.iter().enumerate() {
            // attention block: fold the previous layer's MLP residual (if
            // any) fused with this layer's ln1
            let a = match pending.take() {
                Some(r) => fused_add_layernorm(&mut h, &r, &l.ln1_g, &l.ln1_b),
                None => layernorm(&h, &l.ln1_g, &l.ln1_b),
            };
            if let Some(cap) = capture.as_mut() {
                cap.push(a.clone());
                if li + 1 == self.layers.len() {
                    break; // nothing downstream of the last capture is read
                }
            }
            // one batched projection per q/k/v across every window
            let q = proj.project(li, Proj::Q, &a);
            let mut k = proj.project(li, Proj::K, &a);
            let mut v = proj.project(li, Proj::V, &a);
            // cache-writing prefill: quantize K/V through f16 in place
            // (attention below consumes the round-tripped bits — the same
            // bits a later decode step gathers back out of the pages) and
            // store the patterns into each window's pages; blocks
            // borrowed from the sharing index already hold these exact
            // bits and are skipped
            if let Some((pool, seqs)) = kv.as_mut() {
                let _span = crate::obs::Span::enter(crate::obs::Stage::KvPrefill);
                let bs = pool.config().block_size;
                let mut off = 0;
                for (seq, &t) in seqs.iter_mut().zip(&ts) {
                    for i in 0..t {
                        let store = !seq.block_is_shared(i / bs);
                        pool.write_row(seq, li, i, k.row_mut(off + i), v.row_mut(off + i), store);
                    }
                    off += t;
                }
            }
            // one batched masked attention over the whole stack; the
            // offset table keeps causal attention inside window boundaries
            // (the span covers the attention_batch call only — per-row
            // softmax inside it is far too hot for guards)
            let mut o = Matrix::zeros(total, d);
            {
                let _span = crate::obs::Span::enter(crate::obs::Stage::Attention);
                ATTN_WS.with(|ws| {
                    let ws = &mut ws.borrow_mut();
                    attention_batch(&q, &k, &v, &offsets, self.cfg.n_heads, &mut o, ws)
                });
            }
            let oh = o.matmul(&l.wo);

            // mlp block (row-wise, so the stack batches it for free); the
            // attention residual folds into `h` fused with ln2
            {
                let _span = crate::obs::Span::enter(crate::obs::Stage::Mlp);
                let m = fused_add_layernorm(&mut h, &oh, &l.ln2_g, &l.ln2_b);
                let mut ff = m.matmul(&l.w1);
                for i in 0..total {
                    let row = ff.row_mut(i);
                    for (x, b) in row.iter_mut().zip(&l.b1) {
                        *x = gelu(*x + *b);
                    }
                }
                let mut ff2 = ff.matmul(&l.w2);
                for i in 0..total {
                    let row = ff2.row_mut(i);
                    for (x, b) in row.iter_mut().zip(&l.b2) {
                        *x += *b;
                    }
                }
                // held pending: folds fused with the next layernorm
                pending = Some(ff2);
            }
        }

        // calibration capture needs only the per-layer inputs — skip the
        // final layernorm and the unembedding matmul (the largest matmul
        // in the pass at a realistic vocab) when nobody reads the logits
        if capture.is_some() {
            return Vec::new();
        }

        // last layer's MLP residual fuses with the final layernorm
        let hf = match pending.take() {
            Some(r) => fused_add_layernorm(&mut h, &r, &self.lnf_g, &self.lnf_b),
            None => layernorm(&h, &self.lnf_g, &self.lnf_b),
        };
        // tied output head: logits = hf @ tok_embᵀ
        let mut logits = Matrix::zeros(total, self.cfg.vocab);
        hf.matmul_bt_into(&self.tok_emb, &mut logits);
        // split back into per-window logits
        let mut out = Vec::with_capacity(windows.len());
        let mut off = 0;
        for &t in &ts {
            out.push(logits.slice(off, off + t, 0, self.cfg.vocab));
            off += t;
        }
        out
    }

    /// Dense forward (original weights).
    pub fn forward(&self, tokens: &[u32]) -> Matrix {
        self.forward_with(
            tokens,
            &DenseProjector {
                layers: &self.layers,
            },
        )
    }

    /// Dense batched forward: logits per window, one batched projection
    /// per layer across all windows.
    pub fn forward_batch(&self, windows: &[&[u32]]) -> Vec<Matrix> {
        self.forward_batch_with(
            windows,
            &DenseProjector {
                layers: &self.layers,
            },
        )
    }

    /// One incremental decode step: append `tokens[s]` to sequence
    /// `seqs[s]` and return the [k, vocab] next-token logits — O(t) per
    /// sequence where rescoring the window is O(t²).
    ///
    /// Every layer projects only the k new rows, appends their quantized
    /// K/V to the tail pages, and runs [`decode_batch`] against the
    /// gathered cache; the MLP/residual/layernorm epilogues are the
    /// `forward_batch` code on k rows. Each sequence's block table must
    /// already cover `len() + 1` tokens with an exclusively owned tail
    /// (`KvState::reserve` guarantees both), and `seqs[s].len()` advances
    /// by one on return.
    ///
    /// Bit-identity: the appended rows round-trip through f16 exactly as
    /// a cache-writing prefill's would, and `decode_batch` replays
    /// `attention_batch`'s last-row kernel sequence over the gathered
    /// pages — so row s equals, bit for bit, the last logits row of
    /// [`Transformer::prefill_batch_with`] over the grown window.
    pub fn decode_step_with<P: QkvProjector>(
        &self,
        tokens: &[u32],
        proj: &P,
        pool: &mut PagePool,
        seqs: &mut [&mut SeqKv],
    ) -> Matrix {
        let d = self.cfg.d_model;
        let kreq = tokens.len();
        assert_eq!(kreq, seqs.len(), "one token per sequence");
        for seq in seqs.iter() {
            assert!(seq.len() < self.cfg.seq_len, "sequence at seq_len capacity");
        }
        // the new token's embedding at its sequence position
        let mut h = Matrix::zeros(kreq, d);
        for (s, (&tok, seq)) in tokens.iter().zip(seqs.iter()).enumerate() {
            let te = self.tok_emb.row(tok as usize);
            let pe = self.pos_emb.row(seq.len());
            let row = h.row_mut(s);
            for j in 0..d {
                row[j] = te[j] + pe[j];
            }
        }
        // keys per sequence after this step's append
        let lens: Vec<usize> = seqs.iter().map(|s| s.len() + 1).collect();
        let mut pending: Option<Matrix> = None;
        for (li, l) in self.layers.iter().enumerate() {
            let a = match pending.take() {
                Some(r) => fused_add_layernorm(&mut h, &r, &l.ln1_g, &l.ln1_b),
                None => layernorm(&h, &l.ln1_g, &l.ln1_b),
            };
            let q = proj.project(li, Proj::Q, &a);
            let mut kp = proj.project(li, Proj::K, &a);
            let mut vp = proj.project(li, Proj::V, &a);
            let mut o = Matrix::zeros(kreq, d);
            {
                let _span = crate::obs::Span::enter(crate::obs::Stage::KvDecode);
                // append this step's quantized K/V rows to the tail pages
                for (s, seq) in seqs.iter_mut().enumerate() {
                    let pos = seq.len();
                    pool.write_row(seq, li, pos, kp.row_mut(s), vp.row_mut(s), true);
                }
                let seqs_ro: &[&mut SeqKv] = seqs;
                let pool_ro: &PagePool = pool;
                let _aspan = crate::obs::Span::enter(crate::obs::Stage::Attention);
                ATTN_WS.with(|ws| {
                    let ws = &mut ws.borrow_mut();
                    decode_batch(
                        &q,
                        &lens,
                        |s, dk, dv| {
                            let _g = crate::obs::Span::enter(crate::obs::Stage::PageGather);
                            pool_ro.gather(&*seqs_ro[s], li, lens[s], dk, dv);
                        },
                        self.cfg.n_heads,
                        &mut o,
                        ws,
                    )
                });
            }
            let oh = o.matmul(&l.wo);
            {
                let _span = crate::obs::Span::enter(crate::obs::Stage::Mlp);
                let m = fused_add_layernorm(&mut h, &oh, &l.ln2_g, &l.ln2_b);
                let mut ff = m.matmul(&l.w1);
                for i in 0..kreq {
                    let row = ff.row_mut(i);
                    for (x, b) in row.iter_mut().zip(&l.b1) {
                        *x = gelu(*x + *b);
                    }
                }
                let mut ff2 = ff.matmul(&l.w2);
                for i in 0..kreq {
                    let row = ff2.row_mut(i);
                    for (x, b) in row.iter_mut().zip(&l.b2) {
                        *x += *b;
                    }
                }
                pending = Some(ff2);
            }
        }
        let hf = match pending.take() {
            Some(r) => fused_add_layernorm(&mut h, &r, &self.lnf_g, &self.lnf_b),
            None => layernorm(&h, &self.lnf_g, &self.lnf_b),
        };
        let mut logits = Matrix::zeros(kreq, self.cfg.vocab);
        hf.matmul_bt_into(&self.tok_emb, &mut logits);
        for seq in seqs.iter_mut() {
            seq.advance(1);
        }
        logits
    }
}

/// Fused residual epilogue: fold `r` into `h` in place and layernorm each
/// freshly written row in the same pass. Bit-identical to
/// `h = h.add(&r); layernorm(&h, g, b)` (same per-element add, same
/// dispatched `layernorm_row` kernel) but touches every row once while it
/// is cache-hot instead of allocating a sum matrix and re-reading it — the
/// avoided two extra [rows, cols] round-trips are credited to the
/// `bytes_saved_fusion` gauge.
fn fused_add_layernorm(h: &mut Matrix, r: &Matrix, g: &[f32], b: &[f32]) -> Matrix {
    assert_eq!((h.rows, h.cols), (r.rows, r.cols), "residual shape");
    let kt = simd::kernels();
    let mut out = Matrix::zeros(h.rows, h.cols);
    for i in 0..h.rows {
        let hrow = h.row_mut(i);
        (kt.add_k)(r.row(i), hrow);
        (kt.layernorm_row)(hrow, g, b, 1e-5, out.row_mut(i));
    }
    // unfused: write h+r (1 round-trip) then read it back for layernorm
    // (another) — fused skips both, keeping only the in-place update
    crate::obs::registry().add_fusion_saved_bytes(2 * (h.rows * h.cols * 4) as u64);
    #[cfg(feature = "obs-flops")]
    // one add + the ~7-flop/element normalize per element, 8 bytes moved
    crate::obs::count_flops((h.rows * h.cols * 8) as u64, (h.rows * h.cols * 8) as u64);
    out
}

/// Row-wise layernorm matching jax (eps inside rsqrt), routed through the
/// dispatched `layernorm_row` kernel — the same arm the fused epilogues
/// use, so capture comparisons against this function stay bitwise.
pub fn layernorm(x: &Matrix, g: &[f32], b: &[f32]) -> Matrix {
    let kt = simd::kernels();
    let mut out = Matrix::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        (kt.layernorm_row)(x.row(i), g, b, 1e-5, out.row_mut(i));
    }
    out
}

/// tanh-approximation GELU, bit-matching the python model.
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_6 * (x + 0.044715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 2,
            d_ff: 64,
            seq_len: 16,
        }
    }

    #[test]
    fn forward_shapes() {
        let m = Transformer::random(tiny_cfg(), 1);
        let tokens: Vec<u32> = (0..16).map(|i| i % 64).collect();
        let logits = m.forward(&tokens);
        assert_eq!((logits.rows, logits.cols), (16, 64));
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causality() {
        let m = Transformer::random(tiny_cfg(), 2);
        let t1: Vec<u32> = (0..16).map(|i| i % 64).collect();
        let mut t2 = t1.clone();
        t2[10] = (t2[10] + 1) % 64; // perturb a later token
        let l1 = m.forward(&t1);
        let l2 = m.forward(&t2);
        for i in 0..10 {
            for j in 0..64 {
                assert!(
                    (l1.at(i, j) - l2.at(i, j)).abs() < 1e-5,
                    "logits before perturbed position changed"
                );
            }
        }
        // and the perturbed position itself must change
        let mut any = false;
        for j in 0..64 {
            if (l1.at(10, j) - l2.at(10, j)).abs() > 1e-6 {
                any = true;
            }
        }
        assert!(any);
    }

    #[test]
    fn layernorm_normalizes() {
        let x = Matrix::randn(4, 32, 3);
        let g = vec![1.0; 32];
        let b = vec![0.0; 32];
        let y = layernorm(&x, &g, &b);
        for i in 0..4 {
            let row = y.row(i);
            let mu: f32 = row.iter().sum::<f32>() / 32.0;
            let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / 32.0;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn gelu_reference_values() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158808).abs() < 1e-4);
        assert!(gelu(10.0) > 9.99);
    }

    #[test]
    fn forward_batch_bit_matches_per_window_forward() {
        // the serving-path guarantee behind bucketing: a window's logits do
        // not depend on which batch it rode in — bit-for-bit, including a
        // t = 1 degenerate window
        let m = Transformer::random(tiny_cfg(), 11);
        let w1: Vec<u32> = (0..16).map(|i| (i * 5) % 64).collect();
        let w2: Vec<u32> = vec![3];
        let w3: Vec<u32> = (0..7).map(|i| (i * 13 + 4) % 64).collect();
        let batch = m.forward_batch(&[&w1, &w2, &w3]);
        for (w, lg) in [&w1, &w2, &w3].iter().zip(&batch) {
            let solo = m.forward(w);
            assert_eq!(lg.data.as_f32(), solo.data.as_f32(), "window len {}", w.len());
        }
    }

    #[test]
    fn qkv_inputs_match_ln1_of_forward() {
        let m = Transformer::random(tiny_cfg(), 7);
        let tokens: Vec<u32> = (0..12).map(|i| (i * 7) % 64).collect();
        let caps = m.qkv_inputs(&tokens);
        assert_eq!(caps.len(), 2);
        for a in &caps {
            assert_eq!((a.rows, a.cols), (12, 32));
            assert!(a.data.iter().all(|v| v.is_finite()));
        }
        // layer 0's capture is exactly layernorm(embeddings)
        let d = m.cfg.d_model;
        let mut h = Matrix::zeros(12, d);
        for (i, &tok) in tokens.iter().enumerate() {
            let te = m.tok_emb.row(tok as usize);
            let pe = m.pos_emb.row(i);
            let row = h.row_mut(i);
            for j in 0..d {
                row[j] = te[j] + pe[j];
            }
        }
        let expect = layernorm(&h, &m.layers[0].ln1_g, &m.layers[0].ln1_b);
        assert_eq!(caps[0].data, expect.data);
    }

    #[test]
    fn forward_batch_matches_per_window_forward() {
        let m = Transformer::random(tiny_cfg(), 9);
        // mixed lengths exercise the boundary bookkeeping
        let w1: Vec<u32> = (0..16).map(|i| (i * 3) % 64).collect();
        let w2: Vec<u32> = (0..9).map(|i| (i * 7 + 1) % 64).collect();
        let w3: Vec<u32> = (0..13).map(|i| (i * 11 + 2) % 64).collect();
        let batch = m.forward_batch(&[&w1, &w2, &w3]);
        assert_eq!(batch.len(), 3);
        for (w, lg) in [&w1, &w2, &w3].iter().zip(&batch) {
            let solo = m.forward(w);
            assert_eq!((lg.rows, lg.cols), (solo.rows, solo.cols));
            for (a, b) in lg.data.iter().zip(&solo.data) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn qkv_inputs_batch_stacks_window_major() {
        let m = Transformer::random(tiny_cfg(), 10);
        let w1: Vec<u32> = (0..8).map(|i| i % 64).collect();
        let w2: Vec<u32> = (0..6).map(|i| (i * 5) % 64).collect();
        let tall = m.qkv_inputs_batch(&[&w1, &w2]);
        assert_eq!(tall.len(), 2);
        let c1 = m.qkv_inputs(&w1);
        let c2 = m.qkv_inputs(&w2);
        for layer in 0..2 {
            assert_eq!((tall[layer].rows, tall[layer].cols), (14, 32));
            assert_eq!(tall[layer].slice(0, 8, 0, 32).data, c1[layer].data);
            assert_eq!(tall[layer].slice(8, 14, 0, 32).data, c2[layer].data);
        }
    }

    #[test]
    fn qkv_projections_enumerated() {
        let m = Transformer::random(tiny_cfg(), 6);
        let projs = m.qkv_projections();
        assert_eq!(projs.len(), 6);
        assert_eq!(projs[0].0, "layer0.wq");
        assert_eq!(projs[5].0, "layer1.wv");
    }
}
