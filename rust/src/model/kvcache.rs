//! Paged KV cache — the storage side of incremental decoding.
//!
//! Serving a conversation by rescoring the whole window is O(t²):
//! attention recomputes every layer's K/V for every cached token on
//! every new token. This module keeps those K/V rows **resident between
//! requests** so a decode step only computes the new token's row and
//! attends against the cache — O(t) per token, the k = 1 regime the
//! paper's "one sparse and a sequence of thin-matrix multiplications"
//! claim describes.
//!
//! # Layout (vLLM-style pages)
//!
//! The pool is one shared f16-resident [`WeightBuf`] carved into
//! fixed-size **pages**. A page holds `block_size` consecutive tokens of
//! one sequence — *all* layers, K and V — so a sequence's cache is just a
//! per-sequence **block table** (`SeqKv::blocks`) of page ids:
//!
//! ```text
//! page elems = 2 · n_layers · block_size · d_model      (u16 each)
//! elem(page, layer, kv, slot, j)
//!   = page·page_elems + ((layer·2 + kv)·block_size + slot)·d_model + j
//! memory ceiling = n_pages · page_elems · 2 bytes       (fixed at startup)
//! ```
//!
//! Tokens of one (layer, K|V) plane are contiguous, so a decode step
//! gathers a sequence's keys **block-by-block** with one dispatched
//! `widen_f16_lanes` call per (page, layer, plane) — the same SIMD lane
//! primitive the f16-resident weights ride.
//!
//! # Sharing, COW, eviction
//!
//! Full blocks are published under a **prefix-chain hash** (the key of
//! block b commits to all tokens 0..(b+1)·block_size; stored block
//! tokens are verified on lookup, so a hash collision can only miss a
//! sharing opportunity, never alias wrong keys). A prefill whose leading
//! blocks hit the index reuses those pages (refcount++) and skips both
//! the page writes and nothing else — the ULP contract makes the bits it
//! would have written identical. Pages are **copy-on-write**: published
//! pages are full and immutable; appending into a *shared partial* tail
//! (after [`PagePool::fork_seq`]) first copies it ([`PagePool::cow_tail`]).
//! A free-list allocator recycles pages when a sequence's refcounts drop
//! to zero; under memory pressure [`KvState`] evicts whole sessions
//! **LRU-by-session** until the allocation succeeds.
//!
//! # Bit-identity with rescoring
//!
//! Pages are f16, so the cache-writing prefill *itself* consumes the
//! f16-round-tripped K/V (`Transformer::prefill_batch_with` quantizes the
//! projected rows in place before attention). By induction every decode
//! step's activations are bit-identical to a cache-writing prefill of the
//! full window at the same position — the property tests below and the
//! `decode_check` CI gate pin this across `HISOLO_SIMD` dispatch levels.

use crate::linalg::simd;
use crate::linalg::weightbuf::WeightBuf;
use crate::model::transformer::QkvProjector;
use crate::model::{ModelConfig, Transformer};
use crate::util::fp16::{f16_to_f32, f32_to_f16};
use std::collections::HashMap;

/// Geometry of one [`PagePool`] (fixed at construction).
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// tokens per page (16 balances gather width against sharing
    /// granularity: one page = 16 · d_model · 2 planes · n_layers u16s,
    /// and a prefix must match in 16-token units to share)
    pub block_size: usize,
    /// pool capacity in pages — the memory ceiling is
    /// `n_pages · page_elems · 2` bytes, allocated once
    pub n_pages: usize,
    pub n_layers: usize,
    pub d_model: usize,
}

/// Default tokens-per-page (see [`KvCacheConfig::block_size`]).
pub const DEFAULT_BLOCK_SIZE: usize = 16;

impl KvCacheConfig {
    /// Page geometry for a model: all layers' K and V planes of
    /// `block_size` tokens.
    pub fn for_model(cfg: &ModelConfig, n_pages: usize, block_size: usize) -> KvCacheConfig {
        KvCacheConfig {
            block_size,
            n_pages,
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
        }
    }

    /// u16 elements per page.
    pub fn page_elems(&self) -> usize {
        2 * self.n_layers * self.block_size * self.d_model
    }

    /// Resident bytes of the whole pool (the memory ceiling formula).
    pub fn pool_bytes(&self) -> usize {
        self.n_pages * self.page_elems() * 2
    }
}

/// The page pool has no free page and nothing more can be evicted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted;

/// A sequence's view of the pool: its block table plus the token count
/// cached so far. `blocks[b]` holds tokens `b·block_size ..` of the
/// sequence (every layer's K and V).
#[derive(Default)]
pub struct SeqKv {
    blocks: Vec<u32>,
    len: usize,
    /// leading blocks borrowed from the sharing index at prefill — never
    /// written by this sequence (their bits are already identical)
    shared_blocks: usize,
}

impl SeqKv {
    pub fn new() -> SeqKv {
        SeqKv::default()
    }

    /// Tokens cached so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Whether block `b` was borrowed from the sharing index at prefill
    /// (its page must not be written — the bits are already there).
    pub fn block_is_shared(&self, b: usize) -> bool {
        b < self.shared_blocks
    }

    /// Advance the cached-token count after a decode step's writes.
    pub(crate) fn advance(&mut self, n: usize) {
        self.len += n;
    }
}

/// Chain hash for prefix sharing: the key of block b is
/// `chain_key(key of block b-1, tokens of block b)`, seeded by
/// [`KEY_SEED`] — so equal keys mean equal full prefixes (verified
/// against the stored block tokens on lookup).
pub fn chain_key(parent: u64, block_tokens: &[u32]) -> u64 {
    let mut h = parent ^ 0xA076_1D64_78BD_642F;
    for &t in block_tokens {
        h = (h ^ t as u64).wrapping_mul(0x0100_0000_01B3);
        h ^= h >> 29;
        h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    }
    h ^ (h >> 32)
}

/// Seed of every prefix chain (the key "before block 0").
pub const KEY_SEED: u64 = 0x9E37_79B9_7F4A_7C15;

struct Published {
    page: u32,
    tokens: Box<[u32]>,
}

/// The shared f16 page pool: free-list allocation, per-page refcounts,
/// and the prefix-hash sharing index. See the module docs for layout.
pub struct PagePool {
    cfg: KvCacheConfig,
    buf: WeightBuf,
    free: Vec<u32>,
    refcount: Vec<u32>,
    /// key a page is published under in `index` (full, immutable pages only)
    published: Vec<Option<u64>>,
    index: HashMap<u64, Published>,
    hits: u64,
    misses: u64,
}

impl PagePool {
    pub fn new(cfg: KvCacheConfig) -> PagePool {
        assert!(cfg.block_size > 0 && cfg.n_pages > 0 && cfg.d_model > 0 && cfg.n_layers > 0);
        PagePool {
            buf: WeightBuf::F16(vec![0u16; cfg.n_pages * cfg.page_elems()].into()),
            free: (0..cfg.n_pages as u32).rev().collect(),
            refcount: vec![0; cfg.n_pages],
            published: vec![None; cfg.n_pages],
            index: HashMap::new(),
            hits: 0,
            misses: 0,
            cfg,
        }
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    pub fn pages_total(&self) -> usize {
        self.cfg.n_pages
    }

    pub fn pages_free(&self) -> usize {
        self.free.len()
    }

    /// Pages with refcount > 0. The allocator invariant the property
    /// tests pin: `pages_in_use() + pages_free() == pages_total()` at
    /// every point of any alloc/free/retain/fork/COW interleaving.
    pub fn pages_in_use(&self) -> usize {
        self.cfg.n_pages - self.free.len()
    }

    /// Actual bytes the pool keeps resident (f16 pages).
    pub fn resident_bytes(&self) -> usize {
        self.buf.resident_bytes()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn refcount(&self, page: u32) -> u32 {
        self.refcount[page as usize]
    }

    fn f16(&self) -> &[u16] {
        self.buf.as_f16()
    }

    fn f16_mut(&mut self) -> &mut [u16] {
        match &mut self.buf {
            WeightBuf::F16(v) => v,
            WeightBuf::F32(_) => unreachable!("page pool is always f16-resident"),
        }
    }

    /// Element offset of (page, layer, K|V plane) — `block_size · d_model`
    /// contiguous values.
    fn plane_base(&self, page: u32, layer: usize, kv: usize) -> usize {
        page as usize * self.cfg.page_elems()
            + (layer * 2 + kv) * self.cfg.block_size * self.cfg.d_model
    }

    /// Take a page off the free list (refcount 0 → 1).
    pub fn alloc(&mut self) -> Option<u32> {
        let p = self.free.pop()?;
        debug_assert_eq!(self.refcount[p as usize], 0);
        self.refcount[p as usize] = 1;
        Some(p)
    }

    pub fn retain(&mut self, page: u32) {
        assert!(self.refcount[page as usize] > 0, "retain of a free page");
        self.refcount[page as usize] += 1;
    }

    /// Drop one reference; the last release unpublishes the page and
    /// returns it to the free list. Panics on double-free.
    pub fn release(&mut self, page: u32) {
        let rc = &mut self.refcount[page as usize];
        assert!(*rc > 0, "double free of page {page}");
        *rc -= 1;
        if *rc == 0 {
            if let Some(key) = self.published[page as usize].take() {
                self.index.remove(&key);
            }
            self.free.push(page);
        }
    }

    /// Look up a full block by its chain key; on a verified hit the page
    /// is retained for the caller and the hit counter bumps.
    pub fn lookup_shared(&mut self, key: u64, block_tokens: &[u32]) -> Option<u32> {
        let page = match self.index.get(&key) {
            Some(e) if &*e.tokens == block_tokens => e.page,
            _ => return None,
        };
        self.refcount[page as usize] += 1;
        self.hits += 1;
        Some(page)
    }

    /// Count a full-block prefill that could not share (the denominator
    /// partner of `lookup_shared` hits).
    pub fn note_miss(&mut self) {
        self.misses += 1;
    }

    /// Publish a full, final page under its chain key so later prefills
    /// with the same prefix share it. First publisher wins; partial
    /// pages must never be published (they are still appended into).
    pub fn publish(&mut self, page: u32, key: u64, block_tokens: &[u32]) {
        if self.published[page as usize].is_some() || self.index.contains_key(&key) {
            return;
        }
        self.published[page as usize] = Some(key);
        self.index.insert(
            key,
            Published {
                page,
                tokens: block_tokens.into(),
            },
        );
    }

    /// Release every page of a sequence and clear its table.
    pub fn free_seq(&mut self, seq: &mut SeqKv) {
        for &p in &seq.blocks {
            let rc = &mut self.refcount[p as usize];
            assert!(*rc > 0, "double free of page {p}");
            *rc -= 1;
            if *rc == 0 {
                if let Some(key) = self.published[p as usize].take() {
                    self.index.remove(&key);
                }
                self.free.push(p);
            }
        }
        seq.blocks.clear();
        seq.len = 0;
        seq.shared_blocks = 0;
    }

    /// Share a whole sequence (refcount++ on every page) — the multi-turn
    /// fork. The child treats every block as borrowed; appends into a
    /// shared partial tail go through [`PagePool::cow_tail`] first.
    pub fn fork_seq(&mut self, seq: &SeqKv) -> SeqKv {
        for &p in &seq.blocks {
            self.retain(p);
        }
        SeqKv {
            blocks: seq.blocks.clone(),
            len: seq.len,
            shared_blocks: seq.blocks.len(),
        }
    }

    /// Copy-on-write for appends: if the tail block is partial and shared
    /// (or published), copy it into a fresh exclusive page and swap it
    /// into the table. Returns whether a copy happened.
    pub fn cow_tail(&mut self, seq: &mut SeqKv) -> Result<bool, PoolExhausted> {
        if seq.len % self.cfg.block_size == 0 {
            return Ok(false); // appends start a fresh page
        }
        let b = seq.blocks.len() - 1;
        let old = seq.blocks[b];
        if self.refcount[old as usize] == 1 && self.published[old as usize].is_none() {
            return Ok(false); // already exclusive
        }
        let new = self.alloc().ok_or(PoolExhausted)?;
        let elems = self.cfg.page_elems();
        let (src, dst) = (old as usize * elems, new as usize * elems);
        self.f16_mut().copy_within(src..src + elems, dst);
        self.release(old);
        seq.blocks[b] = new;
        if seq.shared_blocks > b {
            seq.shared_blocks = b;
        }
        Ok(true)
    }

    /// Quantize one token's K and V rows to f16 **in place** (so the
    /// caller's attention consumes exactly the cached bits) and, when
    /// `store` is set, write the bit patterns into the page holding
    /// `pos`. `store` is false for shared-prefix rows: the page already
    /// holds these exact bits (ULP contract + verified token prefix).
    pub fn write_row(
        &mut self,
        seq: &SeqKv,
        layer: usize,
        pos: usize,
        krow: &mut [f32],
        vrow: &mut [f32],
        store: bool,
    ) {
        let d = self.cfg.d_model;
        debug_assert_eq!(krow.len(), d);
        debug_assert_eq!(vrow.len(), d);
        let page = seq.blocks[pos / self.cfg.block_size];
        debug_assert!(
            !store || self.published[page as usize].is_none(),
            "write into a published (immutable) page"
        );
        let slot = pos % self.cfg.block_size;
        for (kv, row) in [krow, vrow].into_iter().enumerate() {
            let base = self.plane_base(page, layer, kv) + slot * d;
            let dst = &mut self.f16_mut()[base..base + d];
            for (x, h) in row.iter_mut().zip(dst) {
                let bits = f32_to_f16(*x);
                *x = f16_to_f32(bits);
                if store {
                    *h = bits;
                }
            }
        }
    }

    /// Widen the first `upto` cached tokens of (sequence, layer) into
    /// full-width [upto, d] K and V row blocks — one dispatched
    /// `widen_f16_lanes` call per (page, plane), i.e. gathered
    /// block-by-block through the SIMD lanes.
    pub fn gather(
        &self,
        seq: &SeqKv,
        layer: usize,
        upto: usize,
        dst_k: &mut [f32],
        dst_v: &mut [f32],
    ) {
        let d = self.cfg.d_model;
        let bs = self.cfg.block_size;
        assert!(upto <= seq.blocks.len() * bs, "gather past the block table");
        assert!(dst_k.len() >= upto * d && dst_v.len() >= upto * d);
        let kt = simd::kernels();
        let f16 = self.f16();
        let mut pos = 0usize;
        for &page in &seq.blocks {
            if pos >= upto {
                break;
            }
            let ntok = bs.min(upto - pos);
            let kb = self.plane_base(page, layer, 0);
            let vb = self.plane_base(page, layer, 1);
            (kt.widen_f16_lanes)(&f16[kb..kb + ntok * d], &mut dst_k[pos * d..(pos + ntok) * d]);
            (kt.widen_f16_lanes)(&f16[vb..vb + ntok * d], &mut dst_v[pos * d..(pos + ntok) * d]);
            pos += ntok;
        }
    }
}

/// Pool + session counters in one copyable snapshot (what the worker
/// pushes into `Metrics` after each prefill/decode chunk).
#[derive(Clone, Copy, Debug, Default)]
pub struct KvStatsSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub pages_resident: u64,
    pub pages_total: u64,
    pub sessions: u64,
}

impl KvStatsSnapshot {
    /// Share of full-block prefills served from the sharing index.
    pub fn hit_rate(&self) -> f64 {
        if self.hits + self.misses == 0 {
            0.0
        } else {
            self.hits as f64 / (self.hits + self.misses) as f64
        }
    }
}

struct Session {
    seq: SeqKv,
    /// logits row of the last cached token — predicts the next token, so
    /// each decode scores its token against these before stepping
    last_logits: Vec<f32>,
    last_used: u64,
}

/// The session table a worker serves decode traffic from: sequences
/// keyed by session id, the page pool behind them, and LRU-by-session
/// eviction under memory pressure.
///
/// NLL protocol: `prefill_batch` of a p-token prompt scores its p − 1
/// internal targets and parks the last logits row; each decoded token is
/// first scored against the parked row, then cached by a
/// `Transformer::decode_step_with`. Accumulated token-at-a-time (one
/// f64 add per token, left to right), the prefill + decode total is
/// **bit-identical** to a cache-writing prefill of the full window.
pub struct KvState {
    pool: PagePool,
    sessions: HashMap<u64, Session>,
    clock: u64,
    evictions: u64,
    seq_len: usize,
}

impl KvState {
    pub fn new(cfg: KvCacheConfig, seq_len: usize) -> KvState {
        KvState {
            pool: PagePool::new(cfg),
            sessions: HashMap::new(),
            clock: 0,
            evictions: 0,
            seq_len,
        }
    }

    /// Pool sized for a model: `n_pages` pages of [`DEFAULT_BLOCK_SIZE`]
    /// tokens each.
    pub fn for_model(cfg: &ModelConfig, n_pages: usize) -> KvState {
        KvState::new(
            KvCacheConfig::for_model(cfg, n_pages, DEFAULT_BLOCK_SIZE),
            cfg.seq_len,
        )
    }

    pub fn pool(&self) -> &PagePool {
        &self.pool
    }

    pub fn sessions_len(&self) -> usize {
        self.sessions.len()
    }

    pub fn has_session(&self, id: u64) -> bool {
        self.sessions.contains_key(&id)
    }

    /// Cached token count of a live session.
    pub fn session_len(&self, id: u64) -> Option<usize> {
        self.sessions.get(&id).map(|s| s.seq.len())
    }

    /// Close a session and release its pages. Returns whether it existed.
    pub fn end_session(&mut self, id: u64) -> bool {
        match self.sessions.remove(&id) {
            Some(mut s) => {
                self.pool.free_seq(&mut s.seq);
                true
            }
            None => false,
        }
    }

    pub fn stats(&self) -> KvStatsSnapshot {
        KvStatsSnapshot {
            hits: self.pool.hits,
            misses: self.pool.misses,
            evictions: self.evictions,
            pages_resident: self.pool.pages_in_use() as u64,
            pages_total: self.pool.pages_total() as u64,
            sessions: self.sessions.len() as u64,
        }
    }

    /// Evict the least-recently-used session (sessions mid-batch are
    /// temporarily out of the table and therefore safe). Returns false
    /// when nothing is left to evict.
    fn evict_lru(&mut self) -> bool {
        let victim = self
            .sessions
            .iter()
            .min_by_key(|(_, s)| s.last_used)
            .map(|(&id, _)| id);
        match victim {
            Some(id) => {
                let mut s = self.sessions.remove(&id).unwrap();
                self.pool.free_seq(&mut s.seq);
                self.evictions += 1;
                true
            }
            None => false,
        }
    }

    fn alloc_evicting(&mut self) -> Option<u32> {
        loop {
            if let Some(p) = self.pool.alloc() {
                return Some(p);
            }
            if !self.evict_lru() {
                return None;
            }
        }
    }

    /// Build a block table for a prompt: leading full blocks that hit the
    /// sharing index are borrowed, the rest allocated (evicting LRU
    /// sessions under pressure). Returns the table plus the publishes to
    /// perform once the pages are written.
    #[allow(clippy::type_complexity)]
    fn acquire_blocks(
        &mut self,
        tokens: &[u32],
    ) -> Result<(SeqKv, Vec<(usize, u64, Vec<u32>)>), String> {
        let bs = self.pool.cfg.block_size;
        let n_blocks = tokens.len().div_ceil(bs);
        let n_full = tokens.len() / bs;
        let mut seq = SeqKv::default();
        let mut pubs = Vec::new();
        let mut key = KEY_SEED;
        let mut sharing = true;
        for b in 0..n_blocks {
            if b < n_full {
                let btoks = &tokens[b * bs..(b + 1) * bs];
                key = chain_key(key, btoks);
                if sharing {
                    if let Some(p) = self.pool.lookup_shared(key, btoks) {
                        seq.blocks.push(p);
                        seq.shared_blocks += 1;
                        continue;
                    }
                    sharing = false;
                }
                self.pool.note_miss();
                pubs.push((b, key, btoks.to_vec()));
            }
            match self.alloc_evicting() {
                Some(p) => seq.blocks.push(p),
                None => {
                    self.pool.free_seq(&mut seq);
                    return Err(format!(
                        "kv page pool exhausted ({} pages)",
                        self.pool.pages_total()
                    ));
                }
            }
        }
        Ok((seq, pubs))
    }

    /// Extend a sequence's block table to hold `n_new` more tokens
    /// (COW-ing a shared partial tail first); decode steps then never
    /// allocate. On failure the session is left exactly as it was.
    fn reserve(&mut self, seq: &mut SeqKv, n_new: usize) -> Result<(), PoolExhausted> {
        loop {
            match self.pool.cow_tail(seq) {
                Ok(_) => break,
                Err(PoolExhausted) => {
                    if !self.evict_lru() {
                        return Err(PoolExhausted);
                    }
                }
            }
        }
        let need = (seq.len + n_new).div_ceil(self.pool.cfg.block_size);
        let before = seq.blocks.len();
        while seq.blocks.len() < need {
            match self.alloc_evicting() {
                Some(p) => seq.blocks.push(p),
                None => {
                    while seq.blocks.len() > before {
                        let p = seq.blocks.pop().unwrap();
                        self.pool.release(p);
                    }
                    return Err(PoolExhausted);
                }
            }
        }
        Ok(())
    }

    /// Open (or re-open) sessions: cache each prompt's K/V and score its
    /// p − 1 internal targets. One `Result` per request — a pool-full or
    /// bad-window failure never poisons the rest of the batch.
    pub fn prefill_batch<P: QkvProjector>(
        &mut self,
        model: &Transformer,
        proj: &P,
        reqs: &[(u64, Vec<u32>)],
    ) -> Vec<Result<(f64, usize), String>> {
        self.clock += 1;
        let clock = self.clock;
        let mut out: Vec<Result<(f64, usize), String>> =
            reqs.iter().map(|_| Err(String::new())).collect();
        let mut acquired: Vec<(usize, SeqKv, Vec<(usize, u64, Vec<u32>)>)> = Vec::new();
        for (i, (id, tokens)) in reqs.iter().enumerate() {
            if tokens.is_empty() || tokens.len() > self.seq_len {
                out[i] = Err(format!(
                    "prefill window must be 1..={} tokens, got {}",
                    self.seq_len,
                    tokens.len()
                ));
                continue;
            }
            if let Some(&tok) = tokens.iter().find(|&&t| t as usize >= model.cfg.vocab) {
                out[i] = Err(format!("token {tok} out of vocab range"));
                continue;
            }
            // a re-prefill replaces the session (conversation reset);
            // within one batch the last request for an id wins
            if let Some(mut old) = self.sessions.remove(id) {
                self.pool.free_seq(&mut old.seq);
            }
            if let Some(prev) = acquired.iter().position(|(j, _, _)| reqs[*j].0 == *id) {
                let (j, mut seq, _) = acquired.remove(prev);
                self.pool.free_seq(&mut seq);
                out[j] = Err(format!("session {id} re-prefilled later in the same batch"));
            }
            match self.acquire_blocks(tokens) {
                Ok((seq, pubs)) => acquired.push((i, seq, pubs)),
                Err(e) => out[i] = Err(e),
            }
        }
        if acquired.is_empty() {
            return out;
        }
        let windows: Vec<&[u32]> = acquired.iter().map(|&(i, _, _)| reqs[i].1.as_slice()).collect();
        let logits = {
            let mut seq_refs: Vec<&mut SeqKv> = acquired.iter_mut().map(|(_, s, _)| s).collect();
            model.prefill_batch_with(&windows, proj, &mut self.pool, &mut seq_refs)
        };
        for ((i, mut seq, pubs), lg) in acquired.into_iter().zip(logits) {
            let (id, tokens) = &reqs[i];
            let p = tokens.len();
            seq.len = p;
            let mut nll = 0.0f64;
            for r in 0..p - 1 {
                nll += crate::eval::perplexity::row_nll(lg.row(r), tokens[r + 1] as usize);
            }
            // the pages are written now — publish the owned full blocks
            for (b, key, btoks) in pubs {
                self.pool.publish(seq.blocks[b], key, &btoks);
            }
            let last_logits = lg.row(p - 1).to_vec();
            self.sessions.insert(
                *id,
                Session {
                    seq,
                    last_logits,
                    last_used: clock,
                },
            );
            out[i] = Ok((nll, p - 1));
        }
        out
    }

    /// Append tokens to live sessions: each token is scored against the
    /// session's parked logits, then cached by one O(t) decode step.
    /// Requests for unknown/evicted sessions (the eviction error arm),
    /// over-length appends, or an exhausted pool fail **individually**.
    pub fn decode<P: QkvProjector>(
        &mut self,
        model: &Transformer,
        proj: &P,
        reqs: &[(u64, Vec<u32>)],
    ) -> Vec<Result<(f64, usize), String>> {
        self.clock += 1;
        let clock = self.clock;
        let mut out: Vec<Result<(f64, usize), String>> =
            reqs.iter().map(|_| Err(String::new())).collect();
        // take live sessions out of the table (also protects them from
        // the LRU eviction that reserve() may trigger)
        let mut live: Vec<(usize, u64, Session, f64)> = Vec::new();
        for (i, (id, tokens)) in reqs.iter().enumerate() {
            let Some(sess) = self.sessions.remove(id) else {
                out[i] = Err(format!("unknown, evicted, or duplicate session {id}"));
                continue;
            };
            let verdict = if tokens.is_empty() {
                Some("empty decode request".to_string())
            } else if sess.seq.len() + tokens.len() > self.seq_len {
                Some(format!(
                    "decode past seq_len {} ({} cached + {} new)",
                    self.seq_len,
                    sess.seq.len(),
                    tokens.len()
                ))
            } else {
                tokens
                    .iter()
                    .find(|&&t| t as usize >= model.cfg.vocab)
                    .map(|tok| format!("token {tok} out of vocab range"))
            };
            match verdict {
                Some(e) => {
                    out[i] = Err(e);
                    self.sessions.insert(*id, sess);
                }
                None => live.push((i, *id, sess, 0.0)),
            }
        }
        // pre-reserve pages so the step loop never allocates
        let mut reserved = Vec::with_capacity(live.len());
        for (i, id, mut sess, nll) in live {
            match self.reserve(&mut sess.seq, reqs[i].1.len()) {
                Ok(()) => reserved.push((i, id, sess, nll)),
                Err(PoolExhausted) => {
                    out[i] = Err(format!(
                        "kv page pool exhausted ({} pages)",
                        self.pool.pages_total()
                    ));
                    self.sessions.insert(id, sess);
                }
            }
        }
        let mut live = reserved;
        let max_steps = live.iter().map(|&(i, ..)| reqs[i].1.len()).max().unwrap_or(0);
        for s in 0..max_steps {
            let mut step_tokens = Vec::new();
            let mut active: Vec<usize> = Vec::new();
            for (li, (i, _, sess, nll)) in live.iter_mut().enumerate() {
                let toks = &reqs[*i].1;
                if s < toks.len() {
                    // parked logits predict this token; score before stepping
                    *nll += crate::eval::perplexity::row_nll(&sess.last_logits, toks[s] as usize);
                    step_tokens.push(toks[s]);
                    active.push(li);
                }
            }
            let logits = {
                let mut refs: Vec<&mut SeqKv> = live
                    .iter_mut()
                    .enumerate()
                    .filter(|(li, _)| active.contains(li))
                    .map(|(_, (_, _, sess, _))| &mut sess.seq)
                    .collect();
                model.decode_step_with(&step_tokens, proj, &mut self.pool, &mut refs)
            };
            for (r, &li) in active.iter().enumerate() {
                live[li].2.last_logits.copy_from_slice(logits.row(r));
            }
        }
        for (i, id, mut sess, nll) in live {
            sess.last_used = clock;
            let ntok = reqs[i].1.len();
            self.sessions.insert(id, sess);
            out[i] = Ok((nll, ntok));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::DenseProjector;
    use crate::util::proptest::check;

    fn tiny_kv_cfg(n_pages: usize, bs: usize) -> KvCacheConfig {
        KvCacheConfig {
            block_size: bs,
            n_pages,
            n_layers: 2,
            d_model: 8,
        }
    }

    fn tiny_model_cfg() -> ModelConfig {
        ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 2,
            d_ff: 64,
            seq_len: 48,
        }
    }

    /// Allocator round-trip property: any interleaving of alloc / free /
    /// retain+release / fork / COW keeps the occupancy invariant
    /// `in_use + free == total`, never double-frees (release panics are
    /// the detector), and a full teardown returns every page.
    #[test]
    fn page_pool_alloc_free_refcount_cow_round_trip() {
        check(24, |rng| {
            let bs = 1 + rng.below(4);
            let n_pages = 4 + rng.below(24);
            let mut pool = PagePool::new(tiny_kv_cfg(n_pages, bs));
            let mut seqs: Vec<SeqKv> = Vec::new();
            for _ in 0..60 {
                match rng.below(5) {
                    // grow a sequence by one block (alloc)
                    0 => {
                        if let Some(p) = pool.alloc() {
                            let mut s = SeqKv::default();
                            s.blocks.push(p);
                            s.len = 1 + rng.below(bs); // partial tail
                            seqs.push(s);
                        }
                    }
                    // free a whole sequence
                    1 => {
                        if !seqs.is_empty() {
                            let mut s = seqs.swap_remove(rng.below(seqs.len()));
                            pool.free_seq(&mut s);
                        }
                    }
                    // fork (refcount++ on every page)
                    2 => {
                        if !seqs.is_empty() {
                            let f = pool.fork_seq(&seqs[rng.below(seqs.len())]);
                            seqs.push(f);
                        }
                    }
                    // COW a shared partial tail
                    3 => {
                        if !seqs.is_empty() {
                            let i = rng.below(seqs.len());
                            let _ = pool.cow_tail(&mut seqs[i]);
                        }
                    }
                    // publish + shared lookup round-trip
                    _ => {
                        if !seqs.is_empty() {
                            let i = rng.below(seqs.len());
                            if seqs[i].len == bs {
                                let toks: Vec<u32> = (0..bs as u32).collect();
                                let key = chain_key(KEY_SEED, &toks);
                                pool.publish(seqs[i].blocks[0], key, &toks);
                                if let Some(p) = pool.lookup_shared(key, &toks) {
                                    let mut s = SeqKv::default();
                                    s.blocks.push(p);
                                    s.len = bs;
                                    s.shared_blocks = 1;
                                    seqs.push(s);
                                }
                            }
                        }
                    }
                }
                if pool.pages_in_use() + pool.pages_free() != pool.pages_total() {
                    return Err(format!(
                        "occupancy broken: {} in use + {} free != {}",
                        pool.pages_in_use(),
                        pool.pages_free(),
                        pool.pages_total()
                    ));
                }
            }
            for s in &mut seqs {
                pool.free_seq(s);
            }
            if pool.pages_free() != pool.pages_total() {
                return Err(format!(
                    "leak: {} of {} pages free after full teardown",
                    pool.pages_free(),
                    pool.pages_total()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn cow_preserves_bits_and_isolates_writers() {
        let cfg = tiny_kv_cfg(4, 2);
        let d = cfg.d_model;
        let mut pool = PagePool::new(cfg);
        let mut a = SeqKv::default();
        a.blocks.push(pool.alloc().unwrap());
        let mut krow: Vec<f32> = (0..d).map(|j| j as f32 * 0.25).collect();
        let mut vrow = krow.clone();
        pool.write_row(&a, 0, 0, &mut krow, &mut vrow, true);
        a.len = 1;
        let mut b = pool.fork_seq(&a);
        assert_eq!(pool.refcount(a.blocks[0]), 2);
        assert!(pool.cow_tail(&mut b).unwrap(), "shared partial tail must copy");
        assert_ne!(a.blocks[0], b.blocks[0]);
        assert_eq!(pool.refcount(a.blocks[0]), 1);
        // the copy carried the bits
        let (mut ka, mut va) = (vec![0.0f32; d], vec![0.0f32; d]);
        let (mut kb, mut vb) = (vec![0.0f32; d], vec![0.0f32; d]);
        pool.gather(&a, 0, 1, &mut ka, &mut va);
        pool.gather(&b, 0, 1, &mut kb, &mut vb);
        assert_eq!(ka, kb);
        // writing b's copy no longer affects a
        let mut k2: Vec<f32> = vec![9.0; d];
        let mut v2 = k2.clone();
        pool.write_row(&b, 0, 0, &mut k2, &mut v2, true);
        let (mut ka2, mut va2) = (vec![0.0f32; d], vec![0.0f32; d]);
        pool.gather(&a, 0, 1, &mut ka2, &mut va2);
        assert_eq!(ka, ka2, "COW writer leaked into the parent");
        pool.free_seq(&mut a);
        pool.free_seq(&mut b);
        assert_eq!(pool.pages_free(), pool.pages_total());
    }

    #[test]
    fn gather_round_trips_quantized_rows_across_block_boundaries() {
        let cfg = tiny_kv_cfg(8, 4);
        let d = cfg.d_model;
        let bs = cfg.block_size;
        let mut pool = PagePool::new(cfg);
        let mut seq = SeqKv::default();
        for _ in 0..2 {
            seq.blocks.push(pool.alloc().unwrap());
        }
        let t = bs + 2; // crosses a block boundary
        let mut expect_k = Vec::new();
        let mut expect_v = Vec::new();
        for pos in 0..t {
            let mut k: Vec<f32> = (0..d).map(|j| (pos * d + j) as f32 * 0.1).collect();
            let mut v: Vec<f32> = (0..d).map(|j| (pos * d + j) as f32 * -0.2).collect();
            for layer in 0..2 {
                pool.write_row(&seq, layer, pos, &mut k, &mut v, true);
            }
            expect_k.extend_from_slice(&k); // post-quantization values
            expect_v.extend_from_slice(&v);
        }
        seq.len = t;
        let mut gk = vec![0.0f32; t * d];
        let mut gv = vec![0.0f32; t * d];
        pool.gather(&seq, 1, t, &mut gk, &mut gv);
        assert_eq!(gk, expect_k, "gathered K != quantized-in-place K");
        assert_eq!(gv, expect_v, "gathered V != quantized-in-place V");
    }

    /// End-to-end decode bit-identity: decoding token by token over
    /// cached pages reproduces — **bitwise** — both the logits and the
    /// NLL total of a cache-writing prefill of the full window, across
    /// ragged lengths, block-boundary token counts, t = 1 prompts, and
    /// page-shared prefixes. (CI runs the whole suite under
    /// `HISOLO_SIMD=off|auto`; the dispatch-level variant of this
    /// property lives in the decode bench's `decode_check`.)
    #[test]
    fn decode_bit_identical_to_full_window_prefill() {
        let mcfg = tiny_model_cfg();
        let model = Transformer::random(mcfg, 77);
        let proj = DenseProjector { layers: &model.layers };
        check(6, |rng| {
            let n_seqs = 1 + rng.below(3);
            let bs = DEFAULT_BLOCK_SIZE;
            let mut kv = KvState::new(
                KvCacheConfig::for_model(&model.cfg, 256, bs),
                model.cfg.seq_len,
            );
            // a shared prefix exercises page sharing on later sessions
            let prefix: Vec<u32> = (0..bs as u32).map(|i| (i * 7 + 3) % 64).collect();
            let mut full_prompts = 0usize;
            for s in 0..n_seqs {
                let id = s as u64;
                // ragged: t = 1, exact block multiples, and arbitrary
                let n = match rng.below(4) {
                    0 => 1,
                    1 => bs,
                    2 => 2 * bs,
                    _ => 2 + rng.below(model.cfg.seq_len - 2),
                };
                let mut window: Vec<u32> = prefix.clone();
                window.extend((0..n as u32).map(|_| rng.below(64) as u32));
                window.truncate(model.cfg.seq_len);
                // split into prompt + decoded tail (prompt ≥ 1 token)
                let p = 1 + rng.below(window.len());
                if p >= bs {
                    full_prompts += 1;
                }
                let pre = kv.prefill_batch(&model, &proj, &[(id, window[..p].to_vec())]);
                let (mut nll, mut toks) = pre[0].clone()?;
                for &tau in &window[p..] {
                    let r = kv.decode(&model, &proj, &[(id, vec![tau])]);
                    let (dn, dt) = r[0].clone()?;
                    nll += dn;
                    toks += dt;
                }
                // reference: cache-writing prefill of the full window in
                // a fresh session (fresh KvState so no sharing shortcuts)
                let mut kv2 = KvState::new(
                    KvCacheConfig::for_model(&model.cfg, 64, bs),
                    model.cfg.seq_len,
                );
                let full = kv2.prefill_batch(&model, &proj, &[(99, window.clone())]);
                let (fnll, ftoks) = full[0].clone()?;
                if toks != ftoks {
                    return Err(format!("token counts differ: {toks} vs {ftoks}"));
                }
                if nll.to_bits() != fnll.to_bits() {
                    return Err(format!(
                        "decode NLL not bit-identical to full prefill: {nll:?} vs {fnll:?} \
                         (window {}, prompt {p})",
                        window.len()
                    ));
                }
            }
            // later sessions shared the prefix block whenever at least two
            // prompts covered it (partial blocks are never published)
            if full_prompts > 1 && kv.pool().hits() == 0 {
                return Err("no page sharing across sessions with a common prefix".into());
            }
            Ok(())
        });
    }

    /// Batched decode (several sessions stepping together) is bit-identical
    /// to decoding each session alone — the decode twin of the
    /// batch-invariance guarantee `attention_batch` pins for prefill.
    #[test]
    fn batched_decode_matches_solo_decode_bitwise() {
        let mcfg = tiny_model_cfg();
        let model = Transformer::random(mcfg, 31);
        let proj = DenseProjector { layers: &model.layers };
        let windows: Vec<Vec<u32>> = (0..3)
            .map(|s| (0..20u32).map(|i| (i * 5 + s) % 64).collect())
            .collect();
        let run = |batched: bool| -> Vec<f64> {
            let mut kv = KvState::for_model(&model.cfg, 128);
            let reqs: Vec<(u64, Vec<u32>)> = windows
                .iter()
                .enumerate()
                .map(|(s, w)| (s as u64, w[..8].to_vec()))
                .collect();
            let mut nll: Vec<f64> = kv
                .prefill_batch(&model, &proj, &reqs)
                .into_iter()
                .map(|r| r.unwrap().0)
                .collect();
            for step in 8..20 {
                if batched {
                    let dreqs: Vec<(u64, Vec<u32>)> = windows
                        .iter()
                        .enumerate()
                        .map(|(s, w)| (s as u64, vec![w[step]]))
                        .collect();
                    for (s, r) in kv.decode(&model, &proj, &dreqs).into_iter().enumerate() {
                        nll[s] += r.unwrap().0;
                    }
                } else {
                    for (s, w) in windows.iter().enumerate() {
                        let r = kv.decode(&model, &proj, &[(s as u64, vec![w[step]])]);
                        nll[s] += r.into_iter().next().unwrap().unwrap().0;
                    }
                }
            }
            nll
        };
        let a = run(true);
        let b = run(false);
        for (s, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "session {s}: batched != solo decode");
        }
    }

    #[test]
    fn lru_eviction_under_pressure_and_evicted_session_errors() {
        let mcfg = tiny_model_cfg();
        let model = Transformer::random(mcfg, 13);
        let proj = DenseProjector { layers: &model.layers };
        // room for ~2 sessions of 2 blocks each
        let mut kv = KvState::new(KvCacheConfig::for_model(&model.cfg, 4, 16), model.cfg.seq_len);
        let window = |seed: u32| -> Vec<u32> { (0..32u32).map(|i| (i * 3 + seed) % 64).collect() };
        assert!(kv.prefill_batch(&model, &proj, &[(1, window(1))])[0].is_ok());
        assert!(kv.prefill_batch(&model, &proj, &[(2, window(2))])[0].is_ok());
        // session 1 is LRU — the third prefill evicts it
        assert!(kv.prefill_batch(&model, &proj, &[(3, window(3))])[0].is_ok());
        assert_eq!(kv.stats().evictions, 1);
        assert!(!kv.has_session(1));
        let r = kv.decode(&model, &proj, &[(1, vec![5])]);
        let e = r[0].as_ref().unwrap_err();
        assert!(e.contains("session 1"), "unexpected error: {e}");
        // live sessions still decode
        assert!(kv.decode(&model, &proj, &[(3, vec![5])])[0].is_ok());
        // occupancy stays consistent
        let st = kv.stats();
        assert_eq!(
            st.pages_resident + kv.pool().pages_free() as u64,
            st.pages_total
        );
    }

    #[test]
    fn prefix_sharing_hits_and_hit_rate() {
        let mcfg = tiny_model_cfg();
        let model = Transformer::random(mcfg, 5);
        let proj = DenseProjector { layers: &model.layers };
        let mut kv = KvState::for_model(&model.cfg, 64);
        let shared: Vec<u32> = (0..32u32).map(|i| (i * 11) % 64).collect();
        assert!(kv.prefill_batch(&model, &proj, &[(1, shared.clone())])[0].is_ok());
        let before = kv.stats();
        assert_eq!(before.hits, 0);
        // same prefix, different tail → both full prefix blocks hit
        let mut w2 = shared.clone();
        w2.extend([9u32, 7, 5]);
        assert!(kv.prefill_batch(&model, &proj, &[(2, w2)])[0].is_ok());
        let after = kv.stats();
        assert_eq!(after.hits, 2, "both shared full blocks should hit");
        assert!(after.hit_rate() > 0.0);
        // shared pages are refcounted, not duplicated
        assert!(after.pages_resident < 2 * before.pages_resident + 1);
    }
}
