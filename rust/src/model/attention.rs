//! Batched masked attention — the last per-window loop in serving, killed.
//!
//! `forward_batch` stacks every window's activations into one tall [Σt, d]
//! block so projections and MLP run as single thin-matrix multiplies; this
//! module does the same for attention. [`attention_batch`] walks a
//! per-window offset table over the stacked Q/K/V blocks and, per (window,
//! head), packs the K/V head slices contiguous at the lane-padded stride
//! (`simd::padded_k(head_dim)`, zero-filled pad lanes — so the per-key
//! kernels run whole 8-lane groups with no scalar tail), forms the causal
//! score rows with the dispatched `dot8_acc` kernel, runs the fused
//! scale+max+exp+normalize softmax through `simd::exp_softmax_row` (the
//! exp loop was the last scalar hotspot), and applies the softmax weights
//! to V with the dispatched axpy — the same SIMD kernel layer every other
//! kernel in the stack rides ([`crate::linalg::simd`]). All scratch
//! (packed head slices, softmax row, padded query/context rows) lives in
//! a reusable [`AttnWorkspace`] sized to the longest window, so a serving
//! batch performs **zero per-window allocation**: one `attention_batch`
//! call replaces k `causal_mha` calls that each allocated score/output
//! matrices.
//!
//! [`causal_mha`] is kept as the single-window (k = 1) case of the same
//! code path — mirroring how `matvec_with` is the k = 1 case of
//! `apply_batch` — so batched and per-window serving are bit-identical by
//! construction, which the property tests pin. The pre-batching scalar
//! implementation survives as [`causal_mha_scalar`], the independent
//! numerical reference for tests and the per-window arm of
//! `benches/attention.rs`.
//!
//! # Observability
//!
//! The whole batched call reports under one `attention` span opened by
//! the transformer's forward (`obs::Stage::Attention`). Nothing inside
//! this module carries its own guards: the per-(window, head) loop and
//! the per-query softmax rows run tens of thousands of times per batch,
//! far below the ~microsecond granularity where a span guard's two clock
//! reads stay invisible — see the span-guard rules in [`crate::obs`].

use crate::linalg::simd;
use crate::linalg::Matrix;

/// Reusable scratch for [`attention_batch`]: packed per-head K/V slices
/// (rows padded to the SIMD lane stride so the score/context kernels run
/// tail-free), one softmax row, and two lane-padded head rows (query in,
/// context out). Q is only copied when the head width needs padding.
/// A default workspace is valid for any call and warms up on first use;
/// after warmup the batched attention allocates nothing.
#[derive(Default)]
pub struct AttnWorkspace {
    /// packed [t, hd_pad] head slice of K (rows contiguous and
    /// zero-padded to the lane multiple, unlike the strided head columns
    /// of the stacked [Σt, d] block)
    kh: Vec<f32>,
    /// packed [t, hd_pad] head slice of V
    vh: Vec<f32>,
    /// one causal score/softmax row (≤ t_max entries live per query)
    probs: Vec<f32>,
    /// lane-padded copy of one query head row (used when hd_pad != hd)
    qrow: Vec<f32>,
    /// lane-padded accumulator for one context head row
    opad: Vec<f32>,
    /// gathered full-width [t, d] K rows for one decode sequence (the
    /// paged cache widens f16 pages block-by-block into here)
    kfull: Vec<f32>,
    /// gathered full-width [t, d] V rows for one decode sequence
    vfull: Vec<f32>,
}

impl AttnWorkspace {
    /// Grow the buffers to fit windows up to `t_max` rows at padded head
    /// width `hd_pad` (idempotent; only ever grows).
    pub fn ensure(&mut self, t_max: usize, hd_pad: usize) {
        if self.kh.len() < t_max * hd_pad {
            self.kh.resize(t_max * hd_pad, 0.0);
            self.vh.resize(t_max * hd_pad, 0.0);
        }
        if self.probs.len() < t_max {
            self.probs.resize(t_max, 0.0);
        }
        if self.qrow.len() < hd_pad {
            self.qrow.resize(hd_pad, 0.0);
            self.opad.resize(hd_pad, 0.0);
        }
    }

    /// Grow the full-width gather staging for decode sequences up to
    /// `t_max` cached tokens at model width `d` (idempotent; only grows).
    pub fn ensure_full(&mut self, t_max: usize, d: usize) {
        if self.kfull.len() < t_max * d {
            self.kfull.resize(t_max * d, 0.0);
            self.vfull.resize(t_max * d, 0.0);
        }
    }
}

/// Multi-head causal attention over a stacked batch of windows.
///
/// `q`/`k`/`v` are the stacked [Σt, d] projection outputs of
/// `forward_batch`; `offsets` is the per-window offset table
/// (`offsets[w]..offsets[w + 1]` are window w's rows, so
/// `offsets = [0, t₀, t₀+t₁, …, Σt]`). Attention never crosses a window
/// boundary: rows of `out` in window w attend only to earlier rows of the
/// same window. `out` must be [Σt, d]; every row is fully overwritten.
///
/// Per (window, head) the K/V head slices are packed contiguous, each causal
/// score row is one `gemm_nt_add` over the packed prefix (the same dot
/// kernel as every dense multiply — and only the causal half of the
/// scores is ever formed), and the softmax-weighted sum over V is one
/// `apply_batch_add_w` with k = head_dim. The single-window case is
/// exactly [`causal_mha`].
pub fn attention_batch(
    q: &Matrix,
    k: &Matrix,
    v: &Matrix,
    offsets: &[usize],
    n_heads: usize,
    out: &mut Matrix,
    ws: &mut AttnWorkspace,
) {
    let d = q.cols;
    assert!(
        offsets.len() >= 2 && offsets[0] == 0,
        "offset table must be [0, ..., total]"
    );
    let total = *offsets.last().unwrap();
    assert_eq!(q.rows, total, "q rows != offset total");
    assert_eq!((k.rows, k.cols), (total, d), "k shape mismatch");
    assert_eq!((v.rows, v.cols), (total, d), "v shape mismatch");
    assert_eq!((out.rows, out.cols), (total, d), "output shape mismatch");
    assert!(
        n_heads > 0 && d % n_heads == 0,
        "d_model {d} not divisible by n_heads {n_heads}"
    );
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    // Pack K/V rows at the lane-padded stride so every score dot and
    // context axpy runs whole 8-lane groups with no scalar tail. The pad
    // lanes are zero: they contribute exact +0 products to the score
    // reduction and zero context columns that are never copied out, so
    // padding is invisible in the results at every dispatch level.
    let hd_pad = simd::padded_k(hd);
    let t_max = offsets.windows(2).map(|w| w[1] - w[0]).max().unwrap_or(0);
    ws.ensure(t_max, hd_pad);
    let AttnWorkspace { kh, vh, probs, qrow, opad, .. } = ws;
    let kt = simd::kernels();

    for wi in 0..offsets.len() - 1 {
        let (off, end) = (offsets[wi], offsets[wi + 1]);
        assert!(end >= off && end <= total, "offset table not monotone");
        let t = end - off;
        if t == 0 {
            continue;
        }
        for h in 0..n_heads {
            let c0 = h * hd;
            // pack the K/V head slices contiguous: strided [t, d] columns
            // c0..c0+hd become row-major [t, hd_pad] blocks, so the t²
            // score and context passes stream dense cache lines (Q is
            // consumed one row at a time — copied only if padding is
            // needed). Pad lanes are re-zeroed per pack because the
            // workspace is reused across calls with different strides.
            for i in 0..t {
                kh[i * hd_pad..i * hd_pad + hd].copy_from_slice(&k.row(off + i)[c0..c0 + hd]);
                kh[i * hd_pad + hd..(i + 1) * hd_pad].fill(0.0);
                vh[i * hd_pad..i * hd_pad + hd].copy_from_slice(&v.row(off + i)[c0..c0 + hd]);
                vh[i * hd_pad + hd..(i + 1) * hd_pad].fill(0.0);
            }
            for i in 0..t {
                let qsrc = &q.row(off + i)[c0..c0 + hd];
                let qi: &[f32] = if hd_pad == hd {
                    qsrc
                } else {
                    qrow[..hd].copy_from_slice(qsrc);
                    qrow[hd..hd_pad].fill(0.0);
                    &qrow[..hd_pad]
                };
                // causal score row: only keys 0..=i are ever formed, each
                // via the dispatched dot kernel (tree-then-tail reduction)
                let pr = &mut probs[..=i];
                let n8 = qi.len() / simd::LANES * simd::LANES;
                for (j, pj) in pr.iter_mut().enumerate() {
                    let krow = &kh[j * hd_pad..j * hd_pad + qi.len()];
                    let mut acc = [0.0f32; 8];
                    (kt.dot8_acc)(&qi[..n8], &krow[..n8], &mut acc);
                    let mut s = simd::hsum8_tree(&acc);
                    for c in n8..qi.len() {
                        s += qi[c] * krow[c];
                    }
                    *pj = s;
                }
                // fused scale + max-subtract + vectorized exp + normalize
                // (the exp loop was the last scalar hotspot)
                (kt.exp_softmax_row)(pr, scale);
                // context row: out[off+i, c0..c0+hd] = probs · V[0..=i],
                // one dispatched axpy per key over the padded V rows
                let od = &mut opad[..hd_pad];
                od.fill(0.0);
                for (j, &pj) in pr.iter().enumerate() {
                    (kt.axpy_k)(pj, &vh[j * hd_pad..(j + 1) * hd_pad], od);
                }
                out.row_mut(off + i)[c0..c0 + hd].copy_from_slice(&od[..hd]);
            }
        }
    }
}

/// Incremental decode attention: one **new** query row per sequence
/// against that sequence's cached K/V — the O(t) step that replaces
/// rescoring the whole window through [`attention_batch`] (O(t²)).
///
/// `q` is [k, d] (row s is sequence s's single new query), `lens[s]` is
/// the sequence's total key count *including* the new token, and
/// `gather(s, dk, dv)` must fill `dk`/`dv` (each `lens[s] * d`) with the
/// sequence's full-width K/V rows — in serving this widens f16 pages
/// block-by-block through the dispatched `widen_f16_lanes` kernel (see
/// `model::kvcache::PagePool::gather`). `out` is [k, d].
///
/// Bit-identity: per (sequence, head) the K/V rows are packed at the
/// same lane-padded stride and the score → `exp_softmax_row` → axpy
/// sequence below is the `i = t - 1` iteration of [`attention_batch`]
/// verbatim, so the decode row is **bit-for-bit** the last output row of
/// rescoring the full window — the property tests pin this across
/// dispatch levels.
pub fn decode_batch(
    q: &Matrix,
    lens: &[usize],
    mut gather: impl FnMut(usize, &mut [f32], &mut [f32]),
    n_heads: usize,
    out: &mut Matrix,
    ws: &mut AttnWorkspace,
) {
    let d = q.cols;
    assert_eq!(q.rows, lens.len(), "one query row per sequence");
    assert_eq!((out.rows, out.cols), (q.rows, d), "output shape mismatch");
    assert!(
        n_heads > 0 && d % n_heads == 0,
        "d_model {d} not divisible by n_heads {n_heads}"
    );
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let hd_pad = simd::padded_k(hd);
    let t_max = lens.iter().copied().max().unwrap_or(0);
    ws.ensure(t_max, hd_pad);
    ws.ensure_full(t_max, d);
    let AttnWorkspace { kh, vh, probs, qrow, opad, kfull, vfull } = ws;
    let kt = simd::kernels();

    for (s, &t) in lens.iter().enumerate() {
        assert!(t >= 1, "sequence {s} has no keys");
        let kf = &mut kfull[..t * d];
        let vf = &mut vfull[..t * d];
        gather(s, kf, vf);
        for h in 0..n_heads {
            let c0 = h * hd;
            // pack exactly as attention_batch does, reading the gathered
            // [t, d] rows instead of the stacked block
            for i in 0..t {
                kh[i * hd_pad..i * hd_pad + hd].copy_from_slice(&kf[i * d + c0..i * d + c0 + hd]);
                kh[i * hd_pad + hd..(i + 1) * hd_pad].fill(0.0);
                vh[i * hd_pad..i * hd_pad + hd].copy_from_slice(&vf[i * d + c0..i * d + c0 + hd]);
                vh[i * hd_pad + hd..(i + 1) * hd_pad].fill(0.0);
            }
            // the single query row is row i = t - 1 of the full window
            let qsrc = &q.row(s)[c0..c0 + hd];
            let qi: &[f32] = if hd_pad == hd {
                qsrc
            } else {
                qrow[..hd].copy_from_slice(qsrc);
                qrow[hd..hd_pad].fill(0.0);
                &qrow[..hd_pad]
            };
            let pr = &mut probs[..t];
            let n8 = qi.len() / simd::LANES * simd::LANES;
            for (j, pj) in pr.iter_mut().enumerate() {
                let krow = &kh[j * hd_pad..j * hd_pad + qi.len()];
                let mut acc = [0.0f32; 8];
                (kt.dot8_acc)(&qi[..n8], &krow[..n8], &mut acc);
                let mut s = simd::hsum8_tree(&acc);
                for c in n8..qi.len() {
                    s += qi[c] * krow[c];
                }
                *pj = s;
            }
            (kt.exp_softmax_row)(pr, scale);
            let od = &mut opad[..hd_pad];
            od.fill(0.0);
            for (j, &pj) in pr.iter().enumerate() {
                (kt.axpy_k)(pj, &vh[j * hd_pad..(j + 1) * hd_pad], od);
            }
            out.row_mut(s)[c0..c0 + hd].copy_from_slice(&od[..hd]);
        }
    }
}

/// Multi-head causal attention for one window: the single-window (k = 1)
/// case of [`attention_batch`] — same kernels, same bits. q, k, v:
/// [t, d] → [t, d].
pub fn causal_mha(q: &Matrix, k: &Matrix, v: &Matrix, n_heads: usize) -> Matrix {
    let mut out = Matrix::zeros(q.rows, q.cols);
    let mut ws = AttnWorkspace::default();
    attention_batch(q, k, v, &[0, q.rows], n_heads, &mut out, &mut ws);
    out
}

/// The pre-batching scalar reference: per-query streaming-softmax causal
/// attention reading the strided head slices in place. Kept as an
/// independent numerical cross-check for [`attention_batch`] (property
/// tests) and as the per-window arm of `benches/attention.rs`; serving
/// never calls it.
pub fn causal_mha_scalar(q: &Matrix, k: &Matrix, v: &Matrix, n_heads: usize) -> Matrix {
    let t = q.rows;
    let d = q.cols;
    let hd = d / n_heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Matrix::zeros(t, d);
    let mut probs = vec![0.0f32; t];
    for h in 0..n_heads {
        let c0 = h * hd;
        for i in 0..t {
            let qi = &q.row(i)[c0..c0 + hd];
            // scores over keys 0..=i (causal), streaming softmax
            let mut maxs = f32::NEG_INFINITY;
            for j in 0..=i {
                let kj = &k.row(j)[c0..c0 + hd];
                let s = crate::linalg::matrix::dot(qi, kj, hd) * scale;
                probs[j] = s;
                maxs = maxs.max(s);
            }
            let mut denom = 0.0f32;
            for p in probs[..=i].iter_mut() {
                *p = (*p - maxs).exp();
                denom += *p;
            }
            let inv = 1.0 / denom;
            let orow = &mut out.row_mut(i)[c0..c0 + hd];
            for j in 0..=i {
                let w = probs[j] * inv;
                let vj = &v.row(j)[c0..c0 + hd];
                for (o, &vv) in orow.iter_mut().zip(vj) {
                    *o += w * vv;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, slices_close};

    fn stacked(total: usize, d: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        (
            Matrix::randn(total, d, seed),
            Matrix::randn(total, d, seed + 1),
            Matrix::randn(total, d, seed + 2),
        )
    }

    /// The tentpole equivalence property: one batched call over ragged
    /// windows (t = 1 and single-window degenerate cases included) is
    /// **bit-for-bit** the per-window `causal_mha` answer — batching and
    /// workspace reuse change layout, never bits.
    #[test]
    fn attention_batch_bit_matches_per_window_causal_mha() {
        check(12, |rng| {
            let heads = 1 + rng.below(4);
            let hd = 4 + rng.below(5);
            let d = heads * hd;
            let n_windows = 1 + rng.below(4);
            let ts: Vec<usize> = (0..n_windows).map(|_| 1 + rng.below(12)).collect();
            let mut offsets = vec![0usize];
            for &t in &ts {
                offsets.push(offsets[offsets.len() - 1] + t);
            }
            let total = *offsets.last().unwrap();
            let (q, k, v) = stacked(total, d, rng.next_u64());
            let mut out = Matrix::zeros(total, d);
            // a reused (and, after the first window, stale) workspace must
            // not leak between windows
            let mut ws = AttnWorkspace::default();
            attention_batch(&q, &k, &v, &offsets, heads, &mut out, &mut ws);
            for w in 0..n_windows {
                let (o0, o1) = (offsets[w], offsets[w + 1]);
                let solo = causal_mha(
                    &q.slice(o0, o1, 0, d),
                    &k.slice(o0, o1, 0, d),
                    &v.slice(o0, o1, 0, d),
                    heads,
                );
                let got = out.slice(o0, o1, 0, d);
                if got.data.as_f32() != solo.data.as_f32() {
                    return Err(format!("window {w}: batched != per-window (bitwise)"));
                }
            }
            Ok(())
        });
    }

    /// Independent cross-check: the kernel-driven path agrees with the
    /// pre-batching scalar implementation to fp tolerance (different
    /// accumulation grouping in the P·V pass, same math).
    #[test]
    fn attention_batch_matches_scalar_reference() {
        check(10, |rng| {
            let heads = 1 + rng.below(4);
            let d = heads * (4 + rng.below(5));
            let t = 1 + rng.below(14);
            let (q, k, v) = stacked(t, d, rng.next_u64());
            let batched = causal_mha(&q, &k, &v, heads);
            let scalar = causal_mha_scalar(&q, &k, &v, heads);
            slices_close(&batched.data, &scalar.data, 1e-5, 1e-5, "vs scalar")
        });
    }

    /// The decode kernel's contract: one new query row against gathered
    /// K/V is **bit-for-bit** the last output row of rescoring the full
    /// window through `attention_batch` — ragged lengths and t = 1
    /// included. (The paged-cache end-to-end version of this property
    /// lives in `model::kvcache`.)
    #[test]
    fn decode_batch_bit_matches_last_row_of_attention_batch() {
        check(12, |rng| {
            let heads = 1 + rng.below(4);
            let hd = 4 + rng.below(5);
            let d = heads * hd;
            let n_seqs = 1 + rng.below(5);
            let ts: Vec<usize> = (0..n_seqs).map(|_| 1 + rng.below(14)).collect();
            let total: usize = ts.iter().sum();
            let (qs, ks, vs) = stacked(total, d, rng.next_u64());
            let mut offsets = vec![0usize];
            for &t in &ts {
                offsets.push(offsets[offsets.len() - 1] + t);
            }
            // full-window rescore reference
            let mut full = Matrix::zeros(total, d);
            let mut ws = AttnWorkspace::default();
            attention_batch(&qs, &ks, &vs, &offsets, heads, &mut full, &mut ws);
            // decode arm: last query row of each window, keys gathered
            let mut q1 = Matrix::zeros(n_seqs, d);
            for (s, &t) in ts.iter().enumerate() {
                q1.row_mut(s).copy_from_slice(qs.row(offsets[s] + t - 1));
            }
            let mut out = Matrix::zeros(n_seqs, d);
            decode_batch(
                &q1,
                &ts,
                |s, dk, dv| {
                    for i in 0..ts[s] {
                        dk[i * d..(i + 1) * d].copy_from_slice(ks.row(offsets[s] + i));
                        dv[i * d..(i + 1) * d].copy_from_slice(vs.row(offsets[s] + i));
                    }
                },
                heads,
                &mut out,
                &mut ws,
            );
            for (s, &t) in ts.iter().enumerate() {
                if out.row(s) != full.row(offsets[s] + t - 1) {
                    return Err(format!("seq {s} (t={t}): decode row != rescore last row"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn uniform_v_rows_sum_to_one() {
        let t = 8;
        let d = 16;
        let q = Matrix::randn(t, d, 4);
        let k = Matrix::randn(t, d, 5);
        let v = Matrix::from_fn(t, d, |_i, _j| 1.0);
        let o = causal_mha(&q, &k, &v, 4);
        for val in o.data.iter() {
            assert!((val - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn single_token_window_passes_v_through() {
        let d = 12;
        let (q, k, v) = stacked(1, d, 7);
        let o = causal_mha(&q, &k, &v, 3);
        slices_close(&o.data, &v.data, 1e-6, 1e-6, "t=1").unwrap();
    }

    #[test]
    fn empty_window_in_offset_table_is_skipped() {
        let d = 8;
        let (q, k, v) = stacked(5, d, 9);
        let mut out = Matrix::zeros(5, d);
        let mut ws = AttnWorkspace::default();
        // window layout [3, 0, 2]: the empty middle window contributes no
        // rows and must not disturb its neighbours
        attention_batch(&q, &k, &v, &[0, 3, 3, 5], 2, &mut out, &mut ws);
        let a = causal_mha(&q.slice(0, 3, 0, d), &k.slice(0, 3, 0, d), &v.slice(0, 3, 0, d), 2);
        let b = causal_mha(&q.slice(3, 5, 0, d), &k.slice(3, 5, 0, d), &v.slice(3, 5, 0, d), 2);
        assert_eq!(out.slice(0, 3, 0, d).data.as_f32(), a.data.as_f32());
        assert_eq!(out.slice(3, 5, 0, d).data.as_f32(), b.data.as_f32());
    }

    #[test]
    fn out_rows_fully_overwritten() {
        let d = 8;
        let (q, k, v) = stacked(6, d, 11);
        let mut stale = Matrix::from_fn(6, d, |_, _| 42.0);
        let mut ws = AttnWorkspace::default();
        attention_batch(&q, &k, &v, &[0, 6], 2, &mut stale, &mut ws);
        let fresh = causal_mha(&q, &k, &v, 2);
        assert_eq!(stale.data.as_f32(), fresh.data.as_f32());
    }
}
