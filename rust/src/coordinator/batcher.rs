//! Dynamic batcher: collects requests into batches of up to `max_batch`,
//! waiting at most `max_wait` after the first request arrives (the standard
//! latency/throughput knob of serving systems; cf. vLLM's batch scheduler).
//!
//! Generic over the item type so unit tests run without a PJRT client.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// queue capacity; pushes beyond it are rejected (backpressure)
    pub capacity: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            capacity: 1024,
        }
    }
}

struct State<T> {
    queue: VecDeque<T>,
    closed: bool,
}

/// Outcome of a bounded-wait [`Batcher::poll_batch`].
pub enum BatchPoll<T> {
    /// A non-empty batch of up to `max_batch` items.
    Batch(Vec<T>),
    /// Nothing arrived within the idle window; the queue is still open.
    Idle,
    /// Closed and fully drained.
    Closed,
}

/// MPMC dynamic batching queue.
pub struct Batcher<T> {
    cfg: BatcherConfig,
    state: Mutex<State<T>>,
    cv: Condvar,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Batcher<T> {
        Batcher {
            cfg,
            state: Mutex::new(State {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue; returns Err(item) if the queue is full or closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        if s.closed || s.queue.len() >= self.cfg.capacity {
            return Err(item);
        }
        s.queue.push_back(item);
        drop(s);
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop of the next batch. Returns None when closed and drained.
    /// Waits for the first item indefinitely, then up to `max_wait` for the
    /// batch to fill.
    pub fn pop_batch(&self) -> Option<Vec<T>> {
        loop {
            match self.poll_batch(Duration::from_millis(100)) {
                BatchPoll::Batch(b) => return Some(b),
                BatchPoll::Idle => continue,
                BatchPoll::Closed => return None,
            }
        }
    }

    /// Bounded-wait pop: waits up to `idle_wait` for the first item, then up
    /// to `max_wait` for the batch to fill. Returning [`BatchPoll::Idle`] on
    /// an empty window gives the caller a chance to service control work
    /// (e.g. a pending scorer hot-swap) without dropping requests.
    pub fn poll_batch(&self, idle_wait: Duration) -> BatchPoll<T> {
        let mut s = self.state.lock().unwrap();
        // wait for the first item (or close / idle timeout)
        let idle_deadline = Instant::now() + idle_wait;
        while s.queue.is_empty() {
            if s.closed {
                return BatchPoll::Closed;
            }
            let now = Instant::now();
            if now >= idle_deadline {
                return BatchPoll::Idle;
            }
            let (ns, _) = self.cv.wait_timeout(s, idle_deadline - now).unwrap();
            s = ns;
        }
        // batch-fill window
        let deadline = Instant::now() + self.cfg.max_wait;
        while s.queue.len() < self.cfg.max_batch && !s.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ns, timeout) = self.cv.wait_timeout(s, deadline - now).unwrap();
            s = ns;
            if timeout.timed_out() {
                break;
            }
        }
        let take = s.queue.len().min(self.cfg.max_batch);
        if take == 0 {
            // another consumer drained the queue while this one released
            // the lock inside the fill window — report Idle rather than an
            // empty batch (which would pollute batch-size metrics)
            return if s.closed {
                BatchPoll::Closed
            } else {
                BatchPoll::Idle
            };
        }
        BatchPoll::Batch(s.queue.drain(..take).collect())
    }

    /// Close the queue; pending items are still drained by pop_batch.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    fn cfg(max_batch: usize, wait_ms: u64, cap: usize) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            capacity: cap,
        }
    }

    #[test]
    fn batches_up_to_max() {
        let b = Batcher::new(cfg(3, 5, 100));
        for i in 0..7 {
            b.push(i).unwrap();
        }
        assert_eq!(b.pop_batch().unwrap(), vec![0, 1, 2]);
        assert_eq!(b.pop_batch().unwrap(), vec![3, 4, 5]);
        assert_eq!(b.pop_batch().unwrap(), vec![6]);
    }

    #[test]
    fn waits_for_first_item() {
        let b = Arc::new(Batcher::new(cfg(4, 1, 100)));
        let b2 = b.clone();
        let h = thread::spawn(move || b2.pop_batch());
        thread::sleep(Duration::from_millis(20));
        b.push(42).unwrap();
        let got = h.join().unwrap().unwrap();
        assert_eq!(got, vec![42]);
    }

    #[test]
    fn fills_batch_within_wait_window() {
        let b = Arc::new(Batcher::new(cfg(4, 50, 100)));
        let b2 = b.clone();
        let h = thread::spawn(move || b2.pop_batch());
        thread::sleep(Duration::from_millis(5));
        for i in 0..4 {
            b.push(i).unwrap();
            thread::sleep(Duration::from_millis(2));
        }
        let got = h.join().unwrap().unwrap();
        assert_eq!(got.len(), 4, "batch should fill during the wait window");
    }

    #[test]
    fn backpressure_rejects_over_capacity() {
        let b = Batcher::new(cfg(4, 1, 2));
        assert!(b.push(1).is_ok());
        assert!(b.push(2).is_ok());
        assert_eq!(b.push(3), Err(3));
    }

    #[test]
    fn poll_batch_reports_idle_then_batches() {
        let b = Batcher::new(cfg(4, 1, 100));
        let t0 = std::time::Instant::now();
        assert!(matches!(
            b.poll_batch(Duration::from_millis(5)),
            BatchPoll::Idle
        ));
        assert!(t0.elapsed() >= Duration::from_millis(5));
        b.push(9).unwrap();
        match b.poll_batch(Duration::from_millis(5)) {
            BatchPoll::Batch(v) => assert_eq!(v, vec![9]),
            _ => panic!("expected a batch"),
        }
        b.close();
        assert!(matches!(
            b.poll_batch(Duration::from_millis(5)),
            BatchPoll::Closed
        ));
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(cfg(4, 1, 100));
        b.push(1).unwrap();
        b.close();
        assert!(b.push(2).is_err());
        assert_eq!(b.pop_batch().unwrap(), vec![1]);
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn concurrent_producers_consumers() {
        let b = Arc::new(Batcher::new(cfg(8, 2, 10_000)));
        let total = 500;
        let mut producers = Vec::new();
        for p in 0..4 {
            let b = b.clone();
            producers.push(thread::spawn(move || {
                for i in 0..total {
                    while b.push(p * total + i).is_err() {
                        thread::yield_now();
                    }
                }
            }));
        }
        let consumer = {
            let b = b.clone();
            thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = b.pop_batch() {
                    seen.extend(batch);
                }
                seen
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        // give the consumer time to drain, then close
        while !b.is_empty() {
            thread::yield_now();
        }
        b.close();
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen.len(), 4 * total);
        assert_eq!(seen, (0..4 * total).collect::<Vec<_>>());
    }
}
