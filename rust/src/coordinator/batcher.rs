//! Dynamic batcher: collects requests into batches of up to `max_batch`,
//! waiting at most `max_wait` after the first request arrives (the standard
//! latency/throughput knob of serving systems; cf. vLLM's batch scheduler),
//! then optionally coalesces the batch into length-homogeneous buckets
//! ([`Batcher::poll_buckets`]) so each scored chunk sees near-uniform
//! window lengths and padding waste is bounded.
//!
//! Generic over the item type so unit tests run without a PJRT client.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// queue capacity; pushes beyond it are rejected (backpressure)
    pub capacity: usize,
    /// Sorted upper edges of the window-length buckets used by
    /// [`Batcher::poll_buckets`]: a length lands in the first bucket whose
    /// edge is ≥ it, lengths beyond the last edge share one overflow
    /// bucket. An empty list disables coalescing (every poll is a single
    /// bucket). Default: powers of two ([`default_bucket_edges`]).
    pub bucket_edges: Vec<usize>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            capacity: 1024,
            bucket_edges: default_bucket_edges(),
        }
    }
}

/// The default length-bucket edges: powers of two from 2 to 4096. Within
/// every bucket the lengths differ by at most 2×, so padding a chunk to
/// its longest member wastes < 50% — and in practice far less, since
/// serving traffic clusters near its context length. Lengths beyond the
/// last edge share one **unbounded** overflow bucket; traffic regularly
/// exceeding 4096 should supply its own edges.
pub fn default_bucket_edges() -> Vec<usize> {
    (1..=12).map(|p| 1usize << p).collect()
}

/// Index of the bucket holding `len` under `edges` (see
/// [`BatcherConfig::bucket_edges`]).
pub fn bucket_index(len: usize, edges: &[usize]) -> usize {
    edges.iter().position(|&e| len <= e).unwrap_or(edges.len())
}

/// Split `items` into length-homogeneous buckets, preserving arrival
/// order within each bucket; buckets come out in first-seen order. Every
/// item lands in exactly one bucket — nothing is dropped or duplicated.
/// Empty `edges` (or a trivial batch) returns the batch as one bucket.
pub fn bucket_by_len<T, F: Fn(&T) -> usize>(
    items: Vec<T>,
    edges: &[usize],
    len_of: F,
) -> Vec<Vec<T>> {
    bucket_by_key(items, edges, |t| (0, len_of(t)))
}

/// [`bucket_by_len`] with an extra coalescing **class**: items bucket
/// within (class, length bucket), so requests that must not share a
/// scored chunk — rescore vs prefill vs decode — never coalesce even
/// when their window lengths match. Class separation is unconditional;
/// empty `edges` only disables the *length* split within a class.
pub fn bucket_by_key<T, F: Fn(&T) -> (usize, usize)>(
    items: Vec<T>,
    edges: &[usize],
    key_of: F,
) -> Vec<Vec<T>> {
    if items.len() <= 1 {
        return vec![items];
    }
    let mut buckets: Vec<Vec<T>> = Vec::new();
    let mut slot: Vec<(usize, usize, usize)> = Vec::new(); // (class, len bucket) → bucket
    for item in items {
        let (class, len) = key_of(&item);
        let b = if edges.is_empty() {
            0
        } else {
            bucket_index(len, edges)
        };
        let at = match slot.iter().find(|&&(c, lb, _)| (c, lb) == (class, b)) {
            Some(&(_, _, at)) => at,
            None => {
                slot.push((class, b, buckets.len()));
                buckets.push(Vec::new());
                buckets.len() - 1
            }
        };
        buckets[at].push(item);
    }
    buckets
}

struct State<T> {
    queue: VecDeque<T>,
    closed: bool,
}

/// Outcome of a bounded-wait [`Batcher::poll_batch`].
pub enum BatchPoll<T> {
    /// A non-empty batch of up to `max_batch` items.
    Batch(Vec<T>),
    /// Nothing arrived within the idle window; the queue is still open.
    Idle,
    /// Closed and fully drained.
    Closed,
}

/// Outcome of a bounded-wait [`Batcher::poll_buckets`]: one polled batch,
/// coalesced into length-homogeneous buckets.
pub enum BucketPoll<T> {
    /// Non-empty buckets covering one polled batch (each bucket non-empty,
    /// arrival order preserved within it).
    Buckets(Vec<Vec<T>>),
    /// Nothing arrived within the idle window; the queue is still open.
    Idle,
    /// Closed and fully drained.
    Closed,
}

/// MPMC dynamic batching queue.
pub struct Batcher<T> {
    cfg: BatcherConfig,
    state: Mutex<State<T>>,
    cv: Condvar,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Batcher<T> {
        Batcher {
            cfg,
            state: Mutex::new(State {
                queue: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Enqueue; returns Err(item) if the queue is full or closed.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        if s.closed || s.queue.len() >= self.cfg.capacity {
            return Err(item);
        }
        s.queue.push_back(item);
        drop(s);
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop of the next batch. Returns None when closed and drained.
    /// Waits for the first item indefinitely, then up to `max_wait` for the
    /// batch to fill.
    pub fn pop_batch(&self) -> Option<Vec<T>> {
        loop {
            match self.poll_batch(Duration::from_millis(100)) {
                BatchPoll::Batch(b) => return Some(b),
                BatchPoll::Idle => continue,
                BatchPoll::Closed => return None,
            }
        }
    }

    /// Bounded-wait pop: waits up to `idle_wait` for the first item, then up
    /// to `max_wait` for the batch to fill. Returning [`BatchPoll::Idle`] on
    /// an empty window gives the caller a chance to service control work
    /// (e.g. a pending scorer hot-swap) without dropping requests.
    pub fn poll_batch(&self, idle_wait: Duration) -> BatchPoll<T> {
        let mut s = self.state.lock().unwrap();
        // wait for the first item (or close / idle timeout)
        let idle_deadline = Instant::now() + idle_wait;
        while s.queue.is_empty() {
            if s.closed {
                return BatchPoll::Closed;
            }
            let now = Instant::now();
            if now >= idle_deadline {
                return BatchPoll::Idle;
            }
            let (ns, _) = self.cv.wait_timeout(s, idle_deadline - now).unwrap();
            s = ns;
        }
        // batch-fill window
        let deadline = Instant::now() + self.cfg.max_wait;
        while s.queue.len() < self.cfg.max_batch && !s.closed {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (ns, timeout) = self.cv.wait_timeout(s, deadline - now).unwrap();
            s = ns;
            if timeout.timed_out() {
                break;
            }
        }
        let take = s.queue.len().min(self.cfg.max_batch);
        if take == 0 {
            // another consumer drained the queue while this one released
            // the lock inside the fill window — report Idle rather than an
            // empty batch (which would pollute batch-size metrics)
            return if s.closed {
                BatchPoll::Closed
            } else {
                BatchPoll::Idle
            };
        }
        BatchPoll::Batch(s.queue.drain(..take).collect())
    }

    /// [`Batcher::poll_batch`] plus length coalescing: the polled batch is
    /// split into buckets of similar `len_of` (see
    /// [`BatcherConfig::bucket_edges`]), so a worker can score
    /// bucket-by-bucket and every `forward_batch` call sees near-uniform
    /// window lengths. The union of the buckets is exactly the polled
    /// batch — per-item reply routing is untouched.
    pub fn poll_buckets<F: Fn(&T) -> usize>(&self, idle_wait: Duration, len_of: F) -> BucketPoll<T> {
        self.poll_buckets_keyed(idle_wait, |t| (0, len_of(t)))
    }

    /// [`Batcher::poll_buckets`] with a (class, length) key: buckets
    /// never mix classes ([`bucket_by_key`]), which is how decode steps
    /// coalesce with each other instead of padding against rescore or
    /// prefill windows in the same poll.
    pub fn poll_buckets_keyed<F: Fn(&T) -> (usize, usize)>(
        &self,
        idle_wait: Duration,
        key_of: F,
    ) -> BucketPoll<T> {
        match self.poll_batch(idle_wait) {
            BatchPoll::Batch(b) => {
                let _span = crate::obs::Span::enter(crate::obs::Stage::BucketForm);
                BucketPoll::Buckets(bucket_by_key(b, &self.cfg.bucket_edges, key_of))
            }
            BatchPoll::Idle => BucketPoll::Idle,
            BatchPoll::Closed => BucketPoll::Closed,
        }
    }

    /// Close the queue; pending items are still drained by pop_batch.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    fn cfg(max_batch: usize, wait_ms: u64, cap: usize) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
            capacity: cap,
            ..BatcherConfig::default()
        }
    }

    #[test]
    fn batches_up_to_max() {
        let b = Batcher::new(cfg(3, 5, 100));
        for i in 0..7 {
            b.push(i).unwrap();
        }
        assert_eq!(b.pop_batch().unwrap(), vec![0, 1, 2]);
        assert_eq!(b.pop_batch().unwrap(), vec![3, 4, 5]);
        assert_eq!(b.pop_batch().unwrap(), vec![6]);
    }

    #[test]
    fn waits_for_first_item() {
        let b = Arc::new(Batcher::new(cfg(4, 1, 100)));
        let b2 = b.clone();
        let h = thread::spawn(move || b2.pop_batch());
        thread::sleep(Duration::from_millis(20));
        b.push(42).unwrap();
        let got = h.join().unwrap().unwrap();
        assert_eq!(got, vec![42]);
    }

    #[test]
    fn fills_batch_within_wait_window() {
        let b = Arc::new(Batcher::new(cfg(4, 50, 100)));
        let b2 = b.clone();
        let h = thread::spawn(move || b2.pop_batch());
        thread::sleep(Duration::from_millis(5));
        for i in 0..4 {
            b.push(i).unwrap();
            thread::sleep(Duration::from_millis(2));
        }
        let got = h.join().unwrap().unwrap();
        assert_eq!(got.len(), 4, "batch should fill during the wait window");
    }

    #[test]
    fn backpressure_rejects_over_capacity() {
        let b = Batcher::new(cfg(4, 1, 2));
        assert!(b.push(1).is_ok());
        assert!(b.push(2).is_ok());
        assert_eq!(b.push(3), Err(3));
    }

    #[test]
    fn poll_batch_reports_idle_then_batches() {
        let b = Batcher::new(cfg(4, 1, 100));
        let t0 = std::time::Instant::now();
        assert!(matches!(
            b.poll_batch(Duration::from_millis(5)),
            BatchPoll::Idle
        ));
        assert!(t0.elapsed() >= Duration::from_millis(5));
        b.push(9).unwrap();
        match b.poll_batch(Duration::from_millis(5)) {
            BatchPoll::Batch(v) => assert_eq!(v, vec![9]),
            _ => panic!("expected a batch"),
        }
        b.close();
        assert!(matches!(
            b.poll_batch(Duration::from_millis(5)),
            BatchPoll::Closed
        ));
    }

    #[test]
    fn close_drains_then_none() {
        let b = Batcher::new(cfg(4, 1, 100));
        b.push(1).unwrap();
        b.close();
        assert!(b.push(2).is_err());
        assert_eq!(b.pop_batch().unwrap(), vec![1]);
        assert!(b.pop_batch().is_none());
    }

    #[test]
    fn bucket_index_edges() {
        let edges = default_bucket_edges();
        assert_eq!(bucket_index(1, &edges), 0);
        assert_eq!(bucket_index(2, &edges), 0);
        assert_eq!(bucket_index(3, &edges), 1);
        assert_eq!(bucket_index(16, &edges), 3);
        assert_eq!(bucket_index(17, &edges), 4);
        assert_eq!(bucket_index(4096, &edges), edges.len() - 1);
        assert_eq!(bucket_index(9999, &edges), edges.len()); // overflow bucket
        assert_eq!(bucket_index(7, &[]), 0); // no edges: single bucket
        // the <2x within-bucket spread the padding bound rests on
        for len in 1..=4096usize {
            let b = bucket_index(len, &edges);
            let hi = edges[b];
            assert!(hi < 2 * len || hi <= 2, "len {len} bucket edge {hi}");
        }
    }

    /// Bucketing is a partition: nothing dropped, nothing duplicated,
    /// arrival order preserved within each bucket, lengths homogeneous.
    #[test]
    fn bucket_by_len_partitions_without_loss() {
        let edges = vec![4usize, 8, 16];
        let items: Vec<usize> = vec![3, 9, 4, 17, 8, 1, 100, 16, 5];
        let buckets = bucket_by_len(items.clone(), &edges, |&l| l);
        let mut seen: Vec<usize> = buckets.iter().flatten().copied().collect();
        assert_eq!(seen.len(), items.len(), "no drops or duplicates");
        seen.sort_unstable();
        let mut want = items.clone();
        want.sort_unstable();
        assert_eq!(seen, want);
        for b in &buckets {
            assert!(!b.is_empty(), "no empty buckets emitted");
            let idx = bucket_index(b[0], &edges);
            assert!(b.iter().all(|&l| bucket_index(l, &edges) == idx));
            // arrival order within the bucket matches submission order
            let in_order: Vec<usize> = items
                .iter()
                .copied()
                .filter(|&l| bucket_index(l, &edges) == idx)
                .collect();
            assert_eq!(b, &in_order);
        }
        // empty edge list disables coalescing
        assert_eq!(bucket_by_len(items.clone(), &[], |&l| l), vec![items]);
    }

    /// Class separation is unconditional: same lengths, different
    /// classes → different buckets; and with empty edges the classes
    /// still split (only the length coalescing is disabled).
    #[test]
    fn bucket_by_key_never_mixes_classes() {
        let edges = vec![4usize, 8];
        // (class, len)
        let items = vec![(0, 3), (1, 3), (0, 4), (2, 9), (1, 8), (2, 2)];
        let buckets = bucket_by_key(items.clone(), &edges, |&(c, l)| (c, l));
        assert_eq!(
            buckets,
            vec![
                vec![(0, 3), (0, 4)],
                vec![(1, 3)],
                vec![(2, 9)],
                vec![(1, 8)],
                vec![(2, 2)],
            ]
        );
        let no_edges = bucket_by_key(items, &[], |&(c, l)| (c, l));
        assert_eq!(
            no_edges,
            vec![vec![(0, 3), (0, 4)], vec![(1, 3), (1, 8)], vec![(2, 9), (2, 2)]]
        );
    }

    #[test]
    fn poll_buckets_keyed_separates_classes() {
        let b: Batcher<(usize, usize)> = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            capacity: 64,
            bucket_edges: vec![4, 8],
        });
        for it in [(0usize, 2usize), (2, 2), (0, 3), (2, 3)] {
            b.push(it).unwrap();
        }
        match b.poll_buckets_keyed(Duration::from_millis(5), |&(c, l)| (c, l)) {
            BucketPoll::Buckets(bs) => {
                assert_eq!(bs, vec![vec![(0, 2), (0, 3)], vec![(2, 2), (2, 3)]]);
            }
            _ => panic!("expected buckets"),
        }
    }

    #[test]
    fn poll_buckets_coalesces_by_length() {
        let b: Batcher<usize> = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            capacity: 64,
            bucket_edges: vec![4, 8],
        });
        for len in [2usize, 6, 3, 9, 7] {
            b.push(len).unwrap();
        }
        match b.poll_buckets(Duration::from_millis(5), |&l| l) {
            BucketPoll::Buckets(bs) => {
                assert_eq!(bs, vec![vec![2, 3], vec![6, 7], vec![9]]);
            }
            _ => panic!("expected buckets"),
        }
        b.close();
        assert!(matches!(
            b.poll_buckets(Duration::from_millis(1), |&l| l),
            BucketPoll::Closed
        ));
    }

    #[test]
    fn concurrent_producers_consumers() {
        let b = Arc::new(Batcher::new(cfg(8, 2, 10_000)));
        let total = 500;
        let mut producers = Vec::new();
        for p in 0..4 {
            let b = b.clone();
            producers.push(thread::spawn(move || {
                for i in 0..total {
                    while b.push(p * total + i).is_err() {
                        thread::yield_now();
                    }
                }
            }));
        }
        let consumer = {
            let b = b.clone();
            thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = b.pop_batch() {
                    seen.extend(batch);
                }
                seen
            })
        };
        for p in producers {
            p.join().unwrap();
        }
        // give the consumer time to drain, then close
        while !b.is_empty() {
            thread::yield_now();
        }
        b.close();
        let mut seen = consumer.join().unwrap();
        seen.sort_unstable();
        assert_eq!(seen.len(), 4 * total);
        assert_eq!(seen, (0..4 * total).collect::<Vec<_>>());
    }
}
