//! The coordinator facade: one batcher + worker thread per model variant,
//! a submit API with backpressure, metrics, scorer hot-swap, and graceful
//! shutdown.

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{RequestKind, ScoreRequest, ScoreResponse, Variant};
use crate::coordinator::worker::{
    run_worker_init_failed, run_worker_swappable, BoxScorer, Scorer, SwapRequest,
};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Clone, Debug, Default)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
}

struct VariantLane {
    batcher: Arc<Batcher<ScoreRequest>>,
    workers: Vec<JoinHandle<()>>,
    /// one swap mailbox per worker (mutexed so `Coordinator` stays `Sync`)
    swap_txs: Vec<Mutex<Sender<SwapRequest>>>,
}

/// The metrics reporter thread: periodically samples queue depths into
/// the gauges, logs the one-line summary (silenced by `HISOLO_LOG=off`),
/// and optionally rewrites a JSON snapshot file.
struct Reporter {
    stop: Arc<AtomicBool>,
    handle: JoinHandle<()>,
}

/// The serving coordinator. Register one or more scorers per variant, then
/// `submit` windows and collect responses.
pub struct Coordinator {
    lanes: HashMap<Variant, VariantLane>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    cfg: CoordinatorConfig,
    reporter: Option<Reporter>,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        Coordinator {
            lanes: HashMap::new(),
            metrics: Arc::new(Metrics::new()),
            next_id: AtomicU64::new(0),
            cfg,
            reporter: None,
        }
    }

    /// Add a worker for `variant`; multiple workers share the variant queue.
    pub fn add_worker<S: Scorer + Send + 'static>(&mut self, variant: Variant, scorer: S) {
        self.add_worker_factory(variant, move || Ok(scorer));
    }

    /// Add a worker whose scorer is constructed *on the worker thread* —
    /// required for PJRT-backed scorers: the xla client is `!Send`, so each
    /// worker owns its own client/executable.
    pub fn add_worker_factory<S, F>(&mut self, variant: Variant, factory: F)
    where
        S: Scorer + 'static,
        F: FnOnce() -> anyhow::Result<S> + Send + 'static,
    {
        let lane = self.lanes.entry(variant).or_insert_with(|| VariantLane {
            batcher: Arc::new(Batcher::new(self.cfg.batcher.clone())),
            workers: Vec::new(),
            swap_txs: Vec::new(),
        });
        let batcher = lane.batcher.clone();
        let metrics = self.metrics.clone();
        let (swap_tx, swap_rx) = channel();
        lane.swap_txs.push(Mutex::new(swap_tx));
        lane.workers.push(std::thread::spawn(move || {
            match factory() {
                Ok(scorer) => {
                    run_worker_swappable(variant, Box::new(scorer), batcher, metrics, swap_rx)
                }
                Err(e) => {
                    crate::util::logging::log(
                        crate::util::logging::Level::Error,
                        format_args!("worker factory failed: {e:#}"),
                    );
                    // drain requests with errors, but keep the swap mailbox
                    // live so a later swap_variant can repair the lane
                    run_worker_init_failed(variant, format!("{e:#}"), batcher, metrics, swap_rx)
                }
            }
        }));
    }

    /// Atomically replace the scorer(s) serving `variant` while requests
    /// are in flight. The factory runs once per worker, *on that worker's
    /// thread* (PJRT clients are `!Send`); each worker installs the new
    /// scorer between batches, so every request is answered wholly by the
    /// old or wholly by the new model — never a mix. A factory error keeps
    /// the old scorer serving and surfaces through the returned
    /// [`SwapTicket`].
    pub fn swap_variant<S, F>(&self, variant: Variant, factory: F) -> anyhow::Result<SwapTicket>
    where
        S: Scorer + 'static,
        F: Fn() -> anyhow::Result<S> + Send + Sync + 'static,
    {
        let lane = self
            .lanes
            .get(&variant)
            .ok_or_else(|| anyhow::anyhow!("no worker registered for variant {variant:?}"))?;
        let factory = Arc::new(factory);
        let (ack_tx, ack_rx) = channel();
        // deliver to every worker before judging the outcome: aborting on
        // the first dead mailbox would leave earlier workers swapped while
        // the caller believes nothing changed
        let mut expected = 0;
        let mut undelivered = 0;
        for tx in &lane.swap_txs {
            let f = factory.clone();
            let req = SwapRequest {
                factory: Box::new(move || (*f)().map(|s| Box::new(s) as BoxScorer)),
                ack: ack_tx.clone(),
            };
            match tx.lock().unwrap().send(req) {
                Ok(()) => expected += 1,
                Err(_) => undelivered += 1, // worker thread has exited
            }
        }
        Ok(SwapTicket {
            expected,
            undelivered,
            acks: ack_rx,
        })
    }

    /// [`Coordinator::swap_variant`] with **background prefetch**: the
    /// factory runs on a helper thread — store parse, payload decode, and
    /// workspace warmup all happen off the serving lanes — and each worker
    /// receives an already-built scorer it merely installs between
    /// batches. This shrinks the swap window from "parse + install" to
    /// "install": for multi-GB stores the worker never stops serving while
    /// the incoming variant is read. Requires `S: Send` (native scorers
    /// are; PJRT-backed ones must keep using [`Coordinator::swap_variant`],
    /// whose factory runs on the worker thread).
    ///
    /// Returns immediately; the [`SwapTicket`] resolves once every worker
    /// installed its prefetched scorer (or any build failed — the old
    /// scorer then keeps serving, exactly like a failed `swap_variant`).
    pub fn swap_variant_prefetched<S, F>(
        &self,
        variant: Variant,
        factory: F,
    ) -> anyhow::Result<SwapTicket>
    where
        S: Scorer + Send + 'static,
        F: Fn() -> anyhow::Result<S> + Send + Sync + 'static,
    {
        let lane = self
            .lanes
            .get(&variant)
            .ok_or_else(|| anyhow::anyhow!("no worker registered for variant {variant:?}"))?;
        let (ack_tx, ack_rx) = channel();
        // snapshot the mailboxes so the helper thread owns its own senders
        let txs: Vec<Sender<SwapRequest>> = lane
            .swap_txs
            .iter()
            .map(|m| m.lock().unwrap().clone())
            .collect();
        let expected = txs.len();
        std::thread::spawn(move || {
            for tx in txs {
                // build here, off the serving lane
                match factory() {
                    Ok(scorer) => {
                        let mut slot = Some(scorer);
                        let req = SwapRequest {
                            factory: Box::new(move || {
                                let s = slot.take().expect("prefetched scorer installed once");
                                Ok(Box::new(s) as BoxScorer)
                            }),
                            ack: ack_tx.clone(),
                        };
                        if tx.send(req).is_err() {
                            // worker thread exited after the snapshot:
                            // surface it as a failed ack so wait() errors
                            // instead of timing out
                            let gone = "worker exited before the prefetched swap arrived";
                            let _ = ack_tx.send(Err(gone.into()));
                        }
                    }
                    Err(e) => {
                        let _ = ack_tx.send(Err(format!("{e:#}")));
                    }
                }
            }
        });
        Ok(SwapTicket {
            expected,
            undelivered: 0,
            acks: ack_rx,
        })
    }

    /// [`Coordinator::swap_variant_prefetched`] with **shard streaming**:
    /// `load` runs once on the helper thread — for a sharded `HSB2`
    /// variant that is `CompressedModel::from_store_with_progress`, which
    /// decodes layers across threads and reports each as it lands — and
    /// every [`LayerProgress`] event is forwarded on the returned
    /// receiver while the old scorer keeps serving. Once the load
    /// completes, `make_scorer` builds one scorer per worker off the
    /// shared result and each worker installs it between batches, so
    /// per-request consistency is exactly `swap_variant`'s: a request is
    /// answered wholly by the old or wholly by the new model.
    ///
    /// A failed load keeps the old scorers serving; the ticket's `wait`
    /// reports the error (one failed ack per expected worker).
    pub fn swap_variant_streamed<T, S, L, F>(
        &self,
        variant: Variant,
        load: L,
        make_scorer: F,
    ) -> anyhow::Result<StreamedSwap>
    where
        T: Send + 'static,
        S: Scorer + Send + 'static,
        L: FnOnce(Sender<LayerProgress>) -> anyhow::Result<T> + Send + 'static,
        F: Fn(&T) -> anyhow::Result<S> + Send + 'static,
    {
        let lane = self
            .lanes
            .get(&variant)
            .ok_or_else(|| anyhow::anyhow!("no worker registered for variant {variant:?}"))?;
        let (ack_tx, ack_rx) = channel();
        let (progress_tx, progress_rx) = channel();
        let txs: Vec<Sender<SwapRequest>> = lane
            .swap_txs
            .iter()
            .map(|m| m.lock().unwrap().clone())
            .collect();
        let expected = txs.len();
        std::thread::spawn(move || {
            // the expensive part happens once, off every serving lane,
            // streaming per-layer completions as they happen
            let loaded = match load(progress_tx) {
                Ok(t) => t,
                Err(e) => {
                    // fail every expected ack so wait() errors promptly
                    for _ in 0..expected {
                        let _ = ack_tx.send(Err(format!("{e:#}")));
                    }
                    return;
                }
            };
            for tx in txs {
                match make_scorer(&loaded) {
                    Ok(scorer) => {
                        let mut slot = Some(scorer);
                        let req = SwapRequest {
                            factory: Box::new(move || {
                                let s = slot.take().expect("streamed scorer installed once");
                                Ok(Box::new(s) as BoxScorer)
                            }),
                            ack: ack_tx.clone(),
                        };
                        if tx.send(req).is_err() {
                            let gone = "worker exited before the streamed swap arrived";
                            let _ = ack_tx.send(Err(gone.into()));
                        }
                    }
                    Err(e) => {
                        let _ = ack_tx.send(Err(format!("{e:#}")));
                    }
                }
            }
        });
        Ok(StreamedSwap {
            ticket: SwapTicket {
                expected,
                undelivered: 0,
                acks: ack_rx,
            },
            progress: progress_rx,
        })
    }

    /// Submit one window for a stateless rescore; the response arrives on
    /// the returned receiver. Errors (backpressure / unknown variant) are
    /// returned immediately.
    pub fn submit(
        &self,
        variant: Variant,
        window: Vec<u32>,
    ) -> anyhow::Result<Receiver<ScoreResponse>> {
        self.submit_kind(variant, RequestKind::Score, window)
    }

    /// Open (or replace) a paged-KV session: cache the window's K/V on
    /// the lane's scorer under `session` and score its internal targets.
    /// Requires the lane's scorer to hold a KV cache (`--kv-pages`).
    pub fn submit_prefill(
        &self,
        variant: Variant,
        session: u64,
        window: Vec<u32>,
    ) -> anyhow::Result<Receiver<ScoreResponse>> {
        self.submit_kind(variant, RequestKind::Prefill { session }, window)
    }

    /// Append `tokens` to a cached session, one O(t) decode step each —
    /// the reply's NLL covers exactly those tokens. An unknown or evicted
    /// session comes back as a per-request error reply.
    pub fn submit_decode(
        &self,
        variant: Variant,
        session: u64,
        tokens: Vec<u32>,
    ) -> anyhow::Result<Receiver<ScoreResponse>> {
        self.submit_kind(variant, RequestKind::Decode { session }, tokens)
    }

    fn submit_kind(
        &self,
        variant: Variant,
        kind: RequestKind,
        window: Vec<u32>,
    ) -> anyhow::Result<Receiver<ScoreResponse>> {
        let lane = self
            .lanes
            .get(&variant)
            .ok_or_else(|| anyhow::anyhow!("no worker registered for variant {variant:?}"))?;
        let (tx, rx) = channel();
        let req = ScoreRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            // mint the end-to-end trace id here: it rides the request
            // through batcher → bucket → worker and is echoed on the reply
            trace: crate::obs::TraceId::next(),
            variant,
            kind,
            window,
            submitted: Instant::now(),
            reply: tx,
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        lane.batcher.push(req).map_err(|_| {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::anyhow!("queue full (backpressure) for {variant:?}")
        })?;
        Ok(rx)
    }

    /// Submit many windows and block for all responses (order preserved).
    pub fn submit_all(
        &self,
        variant: Variant,
        windows: &[Vec<u32>],
    ) -> anyhow::Result<Vec<ScoreResponse>> {
        let rxs: Vec<Receiver<ScoreResponse>> = windows
            .iter()
            .map(|w| self.submit(variant, w.clone()))
            .collect::<anyhow::Result<_>>()?;
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|e| anyhow::anyhow!("worker gone: {e}")))
            .collect()
    }

    /// Worker count for a variant (0 if unregistered).
    pub fn worker_count(&self, variant: Variant) -> usize {
        self.lanes.get(&variant).map_or(0, |l| l.workers.len())
    }

    /// Sample every lane's queue length into the per-variant queue-depth
    /// gauge (the reporter thread does this each tick; call it directly
    /// before reading `metrics.queue_depth` / taking a final snapshot).
    pub fn sample_queue_depths(&self) {
        for (variant, lane) in self.lanes.iter() {
            self.metrics
                .set_queue_depth(*variant, lane.batcher.len() as u64);
        }
    }

    /// Start the periodic metrics reporter: every `interval` it samples
    /// queue depths, logs the one-line summary at info level (set
    /// `HISOLO_LOG=off` to silence it in benches/tests), and — when
    /// `json_path` is given — atomically rewrites that file with the
    /// [`Metrics::to_json`] snapshot. Register workers first: the thread
    /// samples the lanes that exist at call time. A second call replaces
    /// the previous reporter; `shutdown` stops it.
    pub fn start_reporter(&mut self, interval: Duration, json_path: Option<PathBuf>) {
        self.stop_reporter();
        let stop = Arc::new(AtomicBool::new(false));
        let lanes: Vec<(Variant, Arc<Batcher<ScoreRequest>>)> = self
            .lanes
            .iter()
            .map(|(v, l)| (*v, l.batcher.clone()))
            .collect();
        let metrics = self.metrics.clone();
        let stop2 = stop.clone();
        let handle = std::thread::spawn(move || {
            loop {
                // sleep in short slices so shutdown never waits a full tick
                let mut slept = Duration::ZERO;
                while slept < interval && !stop2.load(Ordering::Relaxed) {
                    let step = (interval - slept).min(Duration::from_millis(50));
                    std::thread::sleep(step);
                    slept += step;
                }
                if stop2.load(Ordering::Relaxed) {
                    return;
                }
                for (variant, batcher) in &lanes {
                    metrics.set_queue_depth(*variant, batcher.len() as u64);
                }
                // advance the rolling SLO window once per tick, so the
                // window burn rate covers the last ~window·interval
                metrics.slo_tick();
                crate::log_info!("metrics: {}", metrics.summary());
                if let Some(path) = &json_path {
                    if let Err(e) = std::fs::write(path, format!("{}\n", metrics.to_json())) {
                        crate::log_warn!("metrics snapshot write failed: {e}");
                    }
                }
            }
        });
        self.reporter = Some(Reporter { stop, handle });
    }

    fn stop_reporter(&mut self) {
        if let Some(r) = self.reporter.take() {
            r.stop.store(true, Ordering::Relaxed);
            let _ = r.handle.join();
        }
    }

    /// Close all queues and join workers (reporter first, so no tick
    /// observes half-closed lanes).
    pub fn shutdown(mut self) {
        self.stop_reporter();
        for (_, lane) in self.lanes.iter() {
            lane.batcher.close();
        }
        for (_, lane) in self.lanes.drain() {
            for w in lane.workers {
                let _ = w.join();
            }
        }
    }
}

/// One layer's q/k/v triple finished decoding during a
/// [`Coordinator::swap_variant_streamed`] load.
#[derive(Clone, Copy, Debug)]
pub struct LayerProgress {
    pub layer: usize,
    /// wall time decoding this layer took on its loader thread
    pub micros: u64,
}

/// Handle on an in-flight [`Coordinator::swap_variant_streamed`]: the
/// [`SwapTicket`] resolving the install, plus the live per-layer
/// progress stream of the background load (the sender side drops when
/// the load finishes, so iterating the receiver terminates).
pub struct StreamedSwap {
    pub ticket: SwapTicket,
    pub progress: Receiver<LayerProgress>,
}

/// Handle on an in-flight [`Coordinator::swap_variant`]: one ack per
/// worker the request reached.
pub struct SwapTicket {
    expected: usize,
    /// workers whose mailbox was gone (thread exited) at send time
    undelivered: usize,
    acks: Receiver<Result<(), String>>,
}

impl SwapTicket {
    /// Workers that must acknowledge before the swap is complete.
    pub fn expected_acks(&self) -> usize {
        self.expected
    }

    /// Workers the swap never reached because their thread had exited.
    pub fn undelivered(&self) -> usize {
        self.undelivered
    }

    /// Block until every reachable worker applied the swap (or any
    /// rejected it). Requests keep flowing the whole time — this only
    /// waits for the *new* scorer to take over. Errors if any worker
    /// rejected the swap or was unreachable, after collecting the acks
    /// from the workers that did swap.
    pub fn wait(self, timeout: Duration) -> anyhow::Result<()> {
        let deadline = Instant::now() + timeout;
        for done in 0..self.expected {
            let left = deadline
                .checked_duration_since(Instant::now())
                .unwrap_or_default();
            match self.acks.recv_timeout(left) {
                Ok(Ok(())) => {}
                Ok(Err(e)) => anyhow::bail!("swap rejected by a worker: {e}"),
                Err(_) => anyhow::bail!(
                    "swap not acknowledged in time ({done}/{} workers)",
                    self.expected
                ),
            }
        }
        if self.undelivered > 0 {
            anyhow::bail!(
                "{} worker(s) had already exited and were not swapped ({} were)",
                self.undelivered,
                self.expected
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::tests::MockScorer;
    use std::time::Duration;

    fn coordinator_with_mock(fail: bool) -> Coordinator {
        let mut c = Coordinator::new(CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                capacity: 32,
                ..BatcherConfig::default()
            },
        });
        c.add_worker(
            Variant::Dense,
            MockScorer {
                vocab: 16,
                seq: 8,
                batch: 4,
                fail,
            },
        );
        c
    }

    /// Full session lifecycle through the coordinator: prefill opens the
    /// session on the lane's scorer, decode appends to it, a scorer
    /// without a KV cache rejects session traffic with a clear error, and
    /// the KV gauges land in the metrics snapshot.
    #[test]
    fn session_prefill_then_decode_roundtrip() {
        let mut c = Coordinator::new(CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                capacity: 32,
                ..BatcherConfig::default()
            },
        });
        let cfg = crate::model::ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 2,
            d_ff: 64,
            seq_len: 48,
        };
        let model = Arc::new(crate::model::Transformer::random(cfg, 11));
        c.add_worker(
            Variant::Dense,
            crate::coordinator::worker::NativeDenseScorer::new(model, 4).with_kv_pages(32),
        );
        let rx = c
            .submit_prefill(Variant::Dense, 1, (1..=20).collect())
            .unwrap();
        let pre = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(pre.error.is_none(), "{:?}", pre.error);
        assert_eq!(pre.tokens, 19);
        let rx = c.submit_decode(Variant::Dense, 1, vec![7]).unwrap();
        let dec = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(dec.error.is_none(), "{:?}", dec.error);
        assert_eq!(dec.tokens, 1);
        assert!(dec.nll.is_finite());
        assert!(c.metrics.kv_pages_resident.load(Ordering::Relaxed) > 0);
        c.shutdown();

        // a lane whose scorer has no KV cache rejects session traffic
        let c = coordinator_with_mock(false);
        let rx = c.submit_decode(Variant::Dense, 1, vec![7]).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let err = resp.error.expect("mock has no KV cache");
        assert!(err.contains("paged-KV"), "{err}");
        c.shutdown();
    }

    #[test]
    fn submit_roundtrip() {
        let c = coordinator_with_mock(false);
        let rx = c.submit(Variant::Dense, (0..9).collect()).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.error.is_none());
        assert!(resp.nll < 1e-3);
        c.shutdown();
    }

    #[test]
    fn submit_all_preserves_order() {
        let c = coordinator_with_mock(false);
        let windows: Vec<Vec<u32>> = (0..10u32)
            .map(|s| (s..s + 9).map(|v| v % 16).collect())
            .collect();
        let resps = c.submit_all(Variant::Dense, &windows).unwrap();
        assert_eq!(resps.len(), 10);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.error.is_none());
        }
        // batching actually happened (mean batch > 1 given burst submit)
        assert!(c.metrics.mean_batch_size() >= 1.0);
        c.shutdown();
    }

    #[test]
    fn trace_ids_minted_unique_and_echoed() {
        let c = coordinator_with_mock(false);
        let windows: Vec<Vec<u32>> = (0..6u32)
            .map(|s| (s..s + 9).map(|v| v % 16).collect())
            .collect();
        let resps = c.submit_all(Variant::Dense, &windows).unwrap();
        // every reply carries its request's trace id; submit order is
        // response order here, so the minted ids are strictly increasing
        let traces: Vec<u64> = resps.iter().map(|r| r.trace.0).collect();
        assert!(traces.iter().all(|&t| t > 0));
        assert!(traces.windows(2).all(|w| w[0] < w[1]), "{traces:?}");
        c.shutdown();
    }

    #[test]
    fn unknown_variant_rejected() {
        let c = coordinator_with_mock(false);
        assert!(c.submit(Variant::Hss, (0..9).collect()).is_err());
        c.shutdown();
    }

    #[test]
    fn errors_propagate() {
        let c = coordinator_with_mock(true);
        let rx = c.submit(Variant::Dense, (0..9).collect()).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.error.is_some());
        c.shutdown();
    }

    #[test]
    fn swap_variant_replaces_scorer_between_requests() {
        let c = coordinator_with_mock(true); // dense lane starts failing
        let before = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(before.error.is_some());

        let ticket = c
            .swap_variant(Variant::Dense, || {
                Ok(MockScorer {
                    vocab: 16,
                    seq: 8,
                    batch: 4,
                    fail: false,
                })
            })
            .unwrap();
        assert_eq!(ticket.expected_acks(), 1);
        ticket.wait(Duration::from_secs(5)).unwrap();

        let after = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(after.error.is_none(), "{:?}", after.error);
        assert_eq!(c.metrics.swaps.load(Ordering::Relaxed), 1);
        c.shutdown();
    }

    #[test]
    fn prefetched_swap_replaces_scorer_and_failed_build_keeps_old() {
        use std::sync::atomic::AtomicUsize;
        let c = coordinator_with_mock(true); // lane starts failing
        // count factory runs: prefetch builds once per worker, on a helper
        // thread, before any worker mailbox sees the request
        let builds = Arc::new(AtomicUsize::new(0));
        let b2 = builds.clone();
        let ticket = c
            .swap_variant_prefetched(Variant::Dense, move || {
                b2.fetch_add(1, Ordering::SeqCst);
                Ok(MockScorer {
                    vocab: 16,
                    seq: 8,
                    batch: 4,
                    fail: false,
                })
            })
            .unwrap();
        assert_eq!(ticket.expected_acks(), 1);
        ticket.wait(Duration::from_secs(5)).unwrap();
        assert_eq!(builds.load(Ordering::SeqCst), 1);

        let resp = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(resp.nll < 1e-3);
        assert_eq!(c.metrics.swaps.load(Ordering::Relaxed), 1);

        // a failing prefetch build is surfaced and leaves the (now
        // healthy) scorer serving
        let err = c
            .swap_variant_prefetched(Variant::Dense, || -> anyhow::Result<MockScorer> {
                anyhow::bail!("store gone mid-prefetch")
            })
            .unwrap()
            .wait(Duration::from_secs(5))
            .unwrap_err();
        assert!(format!("{err}").contains("store gone mid-prefetch"), "{err}");
        let resp = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(resp.error.is_none());
        c.shutdown();
    }

    #[test]
    fn streamed_swap_reports_progress_then_installs() {
        let c = coordinator_with_mock(true); // lane starts failing
        let swap = c
            .swap_variant_streamed(
                Variant::Dense,
                // stand-in for from_store_with_progress: "decode" 3 layers,
                // streaming each, and return the shared load result
                |progress| {
                    for layer in 0..3usize {
                        progress
                            .send(LayerProgress {
                                layer,
                                micros: 10 + layer as u64,
                            })
                            .unwrap();
                    }
                    Ok(Arc::new(42usize))
                },
                |loaded: &Arc<usize>| {
                    assert_eq!(**loaded, 42);
                    Ok(MockScorer {
                        vocab: 16,
                        seq: 8,
                        batch: 4,
                        fail: false,
                    })
                },
            )
            .unwrap();
        // the progress stream terminates once the load finishes
        let events: Vec<LayerProgress> = swap.progress.iter().collect();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].layer, 0);
        assert_eq!(events[2].layer, 2);
        assert!(events.iter().all(|e| e.micros > 0));
        swap.ticket.wait(Duration::from_secs(5)).unwrap();

        let resp = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(c.metrics.swaps.load(Ordering::Relaxed), 1);

        // a failing load keeps the healthy scorer and fails the ticket
        let swap = c
            .swap_variant_streamed(
                Variant::Dense,
                |_progress| -> anyhow::Result<Arc<usize>> {
                    anyhow::bail!("shard gone mid-stream")
                },
                |_: &Arc<usize>| {
                    Ok(MockScorer {
                        vocab: 16,
                        seq: 8,
                        batch: 4,
                        fail: false,
                    })
                },
            )
            .unwrap();
        let err = swap.ticket.wait(Duration::from_secs(5)).unwrap_err();
        assert!(format!("{err}").contains("shard gone mid-stream"), "{err}");
        let resp = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(resp.error.is_none());
        c.shutdown();
    }

    #[test]
    fn swap_unknown_variant_rejected() {
        let c = coordinator_with_mock(false);
        assert!(c
            .swap_variant(Variant::Hss, || {
                Ok(MockScorer {
                    vocab: 16,
                    seq: 8,
                    batch: 4,
                    fail: false,
                })
            })
            .is_err());
        c.shutdown();
    }

    #[test]
    fn swap_repairs_a_lane_whose_init_factory_failed() {
        let mut c = Coordinator::new(CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                capacity: 32,
                ..BatcherConfig::default()
            },
        });
        c.add_worker_factory(Variant::Dense, || -> anyhow::Result<MockScorer> {
            anyhow::bail!("artifacts missing at boot")
        });
        // requests error (no hang) while the lane is degraded
        let r = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(r.error.as_deref().unwrap_or("").contains("worker init failed"));

        // a successful swap repairs the lane in place
        let ticket = c
            .swap_variant(Variant::Dense, || {
                Ok(MockScorer {
                    vocab: 16,
                    seq: 8,
                    batch: 4,
                    fail: false,
                })
            })
            .unwrap();
        ticket.wait(Duration::from_secs(5)).unwrap();
        let r = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        c.shutdown();
    }

    #[test]
    fn failed_swap_keeps_serving_on_old_scorer() {
        let c = coordinator_with_mock(false);
        let ticket = c
            .swap_variant(Variant::Dense, || -> anyhow::Result<MockScorer> {
                anyhow::bail!("store file corrupt")
            })
            .unwrap();
        let err = ticket.wait(Duration::from_secs(5)).unwrap_err();
        assert!(format!("{err}").contains("store file corrupt"), "{err}");
        // lane still healthy on the original scorer
        let resp = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(resp.error.is_none());
        c.shutdown();
    }

    #[test]
    fn reporter_emits_json_snapshot_and_samples_queue_depth() {
        let mut c = coordinator_with_mock(false);
        let path = std::env::temp_dir().join(format!(
            "hisolo-metrics-{}-{:?}.json",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_file(&path);
        c.start_reporter(Duration::from_millis(20), Some(path.clone()));
        let resps = c
            .submit_all(Variant::Dense, &[(0..9).collect(), (0..9).collect()])
            .unwrap();
        assert!(resps.iter().all(|r| r.error.is_none()));
        // wait for at least one tick to land on disk
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        let text = loop {
            if let Ok(t) = std::fs::read_to_string(&path) {
                if !t.is_empty() {
                    break t;
                }
            }
            assert!(std::time::Instant::now() < deadline, "no snapshot written");
            std::thread::sleep(Duration::from_millis(10));
        };
        let j = crate::util::json::Json::parse(text.trim()).unwrap();
        assert!(j.get("queue_wait").is_some(), "{text}");
        assert!(j.get("gauges").unwrap().get("queue_depth").is_some());
        assert!(j.get("stages").unwrap().get("hss_walk").is_some());
        c.sample_queue_depths(); // drained queue samples as depth 0
        assert_eq!(c.metrics.queue_depth(Variant::Dense), 0);
        c.shutdown(); // stops + joins the reporter before closing lanes
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn multiple_variants_routed_independently() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        c.add_worker(
            Variant::Dense,
            MockScorer {
                vocab: 16,
                seq: 8,
                batch: 4,
                fail: false,
            },
        );
        c.add_worker(
            Variant::Hss,
            MockScorer {
                vocab: 16,
                seq: 8,
                batch: 4,
                fail: true, // hss lane fails, dense succeeds
            },
        );
        let ok = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        let bad = c
            .submit(Variant::Hss, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(ok.error.is_none());
        assert!(bad.error.is_some());
        c.shutdown();
    }
}
