//! The coordinator facade: one batcher + worker thread per model variant,
//! a submit API with backpressure, metrics, and graceful shutdown.

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{ScoreRequest, ScoreResponse, Variant};
use crate::coordinator::worker::{run_worker, Scorer};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Clone, Copy, Debug, Default)]
pub struct CoordinatorConfig {
    pub batcher: BatcherConfig,
}

struct VariantLane {
    batcher: Arc<Batcher<ScoreRequest>>,
    workers: Vec<JoinHandle<()>>,
}

/// The serving coordinator. Register one or more scorers per variant, then
/// `submit` windows and collect responses.
pub struct Coordinator {
    lanes: HashMap<Variant, VariantLane>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
    cfg: CoordinatorConfig,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        Coordinator {
            lanes: HashMap::new(),
            metrics: Arc::new(Metrics::new()),
            next_id: AtomicU64::new(0),
            cfg,
        }
    }

    /// Add a worker for `variant`; multiple workers share the variant queue.
    pub fn add_worker<S: Scorer + Send + 'static>(&mut self, variant: Variant, scorer: S) {
        self.add_worker_factory(variant, move || Ok(scorer));
    }

    /// Add a worker whose scorer is constructed *on the worker thread* —
    /// required for PJRT-backed scorers: the xla client is `!Send`, so each
    /// worker owns its own client/executable.
    pub fn add_worker_factory<S, F>(&mut self, variant: Variant, factory: F)
    where
        S: Scorer + 'static,
        F: FnOnce() -> anyhow::Result<S> + Send + 'static,
    {
        let lane = self.lanes.entry(variant).or_insert_with(|| VariantLane {
            batcher: Arc::new(Batcher::new(self.cfg.batcher)),
            workers: Vec::new(),
        });
        let batcher = lane.batcher.clone();
        let metrics = self.metrics.clone();
        lane.workers.push(std::thread::spawn(move || {
            match factory() {
                Ok(scorer) => run_worker(scorer, batcher, metrics),
                Err(e) => {
                    crate::util::logging::log(
                        crate::util::logging::Level::Error,
                        format_args!("worker factory failed: {e:#}"),
                    );
                    // drain queue with errors so submitters don't hang
                    while let Some(batch) = batcher.pop_batch() {
                        for req in batch {
                            metrics.errors.fetch_add(1, Ordering::Relaxed);
                            let _ = req.reply.send(ScoreResponse {
                                id: req.id,
                                variant: req.variant,
                                nll: f64::NAN,
                                tokens: 0,
                                latency_us: 0,
                                batch_size: 0,
                                error: Some(format!("worker init failed: {e:#}")),
                            });
                        }
                    }
                }
            }
        }));
    }

    /// Submit one window; the response arrives on the returned receiver.
    /// Errors (backpressure / unknown variant) are returned immediately.
    pub fn submit(
        &self,
        variant: Variant,
        window: Vec<u32>,
    ) -> anyhow::Result<Receiver<ScoreResponse>> {
        let lane = self
            .lanes
            .get(&variant)
            .ok_or_else(|| anyhow::anyhow!("no worker registered for variant {variant:?}"))?;
        let (tx, rx) = channel();
        let req = ScoreRequest {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            variant,
            window,
            submitted: Instant::now(),
            reply: tx,
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        lane.batcher.push(req).map_err(|_| {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::anyhow!("queue full (backpressure) for {variant:?}")
        })?;
        Ok(rx)
    }

    /// Submit many windows and block for all responses (order preserved).
    pub fn submit_all(
        &self,
        variant: Variant,
        windows: &[Vec<u32>],
    ) -> anyhow::Result<Vec<ScoreResponse>> {
        let rxs: Vec<Receiver<ScoreResponse>> = windows
            .iter()
            .map(|w| self.submit(variant, w.clone()))
            .collect::<anyhow::Result<_>>()?;
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|e| anyhow::anyhow!("worker gone: {e}")))
            .collect()
    }

    /// Close all queues and join workers.
    pub fn shutdown(mut self) {
        for (_, lane) in self.lanes.iter() {
            lane.batcher.close();
        }
        for (_, lane) in self.lanes.drain() {
            for w in lane.workers {
                let _ = w.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::worker::tests::MockScorer;
    use std::time::Duration;

    fn coordinator_with_mock(fail: bool) -> Coordinator {
        let mut c = Coordinator::new(CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                capacity: 32,
            },
        });
        c.add_worker(
            Variant::Dense,
            MockScorer {
                vocab: 16,
                seq: 8,
                batch: 4,
                fail,
            },
        );
        c
    }

    #[test]
    fn submit_roundtrip() {
        let c = coordinator_with_mock(false);
        let rx = c.submit(Variant::Dense, (0..9).collect()).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.error.is_none());
        assert!(resp.nll < 1e-3);
        c.shutdown();
    }

    #[test]
    fn submit_all_preserves_order() {
        let c = coordinator_with_mock(false);
        let windows: Vec<Vec<u32>> = (0..10u32)
            .map(|s| (s..s + 9).map(|v| v % 16).collect())
            .collect();
        let resps = c.submit_all(Variant::Dense, &windows).unwrap();
        assert_eq!(resps.len(), 10);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.error.is_none());
        }
        // batching actually happened (mean batch > 1 given burst submit)
        assert!(c.metrics.mean_batch_size() >= 1.0);
        c.shutdown();
    }

    #[test]
    fn unknown_variant_rejected() {
        let c = coordinator_with_mock(false);
        assert!(c.submit(Variant::Hss, (0..9).collect()).is_err());
        c.shutdown();
    }

    #[test]
    fn errors_propagate() {
        let c = coordinator_with_mock(true);
        let rx = c.submit(Variant::Dense, (0..9).collect()).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.error.is_some());
        c.shutdown();
    }

    #[test]
    fn multiple_variants_routed_independently() {
        let mut c = Coordinator::new(CoordinatorConfig::default());
        c.add_worker(
            Variant::Dense,
            MockScorer {
                vocab: 16,
                seq: 8,
                batch: 4,
                fail: false,
            },
        );
        c.add_worker(
            Variant::Hss,
            MockScorer {
                vocab: 16,
                seq: 8,
                batch: 4,
                fail: true, // hss lane fails, dense succeeds
            },
        );
        let ok = c
            .submit(Variant::Dense, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        let bad = c
            .submit(Variant::Hss, (0..9).collect())
            .unwrap()
            .recv_timeout(Duration::from_secs(5))
            .unwrap();
        assert!(ok.error.is_none());
        assert!(bad.error.is_some());
        c.shutdown();
    }
}
