//! L3 serving coordinator: dynamic batcher, worker threads per model
//! variant, round-robin routing, scorer hot-swap, and metrics.
//!
//! The paper's contribution lives at the compression layer, so the
//! coordinator is the serving shell around it (DESIGN.md §3): requests are
//! token windows to score; workers own either an AOT PJRT executable
//! (dense / sHSS graphs) or a native forward pass, batch up to the
//! executable's static batch size, and return per-window NLL.
//!
//! # Serving path: bucket → stack → batched attention
//!
//! A polled batch flows through three coalescing stages, each turning
//! per-request work into one dense block operation:
//!
//! 1. **bucket** — [`Batcher::poll_buckets`] splits the poll into
//!    length-homogeneous buckets ([`BatcherConfig::bucket_edges`], default
//!    powers of two), so each scored chunk is a near-rectangular token
//!    block and padding waste on fixed-shape backends stays bounded
//!    (tracked by [`Metrics::padding_overhead`]);
//! 2. **stack** — the worker scores each bucket in one `forward_batch`
//!    call, which stacks the windows into a single tall [Σt, d] activation
//!    block: every q/k/v projection and MLP matmul runs once per (layer,
//!    bucket), and a compressed projection traverses its
//!    sparse-plus-low-rank structure once for the whole bucket;
//! 3. **batched attention** — `model::attention_batch` consumes the same
//!    stacked block with a per-window offset table, so even causal
//!    attention (inherently window-local) runs as packed head-blocked
//!    kernel calls with zero per-window allocation — there is no
//!    per-window loop anywhere in the serving pass.
//!
//! `eval::perplexity_parallel_batched` applies the same bucketing, so
//! sweep numbers exercise the identical code path the coordinator serves.
//!
//! # Sessions: prefill → paged KV → decode
//!
//! The three [`RequestKind`]s split serving into a stateless and a
//! stateful path. `Score` is the pre-decode path above: every request
//! rescores its full window, O(t²) total work across a conversation.
//! `Prefill { session }` opens a session on the lane's scorer: the
//! window runs through the same cache-writing batched forward once, its
//! K/V rows land in a paged pool ([`crate::model::kvcache`]), and the
//! reply scores the window's internal targets. `Decode { session }`
//! then appends tokens one O(t) step each — a single new query row per
//! sequence attends over the cached pages, so a conversation costs O(t)
//! per new token instead of O(t) per *rescore*.
//!
//! Cache mechanics (see `model::kvcache` for the layout formula):
//!
//! - **Page size** — [`crate::model::kvcache::DEFAULT_BLOCK_SIZE`] (16)
//!   tokens per page, all layers × K and V interleaved in one page so a
//!   sequence owns `ceil(len / 16)` pages regardless of depth. 16 keeps
//!   tail waste ≤ 15 tokens per sequence while page tables stay short.
//! - **Prefix sharing / COW** — full prompt blocks are published under a
//!   prefix-chain hash; a later prefill whose prompt shares the prefix
//!   retains the same pages instead of recomputing them (stored tokens
//!   are verified, so a hash collision can only miss sharing, never
//!   alias). Shared pages are immutable; appending into a shared tail
//!   block copies it first (copy-on-write), so sessions never observe
//!   each other's writes.
//! - **Eviction** — sessions are evicted LRU by last-served time when
//!   the pool runs dry; an evicted session's next decode gets a
//!   per-request error reply (same lifecycle split as every other arm)
//!   and the client re-prefills. Sessions in the current batch are never
//!   evicted.
//! - **Memory ceiling** — the pool is one f16 slab:
//!   `n_pages × 2 (K,V) × n_layers × block_size × d_model × 2 bytes`,
//!   allocated up front (`--kv-pages N`), so serving memory is fixed no
//!   matter how many sessions arrive.
//!
//! Decode requests coalesce into their own buckets
//! ([`Batcher::poll_buckets_keyed`] keys on `(kind class, len)`), so
//! single-token decode steps are never padded against full prefill
//! windows. Workers publish cache counters to [`Metrics`] after every
//! session batch (`kv_hit_rate`, `kv_pages_resident`, `kv_evictions` in
//! the summary line and `to_json` gauges). Decode NLLs are bit-identical
//! to a full-window cache-writing prefill of the same tokens — the
//! decode kernel replays the batched-attention last-row sequence exactly
//! (`model::attention::decode_batch`) — so `hisolo serve --decode`
//! asserts bitwise equality in its `decode_check` line.
//!
//! Session affinity is topological: a session lives in one scorer, and
//! each variant lane owns exactly one scorer, so no routing is needed.
//! A hot-swap replaces the scorer *and its cache* — sessions opened
//! before the swap error on their next decode and must re-prefill.
//!
//! # Observability
//!
//! Every request's end-to-end latency is split at the dequeue instant:
//! **queue_wait** (submit → worker poll) + **service** (poll → reply)
//! sum exactly to the recorded latency, each with its own histogram in
//! [`Metrics`] (p50/p95/p99/p999). Inside service time, the hot path is
//! traced by [`crate::obs`] span guards under the fixed stage taxonomy —
//! `bucket_form` (length coalescing), `spmm` / `hss_walk` / `lowrank`
//! (compressed apply), `attention`, `mlp`, `softmax` (scoring), and
//! `reply_route` / `swap_install` (coordination) — recorded at call-site
//! granularity only, never inside per-row loops (see the span-guard
//! rules in `obs`). `Batcher` queue depth and worker in-flight counts
//! are gauges: `Coordinator::start_reporter` samples them each tick,
//! logs the one-line `Metrics::summary`, and can rewrite a
//! `Metrics::to_json` snapshot file (`hisolo serve --metrics-json <path>
//! --metrics-interval-secs N`). `HISOLO_LOG=off` silences the reporter's
//! logging; `HISOLO_TRACE=off` disables the span guards themselves.
//!
//! ## Per-request tracing (flight recorder)
//!
//! [`Coordinator::submit`] mints a [`TraceId`] per request
//! ([`ScoreRequest::trace`], echoed on [`ScoreResponse::trace`]), so one
//! request is followable batcher → bucket → worker → reply. When
//! recording is on (`hisolo serve --trace-out`), the worker wraps every
//! scored chunk in a `FlightRecorder::begin_batch`/`end_batch` pair: the
//! kernel spans that fire while the chunk scores attribute to the batch,
//! and through it to **all** member trace IDs — the honest cost model of
//! batched serving. Memory is bounded: events live in fixed-capacity
//! seqlock rings (oldest overwritten on wrap) plus a slowest-N tail
//! reserve that survives wraparound; see `crate::obs::recorder` for the
//! ring layout, capacities, and the Chrome trace-event export schema
//! consumed by `hisolo trace`.
//!
//! ## SLO burn-rate accounting
//!
//! `Metrics::set_slo_target_us` arms a p99 error budget: a request
//! "violates" when its end-to-end latency exceeds the target, the budget
//! allows [`metrics::SLO_EPSILON`] (1%) violations, and `burn_rate =
//! violation_rate / SLO_EPSILON`. The reporter thread advances a rolling
//! window each tick (`Metrics::slo_tick`), so `slo_window_burn_rate`
//! forgets a bad spell once it ages past [`metrics::SLO_WINDOW_TICKS`]
//! ticks while the lifetime rate remembers it. Surfaced in the summary
//! line, the `slo` object of `Metrics::to_json`, and serve's
//! `slo_burn_check` output.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;
pub mod worker;

pub use batcher::{
    bucket_by_key, bucket_by_len, bucket_index, default_bucket_edges, BatchPoll, Batcher,
    BatcherConfig, BucketPoll,
};
pub use metrics::Metrics;
pub use request::{RequestKind, ScoreRequest, ScoreResponse, Variant};

pub use crate::obs::TraceId;
pub use server::{Coordinator, CoordinatorConfig, LayerProgress, StreamedSwap, SwapTicket};
pub use worker::{BoxScorer, Scorer, ScorerFactory, SwapRequest};
