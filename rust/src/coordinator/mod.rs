//! L3 serving coordinator: dynamic batcher, worker threads per model
//! variant, round-robin routing, scorer hot-swap, and metrics.
//!
//! The paper's contribution lives at the compression layer, so the
//! coordinator is the serving shell around it (DESIGN.md §3): requests are
//! token windows to score; workers own either an AOT PJRT executable
//! (dense / sHSS graphs) or a native forward pass, batch up to the
//! executable's static batch size, and return per-window NLL.
//!
//! # Serving path: bucket → stack → batched attention
//!
//! A polled batch flows through three coalescing stages, each turning
//! per-request work into one dense block operation:
//!
//! 1. **bucket** — [`Batcher::poll_buckets`] splits the poll into
//!    length-homogeneous buckets ([`BatcherConfig::bucket_edges`], default
//!    powers of two), so each scored chunk is a near-rectangular token
//!    block and padding waste on fixed-shape backends stays bounded
//!    (tracked by [`Metrics::padding_overhead`]);
//! 2. **stack** — the worker scores each bucket in one `forward_batch`
//!    call, which stacks the windows into a single tall [Σt, d] activation
//!    block: every q/k/v projection and MLP matmul runs once per (layer,
//!    bucket), and a compressed projection traverses its
//!    sparse-plus-low-rank structure once for the whole bucket;
//! 3. **batched attention** — `model::attention_batch` consumes the same
//!    stacked block with a per-window offset table, so even causal
//!    attention (inherently window-local) runs as packed head-blocked
//!    kernel calls with zero per-window allocation — there is no
//!    per-window loop anywhere in the serving pass.
//!
//! `eval::perplexity_parallel_batched` applies the same bucketing, so
//! sweep numbers exercise the identical code path the coordinator serves.
//!
//! # Observability
//!
//! Every request's end-to-end latency is split at the dequeue instant:
//! **queue_wait** (submit → worker poll) + **service** (poll → reply)
//! sum exactly to the recorded latency, each with its own histogram in
//! [`Metrics`] (p50/p95/p99/p999). Inside service time, the hot path is
//! traced by [`crate::obs`] span guards under the fixed stage taxonomy —
//! `bucket_form` (length coalescing), `spmm` / `hss_walk` / `lowrank`
//! (compressed apply), `attention`, `mlp`, `softmax` (scoring), and
//! `reply_route` / `swap_install` (coordination) — recorded at call-site
//! granularity only, never inside per-row loops (see the span-guard
//! rules in `obs`). `Batcher` queue depth and worker in-flight counts
//! are gauges: `Coordinator::start_reporter` samples them each tick,
//! logs the one-line `Metrics::summary`, and can rewrite a
//! `Metrics::to_json` snapshot file (`hisolo serve --metrics-json <path>
//! --metrics-interval-secs N`). `HISOLO_LOG=off` silences the reporter's
//! logging; `HISOLO_TRACE=off` disables the span guards themselves.
//!
//! ## Per-request tracing (flight recorder)
//!
//! [`Coordinator::submit`] mints a [`TraceId`] per request
//! ([`ScoreRequest::trace`], echoed on [`ScoreResponse::trace`]), so one
//! request is followable batcher → bucket → worker → reply. When
//! recording is on (`hisolo serve --trace-out`), the worker wraps every
//! scored chunk in a `FlightRecorder::begin_batch`/`end_batch` pair: the
//! kernel spans that fire while the chunk scores attribute to the batch,
//! and through it to **all** member trace IDs — the honest cost model of
//! batched serving. Memory is bounded: events live in fixed-capacity
//! seqlock rings (oldest overwritten on wrap) plus a slowest-N tail
//! reserve that survives wraparound; see `crate::obs::recorder` for the
//! ring layout, capacities, and the Chrome trace-event export schema
//! consumed by `hisolo trace`.
//!
//! ## SLO burn-rate accounting
//!
//! `Metrics::set_slo_target_us` arms a p99 error budget: a request
//! "violates" when its end-to-end latency exceeds the target, the budget
//! allows [`metrics::SLO_EPSILON`] (1%) violations, and `burn_rate =
//! violation_rate / SLO_EPSILON`. The reporter thread advances a rolling
//! window each tick (`Metrics::slo_tick`), so `slo_window_burn_rate`
//! forgets a bad spell once it ages past [`metrics::SLO_WINDOW_TICKS`]
//! ticks while the lifetime rate remembers it. Surfaced in the summary
//! line, the `slo` object of `Metrics::to_json`, and serve's
//! `slo_burn_check` output.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;
pub mod worker;

pub use batcher::{
    bucket_by_len, bucket_index, default_bucket_edges, BatchPoll, Batcher, BatcherConfig,
    BucketPoll,
};
pub use metrics::Metrics;
pub use request::{ScoreRequest, ScoreResponse, Variant};

pub use crate::obs::TraceId;
pub use server::{Coordinator, CoordinatorConfig, SwapTicket};
pub use worker::{BoxScorer, Scorer, ScorerFactory, SwapRequest};
