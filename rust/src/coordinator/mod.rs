//! L3 serving coordinator: dynamic batcher, worker threads per model
//! variant, round-robin routing, scorer hot-swap, and metrics.
//!
//! The paper's contribution lives at the compression layer, so the
//! coordinator is the serving shell around it (DESIGN.md §3): requests are
//! token windows to score; workers own either an AOT PJRT executable
//! (dense / sHSS graphs) or a native forward pass, batch up to the
//! executable's static batch size, and return per-window NLL.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod server;
pub mod worker;

pub use batcher::{BatchPoll, Batcher, BatcherConfig};
pub use metrics::Metrics;
pub use request::{ScoreRequest, ScoreResponse, Variant};
pub use server::{Coordinator, CoordinatorConfig, SwapTicket};
pub use worker::{BoxScorer, Scorer, ScorerFactory, SwapRequest};
