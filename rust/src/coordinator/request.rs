//! Request/response types for the scoring service.

use crate::obs::TraceId;
use std::sync::mpsc::Sender;
use std::time::Instant;

/// Which model variant serves the request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// uncompressed AOT graph / dense native fwd
    Dense,
    /// sHSS-RCM compressed graph / native compressed fwd
    Hss,
}

impl Variant {
    /// Number of variants — sizes per-variant metric arrays.
    pub const COUNT: usize = 2;

    pub fn name(&self) -> &'static str {
        match self {
            Variant::Dense => "dense",
            Variant::Hss => "hss",
        }
    }

    /// Dense index into per-variant metric arrays (`0..Variant::COUNT`).
    pub fn index(&self) -> usize {
        match self {
            Variant::Dense => 0,
            Variant::Hss => 1,
        }
    }
}

impl std::str::FromStr for Variant {
    type Err = String;

    fn from_str(s: &str) -> Result<Variant, String> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Ok(Variant::Dense),
            "hss" | "shss" | "shss-rcm" => Ok(Variant::Hss),
            o => Err(format!("unknown variant '{o}' (dense|hss)")),
        }
    }
}

/// What the worker should do with a request's window — stateless rescore
/// or one hop of a paged-KV session (see `coordinator` module docs for
/// the prefill → decode lifecycle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    /// full-window rescore (stateless; the pre-decode path)
    Score,
    /// open a session: cache the window's K/V, score its internal targets
    Prefill { session: u64 },
    /// append the window's tokens to a cached session, one O(t) step each
    Decode { session: u64 },
}

impl RequestKind {
    /// Coalescing class: requests of different kinds never share a
    /// bucket (`Batcher::poll_buckets_keyed`), so decode steps are not
    /// padded against full prefill windows.
    pub fn class(&self) -> usize {
        match self {
            RequestKind::Score => 0,
            RequestKind::Prefill { .. } => 1,
            RequestKind::Decode { .. } => 2,
        }
    }

    pub fn session(&self) -> Option<u64> {
        match self {
            RequestKind::Score => None,
            RequestKind::Prefill { session } | RequestKind::Decode { session } => Some(*session),
        }
    }
}

/// A scoring request: one token window; the response reports its NLL.
pub struct ScoreRequest {
    pub id: u64,
    /// End-to-end trace id, minted at submission and propagated through
    /// batcher → bucket → worker → reply (see `obs::recorder`).
    pub trace: TraceId,
    pub variant: Variant,
    /// how to score `window` (rescore / session prefill / session decode)
    pub kind: RequestKind,
    /// window of seq_len + 1 tokens (inputs + targets); for `Decode`,
    /// just the tokens to append
    pub window: Vec<u32>,
    pub submitted: Instant,
    pub reply: Sender<ScoreResponse>,
}

/// The scored result.
#[derive(Clone, Debug)]
pub struct ScoreResponse {
    pub id: u64,
    /// The request's trace id, echoed back so callers can correlate the
    /// reply with flight-recorder timelines and exported traces.
    pub trace: TraceId,
    pub variant: Variant,
    /// total NLL over the window (nats) and token count
    pub nll: f64,
    pub tokens: usize,
    /// end-to-end latency (queue + batch wait + execute)
    pub latency_us: u64,
    /// submit→dequeue share of `latency_us` — the worker stamps one
    /// dequeue instant per polled batch, so `latency_us - queue_us` is
    /// this request's service time and the two halves sum exactly
    pub queue_us: u64,
    /// how many requests shared the executed batch
    pub batch_size: usize,
    pub error: Option<String>,
}

impl ScoreResponse {
    pub fn ppl(&self) -> f64 {
        (self.nll / self.tokens.max(1) as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse() {
        assert_eq!("dense".parse::<Variant>().unwrap(), Variant::Dense);
        assert_eq!("sHSS-RCM".parse::<Variant>().unwrap(), Variant::Hss);
        assert!("x".parse::<Variant>().is_err());
    }

    #[test]
    fn response_ppl() {
        let r = ScoreResponse {
            id: 0,
            trace: TraceId(1),
            variant: Variant::Dense,
            nll: 2.0 * 10.0_f64.ln(),
            tokens: 2,
            latency_us: 1,
            queue_us: 0,
            batch_size: 1,
            error: None,
        };
        assert!((r.ppl() - 10.0).abs() < 1e-9);
    }
}
