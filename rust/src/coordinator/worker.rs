//! Worker: pulls batches for one model variant, scores them, replies.
//!
//! Workers are generic over [`Scorer`] so the same loop drives an AOT PJRT
//! executable, the native forward pass, or a test mock. Each worker owns a
//! swap mailbox: `Coordinator::swap_variant` sends a [`SwapRequest`] whose
//! factory runs *on the worker thread* (PJRT clients are `!Send`), and the
//! worker installs the replacement scorer between batches — every request
//! is served entirely by one scorer, before or after the swap, never torn
//! across it.

use crate::coordinator::batcher::{BatchPoll, Batcher, BucketPoll};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{ScoreRequest, ScoreResponse, Variant};
use crate::eval::perplexity::window_nll;
use crate::linalg::Matrix;
use crate::model::kvcache::{KvState, KvStatsSnapshot};
use crate::obs::recorder::{self, RequestEvent};
use crate::obs::{Span, Stage};
use crate::util::logging::{log, Level};
use std::cell::RefCell;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How long an idle worker waits on the queue before checking its swap
/// mailbox — the upper bound on swap latency under zero traffic.
const IDLE_POLL: Duration = Duration::from_millis(25);

/// Anything that can score a batch of token windows into per-window logits.
/// Not `Send`: PJRT-backed scorers are constructed on their worker thread
/// (see `Coordinator::add_worker_factory`).
pub trait Scorer {
    /// Max windows per call (static batch for AOT executables).
    fn max_batch(&self) -> usize;
    /// Input window length the scorer expects (tokens fed = seq_len).
    fn seq_len(&self) -> usize;
    /// logits [t, vocab] per window; `windows` carry seq_len + 1 tokens and
    /// the scorer sees the first seq_len.
    fn score(&self, inputs: &[Vec<u32>]) -> anyhow::Result<Vec<Matrix>>;
    /// Bytes resident for the variant-specific weights this scorer holds
    /// (0 when unknown, e.g. device-resident AOT executables). Workers
    /// report it per variant via `Metrics::set_resident_weight_bytes` and
    /// log it on every hot-swap, so the f16-resident halving is observable
    /// in serving logs.
    fn resident_weight_bytes(&self) -> u64 {
        0
    }
    /// Open (or replace) paged-KV sessions: one `(session, window)` pair
    /// per request; the window's K/V is cached and its internal targets
    /// scored. Per-request failures (bad length, page-pool exhaustion)
    /// come back as the inner `Err` so one bad request doesn't poison
    /// its batch; the outer `Err` means the scorer has no KV cache at
    /// all. Default: no paged-KV support.
    fn prefill(
        &self,
        _reqs: &[(u64, Vec<u32>)],
    ) -> anyhow::Result<Vec<Result<(f64, usize), String>>> {
        anyhow::bail!("scorer does not support paged-KV sessions")
    }
    /// Append each request's tokens to its cached session, one O(t)
    /// decode step per token. Same result shape and error split as
    /// [`Scorer::prefill`]; an unknown or evicted session is a
    /// per-request `Err`.
    fn decode(
        &self,
        _reqs: &[(u64, Vec<u32>)],
    ) -> anyhow::Result<Vec<Result<(f64, usize), String>>> {
        anyhow::bail!("scorer does not support paged-KV sessions")
    }
    /// Paged-KV cache counters, when this scorer holds a cache. Workers
    /// publish the snapshot to `Metrics` after every session batch.
    fn kv_stats(&self) -> Option<KvStatsSnapshot> {
        None
    }
}

/// A worker-owned scorer behind dynamic dispatch (hot-swap replaces it).
pub type BoxScorer = Box<dyn Scorer>;

/// Builds a replacement scorer on the worker's own thread.
pub type ScorerFactory = Box<dyn FnOnce() -> anyhow::Result<BoxScorer> + Send>;

/// One pending hot-swap: the factory to run and an ack channel. On factory
/// failure the worker keeps its current scorer and reports the error — a
/// bad swap never takes a lane down.
pub struct SwapRequest {
    pub factory: ScorerFactory,
    pub ack: Sender<Result<(), String>>,
}

/// Lifecycle split for one reply: `queue` is submit→dequeue, `service` is
/// dequeue→now, and `latency` is exactly their sum. Every reply path
/// (success, score error, init-failed drain) reports the same split —
/// returns `(queue_us, service_us, latency_us)`.
pub(crate) fn lifecycle_us(submitted: Instant, dequeued: Instant) -> (u64, u64, u64) {
    let queue_us = dequeued.saturating_duration_since(submitted).as_micros() as u64;
    let service_us = dequeued.elapsed().as_micros() as u64;
    (queue_us, service_us, queue_us + service_us)
}

/// Run the worker loop until the batcher closes (no hot-swap mailbox).
pub fn run_worker<S: Scorer + 'static>(
    variant: Variant,
    scorer: S,
    batcher: Arc<Batcher<ScoreRequest>>,
    metrics: Arc<Metrics>,
) {
    let (_tx, rx) = std::sync::mpsc::channel();
    run_worker_swappable(variant, Box::new(scorer), batcher, metrics, rx);
}

/// Worker loop with a hot-swap mailbox: pending swaps apply between
/// batches, so in-flight requests always complete on the scorer that
/// dequeued them. The resident weight bytes of the installed scorer are
/// published to the per-variant gauge at start and on every swap.
pub fn run_worker_swappable(
    variant: Variant,
    mut scorer: BoxScorer,
    batcher: Arc<Batcher<ScoreRequest>>,
    metrics: Arc<Metrics>,
    swaps: Receiver<SwapRequest>,
) {
    metrics.set_resident_weight_bytes(variant, scorer.resident_weight_bytes());
    loop {
        while let Ok(req) = swaps.try_recv() {
            // the span covers factory + install: the full time this lane
            // is busy with the swap instead of scoring
            let _swap_span = Span::enter(Stage::SwapInstall);
            match (req.factory)() {
                Ok(next) => {
                    scorer = next;
                    metrics.swaps.fetch_add(1, Ordering::Relaxed);
                    let resident = scorer.resident_weight_bytes();
                    metrics.set_resident_weight_bytes(variant, resident);
                    log(
                        Level::Info,
                        format_args!(
                            "swap[{}]: installed scorer, resident weight bytes = {resident}",
                            variant.name()
                        ),
                    );
                    let _ = req.ack.send(Ok(()));
                }
                Err(e) => {
                    let _ = req.ack.send(Err(format!("{e:#}")));
                }
            }
        }
        // class+length-bucketed poll: the batch comes back coalesced
        // into near-uniform-length buckets that never mix request kinds
        // (score / prefill / decode), so every forward_batch call is a
        // dense near-rectangular block and decode steps are never padded
        // against full windows; replies still route per request
        let buckets = match batcher
            .poll_buckets_keyed(IDLE_POLL, |r: &ScoreRequest| (r.kind.class(), r.window.len()))
        {
            BucketPoll::Closed => return,
            BucketPoll::Idle => continue,
            BucketPoll::Buckets(b) => b,
        };
        // one dequeue instant for the whole poll: each request's
        // queue_wait (submit→here) and service (here→reply) halves sum
        // exactly to its end-to-end latency
        let dequeued = Instant::now();
        let size: usize = buckets.iter().map(|b| b.len()).sum();
        metrics.record_batch(size);
        metrics.in_flight.fetch_add(size as u64, Ordering::Relaxed);
        for bucket in &buckets {
            // chunk by the scorer's static batch
            for chunk in bucket.chunks(scorer.max_batch()) {
                // flight recorder: every kernel span fired on this thread
                // while the chunk scores (inside the scorer call and
                // `window_nll`) attributes to this batch, and thereby to
                // every member trace id
                let rec = recorder::recorder();
                let flight = rec.begin_batch();
                let mut completions: Vec<RequestEvent> = Vec::new();
                // buckets are class-homogeneous (poll key), so the first
                // request's kind decides the whole chunk's path
                let class = chunk[0].kind.class();
                // one outcome per request: `Ok((nll, tokens))` or an error
                // string — a whole-chunk scorer failure fans out to every
                // member so each still gets its own lifecycle-split reply
                let outcomes: Vec<Result<(f64, usize), String>> = match class {
                    0 => {
                        let inputs: Vec<Vec<u32>> = chunk
                            .iter()
                            .map(|r| r.window[..r.window.len() - 1].to_vec())
                            .collect();
                        match scorer.score(&inputs) {
                            Ok(logits) => {
                                // gauge only chunks that actually scored,
                                // so the width/padding numbers stay honest
                                // when a lane is erroring
                                let actual: u64 = inputs.iter().map(|w| w.len() as u64).sum();
                                let max_t =
                                    inputs.iter().map(|w| w.len()).max().unwrap_or(0) as u64;
                                metrics.record_bucket(
                                    chunk.len(),
                                    actual,
                                    max_t * chunk.len() as u64,
                                );
                                chunk
                                    .iter()
                                    .zip(&logits)
                                    .map(|(req, lg)| Ok(window_nll(lg, &req.window)))
                                    .collect()
                            }
                            Err(e) => {
                                let msg = format!("{e:#}");
                                chunk.iter().map(|_| Err(msg.clone())).collect()
                            }
                        }
                    }
                    _ => {
                        let reqs: Vec<(u64, Vec<u32>)> = chunk
                            .iter()
                            .map(|r| (r.kind.session().unwrap_or(0), r.window.clone()))
                            .collect();
                        let res = if class == 1 {
                            scorer.prefill(&reqs)
                        } else {
                            scorer.decode(&reqs)
                        };
                        match res {
                            Ok(per) => {
                                let actual: u64 =
                                    chunk.iter().map(|r| r.window.len() as u64).sum();
                                let max_t =
                                    chunk.iter().map(|r| r.window.len()).max().unwrap_or(0) as u64;
                                metrics.record_bucket(
                                    chunk.len(),
                                    actual,
                                    max_t * chunk.len() as u64,
                                );
                                per
                            }
                            Err(e) => {
                                let msg = format!("{e:#}");
                                chunk.iter().map(|_| Err(msg.clone())).collect()
                            }
                        }
                    }
                };
                for (req, outcome) in chunk.iter().zip(outcomes) {
                    let (queue_us, service_us, latency_us) =
                        lifecycle_us(req.submitted, dequeued);
                    metrics.in_flight.fetch_sub(1, Ordering::Relaxed);
                    let (nll, tokens, error) = match outcome {
                        Ok((nll, tokens)) => {
                            crate::obs::registry()
                                .record(Stage::QueueWait, Duration::from_micros(queue_us));
                            metrics.record_queue_wait_us(queue_us);
                            metrics.record_service_us(service_us);
                            metrics.record_latency_us(latency_us);
                            metrics.completed.fetch_add(1, Ordering::Relaxed);
                            (nll, tokens, None)
                        }
                        Err(msg) => {
                            metrics.errors.fetch_add(1, Ordering::Relaxed);
                            (f64::NAN, 0, Some(msg))
                        }
                    };
                    if flight.active() {
                        completions.push(RequestEvent {
                            trace: req.trace,
                            batch: 0, // stamped by end_batch
                            submit_us: rec.offset_us(req.submitted),
                            queue_us,
                            service_us,
                            window_len: req.window.len() as u32,
                            variant: req.variant.index() as u8,
                            error: error.is_some(),
                        });
                    }
                    let _route_span = Span::enter(Stage::ReplyRoute);
                    let _ = req.reply.send(ScoreResponse {
                        id: req.id,
                        trace: req.trace,
                        variant: req.variant,
                        nll,
                        tokens,
                        latency_us,
                        queue_us,
                        batch_size: size,
                        error,
                    });
                }
                if class != 0 {
                    if let Some(st) = scorer.kv_stats() {
                        metrics.set_kv_stats(&st);
                    }
                }
                rec.end_batch(flight, &completions);
            }
        }
    }
}

/// Degraded loop for a worker whose initial scorer failed to construct:
/// drains requests with errors so submitters never hang, but keeps
/// servicing the swap mailbox — a later successful
/// `Coordinator::swap_variant` repairs the lane in place instead of
/// leaving it permanently dead.
pub fn run_worker_init_failed(
    variant: Variant,
    init_err: String,
    batcher: Arc<Batcher<ScoreRequest>>,
    metrics: Arc<Metrics>,
    swaps: Receiver<SwapRequest>,
) {
    loop {
        while let Ok(req) = swaps.try_recv() {
            let swap_span = Span::enter(Stage::SwapInstall);
            match (req.factory)() {
                Ok(scorer) => {
                    metrics.swaps.fetch_add(1, Ordering::Relaxed);
                    let _ = req.ack.send(Ok(()));
                    drop(swap_span);
                    return run_worker_swappable(variant, scorer, batcher, metrics, swaps);
                }
                Err(e) => {
                    let _ = req.ack.send(Err(format!("{e:#}")));
                }
            }
        }
        match batcher.poll_batch(IDLE_POLL) {
            BatchPoll::Closed => return,
            BatchPoll::Idle => continue,
            BatchPoll::Batch(batch) => {
                let dequeued = Instant::now();
                for req in batch {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                    let (queue_us, _service_us, latency_us) =
                        lifecycle_us(req.submitted, dequeued);
                    let _ = req.reply.send(ScoreResponse {
                        id: req.id,
                        trace: req.trace,
                        variant: req.variant,
                        nll: f64::NAN,
                        tokens: 0,
                        latency_us,
                        queue_us,
                        batch_size: 0,
                        error: Some(format!("worker init failed: {init_err}")),
                    });
                }
            }
        }
    }
}

/// Native scorer around the dense transformer. A polled batch is scored
/// in one `forward_batch` call: every layer's projections and MLP run as
/// one tall matmul over all windows. With `kv` set the scorer also
/// serves paged-KV sessions (prefill + O(t) decode); the `RefCell` is
/// sound because a scorer lives on exactly one worker thread, so session
/// affinity falls out of the one-lane-per-variant topology. Note a
/// hot-swap replaces the whole scorer, cache included — sessions opened
/// before a swap error on their next decode.
pub struct NativeDenseScorer {
    pub model: Arc<crate::model::Transformer>,
    pub max_batch: usize,
    pub kv: Option<RefCell<KvState>>,
}

impl NativeDenseScorer {
    pub fn new(model: Arc<crate::model::Transformer>, max_batch: usize) -> NativeDenseScorer {
        NativeDenseScorer {
            model,
            max_batch,
            kv: None,
        }
    }

    /// Attach a paged-KV cache with `n_pages` pages (enables
    /// prefill/decode requests on this lane).
    pub fn with_kv_pages(mut self, n_pages: usize) -> NativeDenseScorer {
        self.kv = Some(RefCell::new(KvState::for_model(&self.model.cfg, n_pages)));
        self
    }
}

impl Scorer for NativeDenseScorer {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn seq_len(&self) -> usize {
        self.model.cfg.seq_len
    }

    fn score(&self, inputs: &[Vec<u32>]) -> anyhow::Result<Vec<Matrix>> {
        let refs: Vec<&[u32]> = inputs.iter().map(|w| w.as_slice()).collect();
        Ok(self.model.forward_batch(&refs))
    }

    fn resident_weight_bytes(&self) -> u64 {
        // the variant-specific weights are the q/k/v projections, dense f32
        self.model.cfg.qkv_params() as u64 * 4
    }

    fn prefill(
        &self,
        reqs: &[(u64, Vec<u32>)],
    ) -> anyhow::Result<Vec<Result<(f64, usize), String>>> {
        let Some(kv) = &self.kv else {
            anyhow::bail!("dense scorer has no KV cache (serve with --kv-pages)");
        };
        let proj = crate::model::transformer::DenseProjector {
            layers: &self.model.layers,
        };
        Ok(kv.borrow_mut().prefill_batch(&self.model, &proj, reqs))
    }

    fn decode(
        &self,
        reqs: &[(u64, Vec<u32>)],
    ) -> anyhow::Result<Vec<Result<(f64, usize), String>>> {
        let Some(kv) = &self.kv else {
            anyhow::bail!("dense scorer has no KV cache (serve with --kv-pages)");
        };
        let proj = crate::model::transformer::DenseProjector {
            layers: &self.model.layers,
        };
        Ok(kv.borrow_mut().decode(&self.model, &proj, reqs))
    }

    fn kv_stats(&self) -> Option<KvStatsSnapshot> {
        self.kv.as_ref().map(|kv| kv.borrow().stats())
    }
}

/// Native scorer around a compressed model. A polled batch is scored in
/// one `forward_batch` call, so each compressed projection traverses its
/// sparse-plus-low-rank structure **once per batch** instead of once per
/// request (or, pre-batching, once per token). Paged-KV sessions run the
/// same cache machinery as the dense lane with the compressed model as
/// the Q/K/V projector — cached K/V bits are whatever the compressed
/// projections produced, so decode stays bit-identical to compressed
/// rescoring.
pub struct NativeCompressedScorer {
    pub model: Arc<crate::model::CompressedModel>,
    pub max_batch: usize,
    pub kv: Option<RefCell<KvState>>,
}

impl NativeCompressedScorer {
    pub fn new(
        model: Arc<crate::model::CompressedModel>,
        max_batch: usize,
    ) -> NativeCompressedScorer {
        NativeCompressedScorer {
            model,
            max_batch,
            kv: None,
        }
    }

    /// Attach a paged-KV cache with `n_pages` pages.
    pub fn with_kv_pages(mut self, n_pages: usize) -> NativeCompressedScorer {
        self.kv = Some(RefCell::new(KvState::for_model(
            &self.model.base.cfg,
            n_pages,
        )));
        self
    }
}

impl Scorer for NativeCompressedScorer {
    fn max_batch(&self) -> usize {
        self.max_batch
    }

    fn seq_len(&self) -> usize {
        self.model.base.cfg.seq_len
    }

    fn score(&self, inputs: &[Vec<u32>]) -> anyhow::Result<Vec<Matrix>> {
        let refs: Vec<&[u32]> = inputs.iter().map(|w| w.as_slice()).collect();
        Ok(self.model.forward_batch(&refs))
    }

    fn resident_weight_bytes(&self) -> u64 {
        // compressed q/k/v factors at their resident dtype: a store-loaded
        // (f16-native) model reports half of what the same model widened
        // to f32 would
        self.model.resident_weight_bytes() as u64
    }

    fn prefill(
        &self,
        reqs: &[(u64, Vec<u32>)],
    ) -> anyhow::Result<Vec<Result<(f64, usize), String>>> {
        let Some(kv) = &self.kv else {
            anyhow::bail!("compressed scorer has no KV cache (serve with --kv-pages)");
        };
        Ok(kv
            .borrow_mut()
            .prefill_batch(&self.model.base, &*self.model, reqs))
    }

    fn decode(
        &self,
        reqs: &[(u64, Vec<u32>)],
    ) -> anyhow::Result<Vec<Result<(f64, usize), String>>> {
        let Some(kv) = &self.kv else {
            anyhow::bail!("compressed scorer has no KV cache (serve with --kv-pages)");
        };
        Ok(kv.borrow_mut().decode(&self.model.base, &*self.model, reqs))
    }

    fn kv_stats(&self) -> Option<KvStatsSnapshot> {
        self.kv.as_ref().map(|kv| kv.borrow().stats())
    }
}

/// PJRT-backed scorer (AOT executable with device-resident weights).
impl Scorer for crate::runtime::LoadedModel {
    fn max_batch(&self) -> usize {
        self.batch()
    }

    fn seq_len(&self) -> usize {
        crate::runtime::LoadedModel::seq_len(self)
    }

    fn score(&self, inputs: &[Vec<u32>]) -> anyhow::Result<Vec<Matrix>> {
        crate::runtime::LoadedModel::score(self, inputs)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::coordinator::request::{RequestKind, Variant};
    use std::sync::mpsc::channel;
    use std::time::{Duration, Instant};

    /// Mock scorer: logits put all mass on token (i+1) mod vocab — so NLL is
    /// tiny iff the window is the successor sequence.
    pub struct MockScorer {
        pub vocab: usize,
        pub seq: usize,
        pub batch: usize,
        pub fail: bool,
    }

    impl Scorer for MockScorer {
        fn max_batch(&self) -> usize {
            self.batch
        }

        fn seq_len(&self) -> usize {
            self.seq
        }

        fn score(&self, inputs: &[Vec<u32>]) -> anyhow::Result<Vec<Matrix>> {
            if self.fail {
                anyhow::bail!("mock failure");
            }
            Ok(inputs
                .iter()
                .map(|w| {
                    let mut m = Matrix::zeros(w.len(), self.vocab);
                    for (i, &t) in w.iter().enumerate() {
                        m.set(i, ((t + 1) as usize) % self.vocab, 30.0);
                    }
                    m
                })
                .collect())
        }
    }

    fn mk_req_kind(
        id: u64,
        kind: RequestKind,
        window: Vec<u32>,
    ) -> (ScoreRequest, std::sync::mpsc::Receiver<ScoreResponse>) {
        let (tx, rx) = channel();
        (
            ScoreRequest {
                id,
                // deterministic per-test trace so replies can assert the echo
                trace: crate::obs::TraceId(id + 1000),
                variant: Variant::Dense,
                kind,
                window,
                submitted: Instant::now(),
                reply: tx,
            },
            rx,
        )
    }

    fn mk_req(id: u64, window: Vec<u32>) -> (ScoreRequest, std::sync::mpsc::Receiver<ScoreResponse>) {
        mk_req_kind(id, RequestKind::Score, window)
    }

    #[test]
    fn worker_scores_and_replies() {
        let batcher = Arc::new(Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            capacity: 64,
            ..BatcherConfig::default()
        }));
        let metrics = Arc::new(Metrics::new());
        // successor window => near-zero NLL under the mock
        let w: Vec<u32> = (0..9).collect();
        let (req, rx) = mk_req(7, w);
        assert!(batcher.push(req).is_ok());
        let b2 = batcher.clone();
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || {
            run_worker(
                Variant::Dense,
                MockScorer {
                    vocab: 16,
                    seq: 8,
                    batch: 4,
                    fail: false,
                },
                b2,
                m2,
            )
        });
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.trace, crate::obs::TraceId(1007), "trace echoed on reply");
        assert!(resp.error.is_none());
        assert!(resp.nll < 1e-3, "nll {}", resp.nll);
        assert_eq!(resp.tokens, 8);
        // lifecycle split: queue share never exceeds the whole
        assert!(resp.queue_us <= resp.latency_us, "{resp:?}");
        batcher.close();
        h.join().unwrap();
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 1);
        // queue + service means decompose the mean latency exactly
        let sum = metrics.mean_queue_wait_us() + metrics.mean_service_us();
        assert!((sum - metrics.mean_latency_us()).abs() < 1e-9);
        assert_eq!(metrics.in_flight.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn worker_reports_errors() {
        let batcher = Arc::new(Batcher::new(BatcherConfig::default()));
        let metrics = Arc::new(Metrics::new());
        let (req, rx) = mk_req(1, (0..9).collect());
        assert!(batcher.push(req).is_ok());
        let b2 = batcher.clone();
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || {
            run_worker(
                Variant::Dense,
                MockScorer {
                    vocab: 16,
                    seq: 8,
                    batch: 4,
                    fail: true,
                },
                b2,
                m2,
            )
        });
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.error.is_some());
        // satellite: error replies carry the same lifecycle split as
        // successes — queue + service sums to latency, never hardcoded 0
        assert_eq!(resp.trace, crate::obs::TraceId(1001));
        assert!(resp.queue_us <= resp.latency_us, "{resp:?}");
        batcher.close();
        h.join().unwrap();
        assert_eq!(metrics.errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn lifecycle_helper_sums_exactly_on_every_path() {
        let submitted = Instant::now() - Duration::from_millis(5);
        let dequeued = Instant::now();
        let (q, s, l) = lifecycle_us(submitted, dequeued);
        assert_eq!(q + s, l);
        assert!(q >= 4_000, "queue {q}us should reflect the 5ms wait");
        // submit after dequeue (clock skew shape): queue clamps to 0
        let (q2, s2, l2) = lifecycle_us(Instant::now() + Duration::from_millis(5), dequeued);
        assert_eq!(q2, 0);
        assert_eq!(q2 + s2, l2);
    }

    #[test]
    fn swap_applies_between_batches_and_bad_swap_keeps_old_scorer() {
        let batcher = Arc::new(Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            capacity: 64,
            ..BatcherConfig::default()
        }));
        let metrics = Arc::new(Metrics::new());
        let (swap_tx, swap_rx) = channel();
        let b2 = batcher.clone();
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || {
            run_worker_swappable(
                Variant::Dense,
                Box::new(MockScorer {
                    vocab: 16,
                    seq: 8,
                    batch: 4,
                    fail: true, // initial scorer always errors
                }),
                b2,
                m2,
                swap_rx,
            )
        });

        // before the swap: errors
        let (req, rx) = mk_req(0, (0..9).collect());
        batcher.push(req).unwrap();
        assert!(rx
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .error
            .is_some());

        // a failing factory is acked as an error and changes nothing
        let (ack_tx, ack_rx) = channel();
        swap_tx
            .send(SwapRequest {
                factory: Box::new(|| anyhow::bail!("no artifacts")),
                ack: ack_tx,
            })
            .unwrap();
        let ack = ack_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(ack.unwrap_err().contains("no artifacts"));

        // swap in a healthy scorer
        let (ack_tx, ack_rx) = channel();
        swap_tx
            .send(SwapRequest {
                factory: Box::new(|| {
                    Ok(Box::new(MockScorer {
                        vocab: 16,
                        seq: 8,
                        batch: 4,
                        fail: false,
                    }) as BoxScorer)
                }),
                ack: ack_tx,
            })
            .unwrap();
        ack_rx
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .unwrap();

        // after the swap: success
        let (req, rx) = mk_req(1, (0..9).collect());
        batcher.push(req).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.error.is_none());
        assert!(resp.nll < 1e-3);
        assert_eq!(metrics.swaps.load(Ordering::Relaxed), 1);

        batcher.close();
        h.join().unwrap();
    }

    #[test]
    fn oversized_batches_chunked_to_scorer_limit() {
        let batcher = Arc::new(Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            capacity: 64,
            ..BatcherConfig::default()
        }));
        let metrics = Arc::new(Metrics::new());
        let mut rxs = Vec::new();
        for i in 0..8 {
            let (req, rx) = mk_req(i, (0..9).collect());
            assert!(batcher.push(req).is_ok());
            rxs.push(rx);
        }
        let b2 = batcher.clone();
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || {
            run_worker(
                Variant::Dense,
                MockScorer {
                    vocab: 16,
                    seq: 8,
                    batch: 2, // scorer narrower than the batcher
                    fail: false,
                },
                b2,
                m2,
            )
        });
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(resp.error.is_none());
        }
        batcher.close();
        h.join().unwrap();
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 8);
    }

    fn tiny_kv_scorer() -> NativeDenseScorer {
        let cfg = crate::model::ModelConfig {
            vocab: 64,
            d_model: 32,
            n_heads: 4,
            n_layers: 2,
            d_ff: 64,
            seq_len: 48,
        };
        NativeDenseScorer::new(Arc::new(crate::model::Transformer::random(cfg, 7)), 4)
            .with_kv_pages(32)
    }

    /// Satellite: the `Decode` error arm for an unknown/evicted session
    /// reports the same exact lifecycle split as successes — the reply's
    /// `queue_us` reflects the real submit→dequeue wait (never a
    /// hardcoded zero) and queue + service sum to `latency_us`.
    #[test]
    fn decode_unknown_session_error_keeps_lifecycle_split() {
        let batcher = Arc::new(Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            capacity: 64,
            ..BatcherConfig::default()
        }));
        let metrics = Arc::new(Metrics::new());
        // pre-date the submit instant so a dropped queue share would be
        // visible: the reply must carry ≥ ~5ms of queue time
        let (mut req, rx) = mk_req_kind(3, RequestKind::Decode { session: 999 }, vec![1, 2]);
        req.submitted = Instant::now() - Duration::from_millis(5);
        batcher.push(req).unwrap();
        let b2 = batcher.clone();
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || run_worker(Variant::Dense, tiny_kv_scorer(), b2, m2));
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let err = resp.error.clone().expect("unknown session must error");
        assert!(err.contains("999"), "error should name the session: {err}");
        assert!(
            resp.queue_us >= 4_000,
            "error reply must keep the queue share: {resp:?}"
        );
        assert!(resp.queue_us <= resp.latency_us, "{resp:?}");
        assert!(resp.nll.is_nan() && resp.tokens == 0);
        batcher.close();
        h.join().unwrap();
        assert_eq!(metrics.errors.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.in_flight.load(Ordering::Relaxed), 0);
    }

    /// Prefill + decode round-trip through the worker loop: session
    /// requests dispatch by class, both hops succeed, and the KV gauges
    /// are published to `Metrics` after the batch.
    #[test]
    fn worker_serves_prefill_then_decode_and_publishes_kv_gauges() {
        let batcher = Arc::new(Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            capacity: 64,
            ..BatcherConfig::default()
        }));
        let metrics = Arc::new(Metrics::new());
        let b2 = batcher.clone();
        let m2 = metrics.clone();
        let h = std::thread::spawn(move || run_worker(Variant::Dense, tiny_kv_scorer(), b2, m2));

        let prompt: Vec<u32> = (1..=20).collect();
        let (req, rx) = mk_req_kind(1, RequestKind::Prefill { session: 5 }, prompt);
        batcher.push(req).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens, 19, "prefill scores the window's targets");
        assert!(resp.nll.is_finite());
        assert!(resp.queue_us <= resp.latency_us);

        let (req, rx) = mk_req_kind(2, RequestKind::Decode { session: 5 }, vec![33]);
        batcher.push(req).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.tokens, 1, "one decode step per appended token");
        assert!(resp.nll.is_finite());

        batcher.close();
        h.join().unwrap();
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 2);
        assert!(
            metrics.kv_pages_resident.load(Ordering::Relaxed) > 0,
            "worker must publish KV occupancy after session batches"
        );
        assert!(metrics.kv_misses.load(Ordering::Relaxed) > 0);
    }
}
