//! Lock-free serving metrics: request counters, a log-bucketed latency
//! histogram with percentile queries, and a per-variant gauge of the
//! resident weight bytes the installed scorers hold (the f16-serving
//! halving shows up here, not just in benches).

use crate::coordinator::request::Variant;
use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 40; // log2 buckets over 1us .. ~1099s

/// Atomic metrics registry (one per coordinator).
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// scorer hot-swaps applied by workers (see `Coordinator::swap_variant`)
    pub swaps: AtomicU64,
    /// per-variant gauge: weight bytes resident in the most recently
    /// installed scorer (set at worker start and on every hot-swap)
    resident_weight_bytes: [AtomicU64; Variant::COUNT],
    latency_buckets: [AtomicU64; BUCKETS],
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            resident_weight_bytes: std::array::from_fn(|_| AtomicU64::new(0)),
            latency_buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record the resident weight bytes of the scorer now serving
    /// `variant` (workers call this at start and after each hot-swap).
    pub fn set_resident_weight_bytes(&self, variant: Variant, bytes: u64) {
        self.resident_weight_bytes[variant.index()].store(bytes, Ordering::Relaxed);
    }

    /// Resident weight bytes of the scorer currently serving `variant`
    /// (0 until a worker reports in).
    pub fn resident_weight_bytes(&self, variant: Variant) -> u64 {
        self.resident_weight_bytes[variant.index()].load(Ordering::Relaxed)
    }

    pub fn record_latency_us(&self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.latency_buckets[b].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Approximate latency percentile (upper bucket bound), p in [0,1].
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let counts: Vec<u64> = self
            .latency_buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let want = ((p * total as f64).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= want {
                return 1u64 << (i + 1); // upper bound of bucket i
            }
        }
        1u64 << BUCKETS
    }

    /// Mean requests per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} errors={} swaps={} batches={} mean_batch={:.2} p50={}us p95={}us p99={}us resident_bytes[dense]={} resident_bytes[hss]={}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.swaps.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.latency_percentile_us(0.5),
            self.latency_percentile_us(0.95),
            self.latency_percentile_us(0.99),
            self.resident_weight_bytes(Variant::Dense),
            self.resident_weight_bytes(Variant::Hss),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_monotone() {
        let m = Metrics::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..100 {
                m.record_latency_us(us);
            }
        }
        let p50 = m.latency_percentile_us(0.5);
        let p95 = m.latency_percentile_us(0.95);
        let p99 = m.latency_percentile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p50 >= 1000 && p50 <= 2048, "{p50}");
    }

    #[test]
    fn empty_percentile_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(0.99), 0);
    }

    #[test]
    fn batch_stats() {
        let m = Metrics::new();
        m.record_batch(8);
        m.record_batch(4);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_counters() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("submitted=3"));
    }

    #[test]
    fn resident_bytes_gauge_per_variant() {
        let m = Metrics::new();
        assert_eq!(m.resident_weight_bytes(Variant::Hss), 0);
        m.set_resident_weight_bytes(Variant::Hss, 4096);
        m.set_resident_weight_bytes(Variant::Dense, 8192);
        assert_eq!(m.resident_weight_bytes(Variant::Hss), 4096);
        assert_eq!(m.resident_weight_bytes(Variant::Dense), 8192);
        // gauge semantics: a swap overwrites, never accumulates
        m.set_resident_weight_bytes(Variant::Hss, 2048);
        assert_eq!(m.resident_weight_bytes(Variant::Hss), 2048);
        assert!(m.summary().contains("resident_bytes[hss]=2048"));
    }
}
