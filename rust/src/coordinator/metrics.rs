//! Lock-free serving metrics: request counters, a log-bucketed latency
//! histogram with percentile queries, and a per-variant gauge of the
//! resident weight bytes the installed scorers hold (the f16-serving
//! halving shows up here, not just in benches).

use crate::coordinator::request::Variant;
use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 40; // log2 buckets over 1us .. ~1099s

/// Atomic metrics registry (one per coordinator).
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// length-homogeneous buckets scored (each poll splits into ≥ 1)
    pub bucket_batches: AtomicU64,
    /// requests across all scored buckets (mean = batch-width gauge)
    pub bucket_requests: AtomicU64,
    /// tokens actually scored across all chunks
    pub batch_tokens_actual: AtomicU64,
    /// tokens of the rectangular [width × max_len] shape each scored chunk
    /// pads to on a fixed-shape backend — the padding-overhead gauge's
    /// denominator
    pub batch_tokens_padded: AtomicU64,
    /// scorer hot-swaps applied by workers (see `Coordinator::swap_variant`)
    pub swaps: AtomicU64,
    /// per-variant gauge: weight bytes resident in the most recently
    /// installed scorer (set at worker start and on every hot-swap)
    resident_weight_bytes: [AtomicU64; Variant::COUNT],
    latency_buckets: [AtomicU64; BUCKETS],
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            bucket_batches: AtomicU64::new(0),
            bucket_requests: AtomicU64::new(0),
            batch_tokens_actual: AtomicU64::new(0),
            batch_tokens_padded: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            resident_weight_bytes: std::array::from_fn(|_| AtomicU64::new(0)),
            latency_buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record the resident weight bytes of the scorer now serving
    /// `variant` (workers call this at start and after each hot-swap).
    pub fn set_resident_weight_bytes(&self, variant: Variant, bytes: u64) {
        self.resident_weight_bytes[variant.index()].store(bytes, Ordering::Relaxed);
    }

    /// Resident weight bytes of the scorer currently serving `variant`
    /// (0 until a worker reports in).
    pub fn resident_weight_bytes(&self, variant: Variant) -> u64 {
        self.resident_weight_bytes[variant.index()].load(Ordering::Relaxed)
    }

    pub fn record_latency_us(&self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.latency_buckets[b].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Approximate latency percentile (upper bucket bound), p in [0,1].
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let counts: Vec<u64> = self
            .latency_buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let want = ((p * total as f64).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (i, c) in counts.iter().enumerate() {
            acc += c;
            if acc >= want {
                return 1u64 << (i + 1); // upper bound of bucket i
            }
        }
        1u64 << BUCKETS
    }

    /// Mean requests per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Record one scored length-bucket chunk: its width, the tokens it
    /// actually scored, and the tokens its padded rectangular shape would
    /// hold (`width × max window length`).
    pub fn record_bucket(&self, width: usize, actual_tokens: u64, padded_tokens: u64) {
        self.bucket_batches.fetch_add(1, Ordering::Relaxed);
        self.bucket_requests.fetch_add(width as u64, Ordering::Relaxed);
        self.batch_tokens_actual
            .fetch_add(actual_tokens, Ordering::Relaxed);
        self.batch_tokens_padded
            .fetch_add(padded_tokens, Ordering::Relaxed);
    }

    /// Mean requests per scored length-bucket (the batch-width gauge the
    /// coalescer is trying to keep high).
    pub fn mean_bucket_width(&self) -> f64 {
        let b = self.bucket_batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.bucket_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Fraction of the padded batch shape that is padding, in [0, 1):
    /// `1 − actual / padded`. 0 when every chunk was length-uniform (or
    /// nothing was scored yet); high values mean the bucket edges are too
    /// coarse for the traffic's length mix.
    pub fn padding_overhead(&self) -> f64 {
        let padded = self.batch_tokens_padded.load(Ordering::Relaxed);
        if padded == 0 {
            0.0
        } else {
            1.0 - self.batch_tokens_actual.load(Ordering::Relaxed) as f64 / padded as f64
        }
    }

    /// One-line summary: counters, batch/bucket widths, latency
    /// percentiles, then resident bytes **and** padding overhead together
    /// — the sweep CSV and the coordinator log tell the same memory/shape
    /// story from the same line.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} errors={} swaps={} batches={} mean_batch={:.2} bucket_width={:.2} p50={}us p95={}us p99={}us resident_bytes[dense]={} resident_bytes[hss]={} pad_overhead={:.1}%",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.swaps.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_bucket_width(),
            self.latency_percentile_us(0.5),
            self.latency_percentile_us(0.95),
            self.latency_percentile_us(0.99),
            self.resident_weight_bytes(Variant::Dense),
            self.resident_weight_bytes(Variant::Hss),
            100.0 * self.padding_overhead(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_monotone() {
        let m = Metrics::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..100 {
                m.record_latency_us(us);
            }
        }
        let p50 = m.latency_percentile_us(0.5);
        let p95 = m.latency_percentile_us(0.95);
        let p99 = m.latency_percentile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p50 >= 1000 && p50 <= 2048, "{p50}");
    }

    #[test]
    fn empty_percentile_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(0.99), 0);
    }

    #[test]
    fn batch_stats() {
        let m = Metrics::new();
        m.record_batch(8);
        m.record_batch(4);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_counters() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("submitted=3"));
    }

    #[test]
    fn bucket_and_padding_gauges() {
        let m = Metrics::new();
        assert_eq!(m.mean_bucket_width(), 0.0);
        assert_eq!(m.padding_overhead(), 0.0);
        // a uniform chunk pads nothing; a ragged one pads to its max
        m.record_bucket(4, 32, 32); // 4 windows × 8 tokens, uniform
        m.record_bucket(2, 12, 16); // lengths 4 + 8 padded to 2 × 8
        assert!((m.mean_bucket_width() - 3.0).abs() < 1e-12);
        let want = 1.0 - 44.0 / 48.0;
        assert!((m.padding_overhead() - want).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("bucket_width=3.00"), "{s}");
        assert!(s.contains("pad_overhead=8.3%"), "{s}");
        // resident bytes and padding overhead share the summary line
        assert!(s.contains("resident_bytes[hss]=0"), "{s}");
    }

    #[test]
    fn resident_bytes_gauge_per_variant() {
        let m = Metrics::new();
        assert_eq!(m.resident_weight_bytes(Variant::Hss), 0);
        m.set_resident_weight_bytes(Variant::Hss, 4096);
        m.set_resident_weight_bytes(Variant::Dense, 8192);
        assert_eq!(m.resident_weight_bytes(Variant::Hss), 4096);
        assert_eq!(m.resident_weight_bytes(Variant::Dense), 8192);
        // gauge semantics: a swap overwrites, never accumulates
        m.set_resident_weight_bytes(Variant::Hss, 2048);
        assert_eq!(m.resident_weight_bytes(Variant::Hss), 2048);
        assert!(m.summary().contains("resident_bytes[hss]=2048"));
    }
}
