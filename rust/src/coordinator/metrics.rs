//! Lock-free serving metrics: request counters, log-bucketed latency
//! histograms with percentile queries (end-to-end, queue-wait, and
//! service — the worker stamps them so queue + service sums exactly to
//! end-to-end per request), queue-depth / in-flight gauges, a per-variant
//! gauge of resident weight bytes, and a structured [`Metrics::to_json`]
//! snapshot that folds in the per-stage span registry
//! ([`crate::obs::registry`]).

use crate::coordinator::request::Variant;
use crate::obs::histogram::LogHistogram;
use crate::util::json::{num, obj, Json};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// SLO error-budget fraction for a p99 target: 1% of requests may exceed
/// the target before the budget is spent. `burn_rate = violation_rate /
/// SLO_EPSILON`, so burn 1.0 means "exactly on budget", above 1.0 means
/// the budget is burning faster than it accrues.
pub const SLO_EPSILON: f64 = 0.01;

/// Reporter ticks retained by the rolling SLO window (window burn rate
/// covers the last `SLO_WINDOW_TICKS × reporter interval` of traffic).
pub const SLO_WINDOW_TICKS: usize = 60;

/// Atomic metrics registry (one per coordinator).
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    pub batches: AtomicU64,
    pub batched_requests: AtomicU64,
    /// length-homogeneous buckets scored (each poll splits into ≥ 1)
    pub bucket_batches: AtomicU64,
    /// requests across all scored buckets (mean = batch-width gauge)
    pub bucket_requests: AtomicU64,
    /// tokens actually scored across all chunks
    pub batch_tokens_actual: AtomicU64,
    /// tokens of the rectangular [width × max_len] shape each scored chunk
    /// pads to on a fixed-shape backend — the padding-overhead gauge's
    /// denominator
    pub batch_tokens_padded: AtomicU64,
    /// scorer hot-swaps applied by workers (see `Coordinator::swap_variant`)
    pub swaps: AtomicU64,
    /// requests dequeued but not yet replied to (gauge; workers inc/dec)
    pub in_flight: AtomicU64,
    /// paged-KV cache gauges: the latest snapshot a session-serving
    /// worker published after a prefill/decode batch
    /// ([`Metrics::set_kv_stats`]). Gauge semantics — each publish
    /// overwrites; the scorer's own cache counters are the source of
    /// truth, these are their serving-surface mirror.
    pub kv_hits: AtomicU64,
    pub kv_misses: AtomicU64,
    pub kv_evictions: AtomicU64,
    pub kv_pages_resident: AtomicU64,
    pub kv_pages_total: AtomicU64,
    pub kv_sessions: AtomicU64,
    /// per-variant gauge: weight bytes resident in the most recently
    /// installed scorer (set at worker start and on every hot-swap)
    resident_weight_bytes: [AtomicU64; Variant::COUNT],
    /// per-variant gauge: queued (not yet dequeued) requests, sampled by
    /// the reporter thread / shutdown path via
    /// `Coordinator::sample_queue_depths`
    queue_depth: [AtomicU64; Variant::COUNT],
    /// end-to-end submit→reply latency of completed requests
    latency: LogHistogram,
    latency_total_us: AtomicU64,
    /// submit→dequeue wait of completed requests
    queue_wait: LogHistogram,
    queue_wait_total_us: AtomicU64,
    /// dequeue→reply service time of completed requests
    service: LogHistogram,
    service_total_us: AtomicU64,
    /// construction instant — `uptime_secs` in snapshots, so consumers of
    /// `--metrics-json` can turn counter deltas into rates
    started: Instant,
    /// snapshots taken so far; `to_json` stamps `snapshot_seq` from it so
    /// successive snapshots are strictly ordered even within one second
    snapshot_seq: AtomicU64,
    /// SLO p99 latency target in µs (0 = SLO accounting off)
    slo_target_us: AtomicU64,
    /// completed requests counted against the SLO since the target was set
    slo_total: AtomicU64,
    /// of those, requests whose end-to-end latency exceeded the target
    slo_bad: AtomicU64,
    /// rolling window of cumulative `(total, bad)` pairs, one per reporter
    /// tick (bounded at [`SLO_WINDOW_TICKS`]); the window burn rate is
    /// computed against the oldest retained tick
    slo_window: Mutex<VecDeque<(u64, u64)>>,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            bucket_batches: AtomicU64::new(0),
            bucket_requests: AtomicU64::new(0),
            batch_tokens_actual: AtomicU64::new(0),
            batch_tokens_padded: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
            in_flight: AtomicU64::new(0),
            kv_hits: AtomicU64::new(0),
            kv_misses: AtomicU64::new(0),
            kv_evictions: AtomicU64::new(0),
            kv_pages_resident: AtomicU64::new(0),
            kv_pages_total: AtomicU64::new(0),
            kv_sessions: AtomicU64::new(0),
            resident_weight_bytes: std::array::from_fn(|_| AtomicU64::new(0)),
            queue_depth: std::array::from_fn(|_| AtomicU64::new(0)),
            latency: LogHistogram::new(),
            latency_total_us: AtomicU64::new(0),
            queue_wait: LogHistogram::new(),
            queue_wait_total_us: AtomicU64::new(0),
            service: LogHistogram::new(),
            service_total_us: AtomicU64::new(0),
            started: Instant::now(),
            snapshot_seq: AtomicU64::new(0),
            slo_target_us: AtomicU64::new(0),
            slo_total: AtomicU64::new(0),
            slo_bad: AtomicU64::new(0),
            slo_window: Mutex::new(VecDeque::new()),
        }
    }

    /// Seconds since this registry was constructed.
    pub fn uptime_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Arm SLO accounting against a p99 latency target (0 disarms). Every
    /// subsequently recorded latency counts toward the error budget.
    pub fn set_slo_target_us(&self, us: u64) {
        self.slo_target_us.store(us, Ordering::Relaxed);
    }

    /// The armed SLO p99 target in µs (0 when SLO accounting is off).
    pub fn slo_target_us(&self) -> u64 {
        self.slo_target_us.load(Ordering::Relaxed)
    }

    /// Requests counted against the SLO and how many violated the target.
    pub fn slo_counts(&self) -> (u64, u64) {
        let total = self.slo_total.load(Ordering::Relaxed);
        (total, self.slo_bad.load(Ordering::Relaxed))
    }

    /// Lifetime burn rate: `(violations / total) / SLO_EPSILON`. 1.0 means
    /// the p99 error budget is being consumed exactly as fast as it
    /// accrues; 0 when the SLO is off or nothing completed yet.
    pub fn slo_burn_rate(&self) -> f64 {
        let (total, bad) = self.slo_counts();
        if total == 0 {
            0.0
        } else {
            (bad as f64 / total as f64) / SLO_EPSILON
        }
    }

    /// Burn rate over the rolling window (the last [`SLO_WINDOW_TICKS`]
    /// reporter ticks): same definition as [`Metrics::slo_burn_rate`] but
    /// against the deltas since the oldest retained tick, so a recovered
    /// service stops alerting once the bad minutes age out.
    pub fn slo_window_burn_rate(&self) -> f64 {
        let (total, bad) = self.slo_counts();
        let window = self.slo_window.lock().unwrap();
        let (t0, b0) = window.front().copied().unwrap_or((0, 0));
        let dt = total.saturating_sub(t0);
        let db = bad.saturating_sub(b0);
        if dt == 0 {
            0.0
        } else {
            (db as f64 / dt as f64) / SLO_EPSILON
        }
    }

    /// Fraction of the p99 error budget still unspent, in [0, 1]:
    /// `max(0, 1 − burn_rate)`.
    pub fn slo_budget_remaining(&self) -> f64 {
        (1.0 - self.slo_burn_rate()).max(0.0)
    }

    /// Advance the rolling SLO window by one tick (the reporter thread
    /// calls this once per interval).
    pub fn slo_tick(&self) {
        let counts = self.slo_counts();
        let mut window = self.slo_window.lock().unwrap();
        window.push_back(counts);
        while window.len() > SLO_WINDOW_TICKS {
            window.pop_front();
        }
    }

    /// Record the resident weight bytes of the scorer now serving
    /// `variant` (workers call this at start and after each hot-swap).
    pub fn set_resident_weight_bytes(&self, variant: Variant, bytes: u64) {
        self.resident_weight_bytes[variant.index()].store(bytes, Ordering::Relaxed);
    }

    /// Resident weight bytes of the scorer currently serving `variant`
    /// (0 until a worker reports in).
    pub fn resident_weight_bytes(&self, variant: Variant) -> u64 {
        self.resident_weight_bytes[variant.index()].load(Ordering::Relaxed)
    }

    /// Publish a paged-KV cache snapshot (workers call this after every
    /// prefill/decode batch). Gauge semantics: each call overwrites the
    /// previous snapshot wholesale.
    pub fn set_kv_stats(&self, s: &crate::model::kvcache::KvStatsSnapshot) {
        self.kv_hits.store(s.hits, Ordering::Relaxed);
        self.kv_misses.store(s.misses, Ordering::Relaxed);
        self.kv_evictions.store(s.evictions, Ordering::Relaxed);
        self.kv_pages_resident.store(s.pages_resident, Ordering::Relaxed);
        self.kv_pages_total.store(s.pages_total, Ordering::Relaxed);
        self.kv_sessions.store(s.sessions, Ordering::Relaxed);
    }

    /// Prefix-cache page hit rate in [0, 1]: shared-block lookups that
    /// found an already-cached page over all full-block lookups. 0 before
    /// any session traffic.
    pub fn kv_hit_rate(&self) -> f64 {
        let h = self.kv_hits.load(Ordering::Relaxed);
        let m = self.kv_misses.load(Ordering::Relaxed);
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// Store a sampled queue depth for `variant` (gauge semantics).
    pub fn set_queue_depth(&self, variant: Variant, depth: u64) {
        self.queue_depth[variant.index()].store(depth, Ordering::Relaxed);
    }

    /// Most recently sampled queue depth for `variant`.
    pub fn queue_depth(&self, variant: Variant) -> u64 {
        self.queue_depth[variant.index()].load(Ordering::Relaxed)
    }

    pub fn record_latency_us(&self, us: u64) {
        self.latency.record_us(us);
        self.latency_total_us.fetch_add(us, Ordering::Relaxed);
        let target = self.slo_target_us();
        if target > 0 {
            self.slo_total.fetch_add(1, Ordering::Relaxed);
            if us > target {
                self.slo_bad.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    pub fn record_queue_wait_us(&self, us: u64) {
        self.queue_wait.record_us(us);
        self.queue_wait_total_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn record_service_us(&self, us: u64) {
        self.service.record_us(us);
        self.service_total_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn record_batch(&self, size: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batched_requests
            .fetch_add(size as u64, Ordering::Relaxed);
    }

    /// Approximate latency percentile (upper bucket bound), p in [0,1].
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.latency.percentile_us(p)
    }

    /// Approximate queue-wait percentile (upper bucket bound), p in [0,1].
    pub fn queue_wait_percentile_us(&self, p: f64) -> u64 {
        self.queue_wait.percentile_us(p)
    }

    /// Approximate service-time percentile (upper bucket bound), p in [0,1].
    pub fn service_percentile_us(&self, p: f64) -> u64 {
        self.service.percentile_us(p)
    }

    /// Exact mean end-to-end latency in µs (0 when nothing completed).
    pub fn mean_latency_us(&self) -> f64 {
        mean(&self.latency, &self.latency_total_us)
    }

    /// Exact mean queue wait in µs (0 when nothing completed).
    pub fn mean_queue_wait_us(&self) -> f64 {
        mean(&self.queue_wait, &self.queue_wait_total_us)
    }

    /// Exact mean service time in µs (0 when nothing completed).
    pub fn mean_service_us(&self) -> f64 {
        mean(&self.service, &self.service_total_us)
    }

    /// Mean requests per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.batched_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Record one scored length-bucket chunk: its width, the tokens it
    /// actually scored, and the tokens its padded rectangular shape would
    /// hold (`width × max window length`).
    pub fn record_bucket(&self, width: usize, actual_tokens: u64, padded_tokens: u64) {
        self.bucket_batches.fetch_add(1, Ordering::Relaxed);
        self.bucket_requests.fetch_add(width as u64, Ordering::Relaxed);
        self.batch_tokens_actual
            .fetch_add(actual_tokens, Ordering::Relaxed);
        self.batch_tokens_padded
            .fetch_add(padded_tokens, Ordering::Relaxed);
    }

    /// Mean requests per scored length-bucket (the batch-width gauge the
    /// coalescer is trying to keep high).
    pub fn mean_bucket_width(&self) -> f64 {
        let b = self.bucket_batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.bucket_requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Fraction of the padded batch shape that is padding, in [0, 1):
    /// `1 − actual / padded`. 0 when every chunk was length-uniform (or
    /// nothing was scored yet); high values mean the bucket edges are too
    /// coarse for the traffic's length mix.
    pub fn padding_overhead(&self) -> f64 {
        let padded = self.batch_tokens_padded.load(Ordering::Relaxed);
        if padded == 0 {
            0.0
        } else {
            1.0 - self.batch_tokens_actual.load(Ordering::Relaxed) as f64 / padded as f64
        }
    }

    /// One-line summary: counters, batch/bucket widths, latency
    /// percentiles (p50/p95/p99/p999) with the queue/service split, then
    /// resident bytes **and** padding overhead together — the sweep CSV
    /// and the coordinator log tell the same memory/shape story from the
    /// same line.
    pub fn summary(&self) -> String {
        format!(
            "submitted={} completed={} rejected={} errors={} swaps={} batches={} mean_batch={:.2} bucket_width={:.2} p50={}us p95={}us p99={}us p999={}us queue_p50={}us service_p50={}us queue_depth[dense]={} queue_depth[hss]={} in_flight={} resident_bytes[dense]={} resident_bytes[hss]={} pad_overhead={:.1}% slo_target={}us slo_burn={:.2} slo_window_burn={:.2} kv_hit_rate={:.2} kv_pages={}/{} kv_evictions={}",
            self.submitted.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            self.swaps.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch_size(),
            self.mean_bucket_width(),
            self.latency_percentile_us(0.5),
            self.latency_percentile_us(0.95),
            self.latency_percentile_us(0.99),
            self.latency_percentile_us(0.999),
            self.queue_wait_percentile_us(0.5),
            self.service_percentile_us(0.5),
            self.queue_depth(Variant::Dense),
            self.queue_depth(Variant::Hss),
            self.in_flight.load(Ordering::Relaxed),
            self.resident_weight_bytes(Variant::Dense),
            self.resident_weight_bytes(Variant::Hss),
            100.0 * self.padding_overhead(),
            self.slo_target_us(),
            self.slo_burn_rate(),
            self.slo_window_burn_rate(),
            self.kv_hit_rate(),
            self.kv_pages_resident.load(Ordering::Relaxed),
            self.kv_pages_total.load(Ordering::Relaxed),
            self.kv_evictions.load(Ordering::Relaxed),
        )
    }

    /// Structured snapshot of everything this registry knows, plus the
    /// process-wide per-stage span breakdown, as a [`Json`] value
    /// (`BTreeMap`-backed, so the key set and order are stable). Written
    /// by the serve reporter (`--metrics-json`) and round-trippable
    /// through [`Json::parse`] — counts are finite, means are 0 when
    /// empty, never NaN.
    pub fn to_json(&self) -> Json {
        let hist_json = |h: &LogHistogram, total: &AtomicU64| {
            obj(vec![
                ("count", num(h.count() as f64)),
                ("mean_us", num(mean(h, total))),
                ("p50_us", num(h.percentile_us(0.5) as f64)),
                ("p95_us", num(h.percentile_us(0.95) as f64)),
                ("p99_us", num(h.percentile_us(0.99) as f64)),
                ("p999_us", num(h.percentile_us(0.999) as f64)),
            ])
        };
        let per_variant = |f: &dyn Fn(Variant) -> u64| {
            obj(vec![
                ("dense", num(f(Variant::Dense) as f64)),
                ("hss", num(f(Variant::Hss) as f64)),
            ])
        };
        let (slo_total, slo_bad) = self.slo_counts();
        obj(vec![
            // monotone per-registry sequence + wall uptime: successive
            // snapshots are strictly ordered and counter deltas divide
            // into rates without the consumer keeping its own clock
            (
                "snapshot_seq",
                num((self.snapshot_seq.fetch_add(1, Ordering::Relaxed) + 1) as f64),
            ),
            ("uptime_secs", num(self.uptime_secs())),
            (
                "slo",
                obj(vec![
                    ("target_us", num(self.slo_target_us() as f64)),
                    ("total", num(slo_total as f64)),
                    ("violations", num(slo_bad as f64)),
                    ("burn_rate", num(self.slo_burn_rate())),
                    ("window_burn_rate", num(self.slo_window_burn_rate())),
                    ("budget_remaining", num(self.slo_budget_remaining())),
                ]),
            ),
            (
                "counters",
                obj(vec![
                    ("submitted", num(self.submitted.load(Ordering::Relaxed) as f64)),
                    ("completed", num(self.completed.load(Ordering::Relaxed) as f64)),
                    ("rejected", num(self.rejected.load(Ordering::Relaxed) as f64)),
                    ("errors", num(self.errors.load(Ordering::Relaxed) as f64)),
                    ("swaps", num(self.swaps.load(Ordering::Relaxed) as f64)),
                    ("batches", num(self.batches.load(Ordering::Relaxed) as f64)),
                    (
                        "batched_requests",
                        num(self.batched_requests.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "bucket_batches",
                        num(self.bucket_batches.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "bucket_requests",
                        num(self.bucket_requests.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            ("latency", hist_json(&self.latency, &self.latency_total_us)),
            (
                "queue_wait",
                hist_json(&self.queue_wait, &self.queue_wait_total_us),
            ),
            ("service", hist_json(&self.service, &self.service_total_us)),
            (
                "gauges",
                obj(vec![
                    ("queue_depth", per_variant(&|v| self.queue_depth(v))),
                    (
                        "in_flight",
                        num(self.in_flight.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "resident_bytes",
                        per_variant(&|v| self.resident_weight_bytes(v)),
                    ),
                    ("mean_batch", num(self.mean_batch_size())),
                    ("mean_bucket_width", num(self.mean_bucket_width())),
                    ("padding_overhead", num(self.padding_overhead())),
                    ("kv_hit_rate", num(self.kv_hit_rate())),
                    ("kv_hits", num(self.kv_hits.load(Ordering::Relaxed) as f64)),
                    ("kv_misses", num(self.kv_misses.load(Ordering::Relaxed) as f64)),
                    (
                        "kv_evictions",
                        num(self.kv_evictions.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "kv_pages_resident",
                        num(self.kv_pages_resident.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "kv_pages_total",
                        num(self.kv_pages_total.load(Ordering::Relaxed) as f64),
                    ),
                    (
                        "kv_sessions",
                        num(self.kv_sessions.load(Ordering::Relaxed) as f64),
                    ),
                ]),
            ),
            ("stages", crate::obs::registry().to_json()),
        ])
    }
}

fn mean(h: &LogHistogram, total_us: &AtomicU64) -> f64 {
    let c = h.count();
    if c == 0 {
        0.0
    } else {
        total_us.load(Ordering::Relaxed) as f64 / c as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_monotone() {
        let m = Metrics::new();
        for us in [10u64, 100, 1000, 10_000, 100_000] {
            for _ in 0..100 {
                m.record_latency_us(us);
            }
        }
        let p50 = m.latency_percentile_us(0.5);
        let p95 = m.latency_percentile_us(0.95);
        let p99 = m.latency_percentile_us(0.99);
        let p999 = m.latency_percentile_us(0.999);
        assert!(p50 <= p95 && p95 <= p99 && p99 <= p999);
        assert!(p50 >= 1000 && p50 <= 2048, "{p50}");
    }

    #[test]
    fn empty_percentile_zero() {
        let m = Metrics::new();
        assert_eq!(m.latency_percentile_us(0.99), 0);
        assert_eq!(m.queue_wait_percentile_us(0.99), 0);
        assert_eq!(m.service_percentile_us(0.99), 0);
        assert_eq!(m.mean_latency_us(), 0.0);
    }

    #[test]
    fn batch_stats() {
        let m = Metrics::new();
        m.record_batch(8);
        m.record_batch(4);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn summary_contains_counters() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        let s = m.summary();
        assert!(s.contains("submitted=3"));
        assert!(s.contains("p999="), "{s}");
        assert!(s.contains("queue_depth[dense]="), "{s}");
    }

    #[test]
    fn bucket_and_padding_gauges() {
        let m = Metrics::new();
        assert_eq!(m.mean_bucket_width(), 0.0);
        assert_eq!(m.padding_overhead(), 0.0);
        // a uniform chunk pads nothing; a ragged one pads to its max
        m.record_bucket(4, 32, 32); // 4 windows × 8 tokens, uniform
        m.record_bucket(2, 12, 16); // lengths 4 + 8 padded to 2 × 8
        assert!((m.mean_bucket_width() - 3.0).abs() < 1e-12);
        let want = 1.0 - 44.0 / 48.0;
        assert!((m.padding_overhead() - want).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("bucket_width=3.00"), "{s}");
        assert!(s.contains("pad_overhead=8.3%"), "{s}");
        // resident bytes and padding overhead share the summary line
        assert!(s.contains("resident_bytes[hss]=0"), "{s}");
    }

    #[test]
    fn resident_bytes_gauge_per_variant() {
        let m = Metrics::new();
        assert_eq!(m.resident_weight_bytes(Variant::Hss), 0);
        m.set_resident_weight_bytes(Variant::Hss, 4096);
        m.set_resident_weight_bytes(Variant::Dense, 8192);
        assert_eq!(m.resident_weight_bytes(Variant::Hss), 4096);
        assert_eq!(m.resident_weight_bytes(Variant::Dense), 8192);
        // gauge semantics: a swap overwrites, never accumulates
        m.set_resident_weight_bytes(Variant::Hss, 2048);
        assert_eq!(m.resident_weight_bytes(Variant::Hss), 2048);
        assert!(m.summary().contains("resident_bytes[hss]=2048"));
    }

    #[test]
    fn kv_gauges_overwrite_and_surface_in_summary_and_json() {
        let m = Metrics::new();
        assert_eq!(m.kv_hit_rate(), 0.0, "no traffic yet → rate 0, not NaN");
        use crate::model::kvcache::KvStatsSnapshot;
        let snap = KvStatsSnapshot {
            hits: 3,
            misses: 1,
            evictions: 2,
            pages_resident: 40,
            pages_total: 64,
            sessions: 5,
        };
        m.set_kv_stats(&snap);
        assert!((m.kv_hit_rate() - 0.75).abs() < 1e-12);
        // gauge semantics: a later snapshot overwrites wholesale
        m.set_kv_stats(&KvStatsSnapshot {
            hits: 3,
            misses: 3,
            ..snap
        });
        assert!((m.kv_hit_rate() - 0.5).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("kv_hit_rate=0.50"), "{s}");
        assert!(s.contains("kv_pages=40/64"), "{s}");
        assert!(s.contains("kv_evictions=2"), "{s}");
        let text = m.to_json().to_string();
        for key in ["kv_hit_rate", "kv_pages_resident", "kv_pages_total", "kv_sessions"] {
            assert!(text.contains(&format!("\"{key}\"")), "missing {key}: {text}");
        }
    }

    #[test]
    fn queue_depth_gauge_overwrites() {
        let m = Metrics::new();
        assert_eq!(m.queue_depth(Variant::Dense), 0);
        m.set_queue_depth(Variant::Dense, 17);
        m.set_queue_depth(Variant::Dense, 3);
        assert_eq!(m.queue_depth(Variant::Dense), 3);
    }

    #[test]
    fn queue_plus_service_mean_decomposes_exactly() {
        let m = Metrics::new();
        // worker invariant: latency = queue + service, per request
        for (q, s) in [(100u64, 900u64), (250, 750), (10, 40)] {
            m.record_queue_wait_us(q);
            m.record_service_us(s);
            m.record_latency_us(q + s);
        }
        let sum = m.mean_queue_wait_us() + m.mean_service_us();
        assert!((sum - m.mean_latency_us()).abs() < 1e-9);
    }

    #[test]
    fn to_json_roundtrips_with_stable_keys() {
        let m = Metrics::new();
        m.submitted.fetch_add(5, Ordering::Relaxed);
        m.record_latency_us(1234);
        m.record_queue_wait_us(234);
        m.record_service_us(1000);
        m.set_queue_depth(Variant::Hss, 7);
        let j = m.to_json();
        let text = j.to_string();
        for key in ["queue_wait", "queue_depth", "hss_walk", "p999_us", "in_flight"] {
            assert!(text.contains(&format!("\"{key}\"")), "missing {key}: {text}");
        }
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j, "to_json must round-trip through util::json");
        // key set is stable as more samples arrive
        m.record_latency_us(999_999);
        m.record_batch(4);
        assert_eq!(keys(&m.to_json()), keys(&j));
    }

    /// Satellite: successive `--metrics-json` snapshots must be diffable —
    /// `snapshot_seq` strictly increases and `uptime_secs` never moves
    /// backwards, so consumers can order snapshots and compute rates.
    #[test]
    fn snapshots_strictly_ordered() {
        let m = Metrics::new();
        let mut prev_seq = 0.0;
        let mut prev_up = -1.0;
        for _ in 0..5 {
            let j = m.to_json();
            let seq = j.get("snapshot_seq").unwrap().as_f64().unwrap();
            let up = j.get("uptime_secs").unwrap().as_f64().unwrap();
            assert!(seq > prev_seq, "seq {seq} after {prev_seq}");
            assert!(up >= prev_up, "uptime {up} after {prev_up}");
            prev_seq = seq;
            prev_up = up;
        }
    }

    #[test]
    fn slo_burn_rate_accounting() {
        let m = Metrics::new();
        // off by default: latencies don't count against any budget
        m.record_latency_us(10_000_000);
        assert_eq!(m.slo_counts(), (0, 0));
        assert_eq!(m.slo_burn_rate(), 0.0);

        m.set_slo_target_us(1_000);
        // 100 requests, 2 violations: rate 2% against a 1% budget → burn 2
        for i in 0..100u64 {
            m.record_latency_us(if i < 2 { 5_000 } else { 500 });
        }
        assert_eq!(m.slo_counts(), (100, 2));
        assert!((m.slo_burn_rate() - 2.0).abs() < 1e-12);
        assert_eq!(m.slo_budget_remaining(), 0.0);

        // rolling window: after a tick, only post-tick traffic counts —
        // a clean stretch drives the window burn to 0 while the lifetime
        // burn still remembers the bad spell
        m.slo_tick();
        for _ in 0..100 {
            m.record_latency_us(500);
        }
        assert_eq!(m.slo_window_burn_rate(), 0.0);
        assert!(m.slo_burn_rate() > 0.0);

        let j = m.to_json();
        let slo = j.get("slo").unwrap();
        assert_eq!(slo.get("target_us").unwrap().as_f64(), Some(1_000.0));
        assert_eq!(slo.get("violations").unwrap().as_f64(), Some(2.0));
        assert!(slo.get("burn_rate").unwrap().as_f64().unwrap() > 0.0);
        let s = m.summary();
        assert!(s.contains("slo_target=1000us"), "{s}");
        assert!(s.contains("slo_burn="), "{s}");
    }

    #[test]
    fn slo_window_is_bounded() {
        let m = Metrics::new();
        m.set_slo_target_us(100);
        for _ in 0..(SLO_WINDOW_TICKS + 20) {
            m.record_latency_us(50);
            m.slo_tick();
        }
        assert!(m.slo_window.lock().unwrap().len() <= SLO_WINDOW_TICKS);
        assert_eq!(m.slo_window_burn_rate(), 0.0);
    }

    /// Satellite: 8 threads hammer latency/queue/service/gauges at once;
    /// totals are exact and percentiles monotone afterwards.
    #[test]
    fn concurrent_recording_is_lossless() {
        let m = std::sync::Arc::new(Metrics::new());
        let threads = 8;
        let per = 1_000u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let m = m.clone();
                s.spawn(move || {
                    for i in 0..per {
                        m.record_queue_wait_us(i);
                        m.record_service_us(10 * (i + 1));
                        m.record_latency_us(i + 10 * (i + 1));
                        m.completed.fetch_add(1, Ordering::Relaxed);
                        m.set_queue_depth(Variant::Dense, i);
                        m.in_flight.fetch_add(1, Ordering::Relaxed);
                        m.in_flight.fetch_sub(1, Ordering::Relaxed);
                        let _ = t;
                    }
                });
            }
        });
        let n = threads * per;
        assert_eq!(m.completed.load(Ordering::Relaxed), n);
        assert_eq!(m.in_flight.load(Ordering::Relaxed), 0);
        // exact totals: sum_i i + sum_i 10(i+1) per thread
        let q_per: u64 = (0..per).sum();
        let s_per: u64 = (0..per).map(|i| 10 * (i + 1)).sum();
        let sum = m.mean_queue_wait_us() + m.mean_service_us();
        assert!((sum - m.mean_latency_us()).abs() < 1e-6);
        assert!(
            (m.mean_queue_wait_us() - q_per as f64 / per as f64).abs() < 1e-9,
            "{}",
            m.mean_queue_wait_us()
        );
        assert!((m.mean_service_us() - s_per as f64 / per as f64).abs() < 1e-9);
        let p50 = m.latency_percentile_us(0.5);
        let p999 = m.latency_percentile_us(0.999);
        assert!(p50 <= p999);
        assert!(m.queue_depth(Variant::Dense) < per);
    }

    fn keys(j: &Json) -> Vec<String> {
        fn walk(j: &Json, prefix: &str, out: &mut Vec<String>) {
            if let Json::Obj(map) = j {
                for (k, v) in map {
                    let path = format!("{prefix}/{k}");
                    walk(v, &path, out);
                    out.push(path);
                }
            }
        }
        let mut out = Vec::new();
        walk(j, "", &mut out);
        out.sort();
        out
    }
}
